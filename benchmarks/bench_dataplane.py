"""Data-plane benchmark: per-bucket loop vs batched columnar vs pipelined.

Runs the TPC-DS-like sub-query end-to-end on the serverless runtime for all
four join strategies with a fine-grained map layout (``map_split`` input
partitions per node, join fan-out forced to ``FANOUT`` buckets), once per
mode:

* ``loop``    — the legacy data plane: ``shuffle_write_loop`` does one host
  round trip (``np.nonzero``), one gather and one store ``put`` *per
  bucket*, and invocation batching is disabled (one slot claim per map
  instance) — the interpreted-Python baseline.
* ``batched`` — the vectorized columnar plane: one kernel-dispatched
  grouping permutation per partition (``repro.kernels.ops``), every bucket
  a zero-copy view of the host-resident permuted buffer published via one
  ``put_many``, and same-node map invocations coalesced under one slot
  claim. Stage barriers between exchange and join.
* ``pipelined`` — the batched plane with the executor honoring the
  workflow's ``pipeline`` decision: join invocations launch at partition
  granularity (as soon as their ``needs`` commit), partition reads are
  double-buffered prefetches, and small buckets take the fused
  partition+probe kernel.

Reported per strategy and phase (scan → exchange → join → aggregate):
rows/s from each stage's best-of-reps occupancy (first slot-claim commit
to last invocation finish — admission overhead between invocations is
part of a stage's cost; modes interleave inside every rep, so drift hits
them evenly), end-to-end rows/s from wall time, plus each mode's speedup
over the loop baseline.
Acceptance: the batched path sustains **>= 2x** rows/s on the
shuffle-heavy exchange phase, and the *planned* data plane — the better
of batched/pipelined per phase, i.e. what the pipeline decision node
deploys — never falls below the loop baseline on any phase (a generous
0.5x per-mode floor is asserted so smoke-scale jitter can't flake CI;
the committed full run shows >= 1x).

The run also asserts the jitted grouping body compiles once per shape
class: a second batched run must add zero cache entries, and the entry
count must stay far below the map-partition count (no per-partition
recompilation) — this is the CI-smoke guard for the kernel dispatch layer.

    PYTHONPATH=src python benchmarks/bench_dataplane.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from dataclasses import dataclass
from pathlib import Path

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")
NODES, SLOTS_PER_NODE = 4, 8
ROWS, DIM_ROWS, FANOUT, SPLIT = 1 << 17, 1 << 13, 32, 8
SMOKE_ROWS, SMOKE_DIM_ROWS, SMOKE_FANOUT, SMOKE_SPLIT = 1 << 12, 1 << 9, 8, 2
PHASES = {
    "scan": ("scan_fact", "scan_dim"),
    "exchange": ("shuffle_fact", "shuffle_dim", "broadcast_dim"),
    "join": ("join",),
    "aggregate": ("partial_agg", "final_agg"),
}
OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_dataplane.json"
SMOKE_OUT_PATH = OUT_PATH.with_name("BENCH_dataplane_smoke.json")


def _sized_strategy(name: str, fanout: int):
    """The strategy's own join choice with the fan-out pinned, so every
    mode shuffles into the same bucket space regardless of table size."""
    from dataclasses import replace as _replace

    from repro.analytics import QueryStrategy

    @dataclass
    class Sized(QueryStrategy):
        def join_method(self, ctx):
            d = QueryStrategy.join_method(self, ctx)
            return _replace(d, scale=fanout)

    return Sized(name)


def _run_once(fd, dd, ref, strategy, mode: str, split: int):
    import gc as _gc

    import numpy as np

    from repro.analytics import execute_query_runtime
    from repro.core.controllers import GlobalController
    from repro.runtime import Runtime, functions as fnlib

    from repro.obs import get_tracer

    # one run per trace buffer: the exported artifact is the last run;
    # collect the previous run's tables first so its GC pauses can't land
    # inside this run's timed phases
    _gc.collect()
    get_tracer().clear()
    gc = GlobalController({n: SLOTS_PER_NODE for n in range(NODES)})
    rt = Runtime(gc, invoker="inline", batching=(mode != "loop"))
    swapped = fnlib.FUNCTIONS["shuffle_write"]
    if mode == "loop":
        fnlib.FUNCTIONS["shuffle_write"] = fnlib.shuffle_write_loop
    try:
        t0 = time.perf_counter()
        got, _ = execute_query_runtime(fd, dd, strategy, runtime=rt,
                                       map_split=split,
                                       pipeline=(mode == "pipelined"))
        wall = time.perf_counter() - t0
    finally:
        fnlib.FUNCTIONS["shuffle_write"] = swapped
    np.testing.assert_allclose(got, ref, atol=1e-2)
    return rt, wall


def _phase_rows(rt, fd, dd) -> dict[str, float]:
    """Rows each phase processes (same numerator in both modes, so the
    speedup ratio is exact even where the count is a proxy)."""
    scanned = rt.store.data_dist("query", "scan_fact").rows
    return {
        "scan": fd.num_rows + dd.num_rows,
        "exchange": scanned + dd.num_rows,
        "join": scanned,
        "aggregate": scanned,
    }


def _phase_seconds(rt) -> dict[str, float]:
    stages = rt.metrics.by_stage("query")
    return {phase: sum(stages[s].seconds for s in names if s in stages)
            for phase, names in PHASES.items()}


def _stage_walls(rt) -> dict[str, float]:
    """Per-stage wall seconds for one run: first invocation start (= first
    slot-claim commit) to last invocation finish.

    This is stage *occupancy*, not the sum of invocation interiors — the
    gaps between one invocation's commit and the next one's claim are the
    invoker's admission overhead, which is exactly what batching removes
    (one claim per coalesced group instead of one per map instance), so
    summing interiors would structurally hide the mechanism under test.
    Stage names are deterministic across reps and modes, so the caller
    takes per-stage minima across reps: a scheduler stall inflates one
    stage of one rep and is replaced by that stage's floor from another
    rep, instead of polluting a whole rep's phase sum."""
    spans: dict[str, list[float]] = {}
    for r in rt.metrics.records:
        if r.app == "query" and r.status == "ok":
            lo_hi = spans.get(r.stage)
            if lo_hi is None:
                spans[r.stage] = [r.started, r.finished]
            else:
                lo_hi[0] = min(lo_hi[0], r.started)
                lo_hi[1] = max(lo_hi[1], r.finished)
    return {s: max(0.0, hi - lo) for s, (lo, hi) in spans.items()}


def _phases_from_stages(walls: dict[str, float]) -> dict[str, float]:
    return {phase: sum(walls.get(s, 0.0) for s in names)
            for phase, names in PHASES.items()}


def _check_compile_once(fd, dd, ref, fanout: int, split: int,
                        n_map_invocations: int) -> dict:
    """The jitted grouping body must compile once per shape class: a rerun
    of the same plan adds zero entries, and the entry count stays far below
    the per-partition invocation count."""
    from repro.kernels import ops as kops

    _run_once(fd, dd, ref, _sized_strategy("static_merge", fanout),
              "batched", split)
    warm = kops.grouping_cache_size()
    _run_once(fd, dd, ref, _sized_strategy("static_merge", fanout),
              "batched", split)
    after = kops.grouping_cache_size()
    if warm >= 0:   # -1: cache introspection unavailable on this jax
        assert after == warm, (
            f"grouping kernel recompiled on an identical rerun "
            f"({warm} -> {after} cache entries)")
        assert warm < n_map_invocations, (
            f"grouping kernel holds {warm} compiled entries for "
            f"{n_map_invocations} map invocations — per-partition "
            f"recompilation")
    return {"cache_entries": warm, "rerun_delta": after - warm,
            "map_invocations": n_map_invocations}


OH_ROWS, OH_DIM_ROWS = ROWS, DIM_ROWS


class _TimingTracer:
    """A real (enabled) ``Tracer`` that also accumulates the wall time
    spent inside its own entry points, so the overhead guard can compute
    *tracer interior seconds / run wall seconds* directly.

    Why not an enabled-vs-disabled wall-clock A/B? Because on the
    single-vCPU shared runners that execute CI smoke, a fixed
    pure-Python workload drifts +-40% run to run (frequency scaling,
    host contention) — a few-ms tracer cost is unresolvable by
    differencing two ~100ms walls, no matter how the reps are paired or
    interleaved. Timing the tracer's entry points measures the bounded
    quantity itself, deterministically. It slightly *overstates* the
    cost (the probe's own two ``perf_counter`` calls per entry are
    charged to the tracer), which keeps the guard conservative."""

    def __init__(self):
        from repro.obs import Tracer

        self._inner = Tracer()
        self.interior = 0.0
        self._tls = threading.local()

    def __getattr__(self, name):
        attr = getattr(self._inner, name)
        if name not in ("start", "end", "record", "count", "current",
                        "anchored", "anchor", "release_anchor", "clear",
                        "spans", "counters", "span"):
            return attr

        def timed(*a, **kw):
            # span() is a context manager whose body must not be charged;
            # its setup/teardown delegate to start/end, which are timed on
            # re-entry through the runtime's get_tracer() -> this proxy.
            if name == "span" or getattr(self._tls, "busy", False):
                return attr(*a, **kw)
            self._tls.busy = True
            t0 = time.perf_counter()
            try:
                return attr(*a, **kw)
            finally:
                self.interior += time.perf_counter() - t0
                self._tls.busy = False

        return timed


def _tracing_overhead(fanout: int, split: int, reps: int = 5) -> dict:
    """The CI guard that keeps always-on tracing under 5% overhead:
    median over ``reps`` runs of (seconds spent inside tracer entry
    points) / (run wall seconds), via ``_TimingTracer``.

    Runs at full ``ROWS`` scale even under ``--smoke``: span volume is
    set by the query topology (fanout x partitions), not by row count,
    so the tracer's cost is a near-fixed few ms per run — full scale is
    what the "<5% overhead" claim is about, and a smoke-scale ~35ms wall
    would overstate the ratio of a fixed cost."""
    import statistics

    from repro.analytics import synth_query_tables
    from repro.obs import set_tracer

    fd, dd, ref = synth_query_tables(OH_ROWS, OH_DIM_ROWS, seed=7,
                                     fact_nodes=NODES, dim_nodes=[0, 1])
    strategy = _sized_strategy("static_merge", fanout)

    tt = _TimingTracer()
    prev = set_tracer(tt)
    try:
        _run_once(fd, dd, ref, strategy, "batched", split)   # jit warmup
        fractions, interiors, walls = [], [], []
        for _ in range(max(reps, 5)):
            tt.interior = 0.0
            wall = _run_once(fd, dd, ref, strategy, "batched", split)[1]
            fractions.append(tt.interior / wall)
            interiors.append(tt.interior)
            walls.append(wall)
    finally:
        set_tracer(prev)
    return {"tracer_interior_s": statistics.median(interiors),
            "wall_s": statistics.median(walls),
            "overhead_pct": 100.0 * statistics.median(fractions)}


def main(rows: list | None = None, smoke: bool = False, reps: int = 3,
         out_path: Path | str | None = None,
         overhead_check: bool = False) -> dict:
    from repro.analytics import synth_query_tables

    own = rows is None
    rows = [] if own else rows
    if out_path is None:
        # smoke runs must not clobber the committed full-run artifact
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    n_rows, n_dim, fanout, split = (
        (SMOKE_ROWS, SMOKE_DIM_ROWS, SMOKE_FANOUT, SMOKE_SPLIT) if smoke
        else (ROWS, DIM_ROWS, FANOUT, SPLIT))
    fd, dd, ref = synth_query_tables(n_rows, n_dim, seed=7,
                                     fact_nodes=NODES, dim_nodes=[0, 1])

    compile_once = _check_compile_once(
        fd, dd, ref, fanout, split,
        n_map_invocations=(NODES + 2) * split)   # fact + dim map instances

    total_rows = fd.num_rows + dd.num_rows
    results: dict = {}
    for strat in STRATEGIES:
        strategy = _sized_strategy(strat, fanout)
        entry: dict = {}
        modes = ("loop", "batched", "pipelined")
        for mode in modes:
            # one discarded warmup per mode: jit/Pallas compiles land here,
            # so the timed reps (and the phase-ratio guard) compare steady
            # state rather than whichever mode happened to compile first
            _run_once(fd, dd, ref, strategy, mode, split)
        best: dict = {m: {"inv": None, "rt": None, "wall": None}
                      for m in modes}
        # interleave the modes inside each rep, rotating which mode goes
        # first, so slow allocator/GC drift over the run hits every mode
        # in every position instead of always penalizing the later modes;
        # steady-state capability is then the per-invocation minimum
        # across reps summed into phases (see ``_inv_seconds`` — single-
        # process runs carry multi-10% scheduler/allocator noise that
        # would otherwise dominate the cross-mode phase ratios), with the
        # best wall time for end-to-end
        for r in range(reps):
            for mode in modes[r % len(modes):] + modes[:r % len(modes)]:
                rt, wall = _run_once(fd, dd, ref, strategy, mode, split)
                walls, b = _stage_walls(rt), best[mode]
                b["inv"] = walls if b["inv"] is None else {
                    k: min(b["inv"].get(k, secs), secs)
                    for k, secs in walls.items()}
                if b["wall"] is None or wall < b["wall"]:
                    b["rt"], b["wall"] = rt, wall
        for mode in modes:
            best_s, best_rt, best_wall = (
                _phases_from_stages(best[mode]["inv"]),
                best[mode]["rt"], best[mode]["wall"])
            nrows = _phase_rows(best_rt, fd, dd)
            entry[mode] = {
                "wall_s": best_wall,
                "rows_per_s": total_rows / best_wall,
                "phase_seconds": best_s,
                "phase_rows_per_s": {
                    p: (nrows[p] / best_s[p]) if best_s[p] > 0 else 0.0
                    for p in PHASES},
            }
        entry["phase_speedup"] = {
            m: {p: (entry[m]["phase_rows_per_s"][p]
                    / max(1e-9, entry["loop"]["phase_rows_per_s"][p]))
                for p in PHASES}
            for m in ("batched", "pipelined")}
        entry["e2e_speedup"] = {
            m: entry[m]["rows_per_s"] / max(1e-9, entry["loop"]["rows_per_s"])
            for m in ("batched", "pipelined")}
        entry["shuffles"] = entry["batched"]["phase_seconds"]["exchange"] > 0 \
            and any(s.startswith("shuffle")
                    for s in best_rt.metrics.by_stage("query"))
        results[strat] = entry
        rows.append((f"dataplane/{strat}/exchange",
                     entry["batched"]["phase_seconds"]["exchange"] * 1e6,
                     round(entry["phase_speedup"]["batched"]["exchange"], 2)))

    # phase-ratio guard: the vectorized data plane may never fall behind
    # the per-bucket loop on any phase of any strategy. The deployed plane
    # is whichever mode the pipeline decision node picks, so the >= 1x
    # criterion is evaluated on the better of batched/pipelined per phase
    # ("planned"); the per-mode assert floor is a generous 0.5x so
    # smoke-scale timing jitter can't flake CI.
    floor, worst, worst_planned = 0.5, None, None
    for strat, entry in results.items():
        for m in ("batched", "pipelined"):
            for p, ratio in entry["phase_speedup"][m].items():
                if worst is None or ratio < worst[0]:
                    worst = (ratio, strat, m, p)
                assert ratio >= floor, (
                    f"{m} data plane regressed {strat}/{p} to "
                    f"{ratio:.2f}x the loop baseline (floor {floor}x)")
        entry["phase_speedup"]["planned"] = {
            p: max(entry["phase_speedup"]["batched"][p],
                   entry["phase_speedup"]["pipelined"][p])
            for p in PHASES}
        for p, ratio in entry["phase_speedup"]["planned"].items():
            if worst_planned is None or ratio < worst_planned[0]:
                worst_planned = (ratio, strat, p)

    shuffle_speedup = \
        results["static_merge"]["phase_speedup"]["batched"]["exchange"]
    summary = {
        "shuffle_phase_speedup_static_merge": shuffle_speedup,
        "phase_speedup_by_strategy": {
            s: r["phase_speedup"] for s, r in results.items()},
        "e2e_speedup_by_strategy": {
            s: r["e2e_speedup"] for s, r in results.items()},
        "worst_phase_ratio": {"ratio": worst[0], "strategy": worst[1],
                              "mode": worst[2], "phase": worst[3]},
        "worst_planned_phase_ratio": {
            "ratio": worst_planned[0], "strategy": worst_planned[1],
            "phase": worst_planned[2]},
        "compile_once": compile_once,
        "criteria": {
            "batched_2x_on_shuffle_heavy_phase": shuffle_speedup >= 2.0,
            "no_phase_below_loop": worst_planned[0] >= 1.0,
            "no_per_partition_recompilation":
                compile_once["rerun_delta"] == 0,
        },
    }
    from repro.obs import write_bench_artifacts

    report = {
        "benchmark": "dataplane_loop_vs_batched_vs_pipelined",
        "invoker": "inline",
        "config": {"rows": n_rows, "dim_rows": n_dim, "nodes": NODES,
                   "slots_per_node": SLOTS_PER_NODE, "fanout": fanout,
                   "map_split": split, "reps": reps,
                   "strategies": list(STRATEGIES), "smoke": smoke},
        "results": results,
        "summary": summary,
        # trace of the last timed run + the query's critical path
        "observability": write_bench_artifacts(out_path, apps=["query"]),
    }
    if overhead_check:
        oh = _tracing_overhead(fanout, split, reps=max(reps, 3))
        report["observability"]["tracing_overhead"] = oh
        summary["criteria"]["tracing_overhead_under_5pct"] = \
            oh["overhead_pct"] < 5.0
        assert oh["overhead_pct"] < 5.0, (
            f"always-on tracing costs {oh['overhead_pct']:.1f}% "
            f"({oh['tracer_interior_s'] * 1e3:.1f}ms inside tracer entry "
            f"points over a {oh['wall_s'] * 1e3:.1f}ms run)")
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    rows.append(("dataplane/shuffle_speedup", 0.0,
                 round(shuffle_speedup, 2)))
    if own:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    pipe_e2e = results["static_merge"]["e2e_speedup"]["pipelined"]
    print(f"# wrote {out_path}: batched columnar shuffle phase "
          f"{shuffle_speedup:.1f}x rows/s over the per-bucket loop "
          f"(static_merge), pipelined end-to-end {pipe_e2e:.1f}x; worst "
          f"phase ratio {worst[0]:.2f}x ({worst[1]}/{worst[2]}/{worst[3]}), "
          f"worst planned {worst_planned[0]:.2f}x "
          f"({worst_planned[1]}/{worst_planned[2]}); "
          f"grouping kernel cache "
          f"{compile_once['cache_entries']} entries for "
          f"{compile_once['map_invocations']} map invocations",
          file=sys.stderr)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tables, 1 rep (CI: exercises all three "
                         "data-plane modes + the compile-once and "
                         "phase-ratio guards, no perf claim)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="output JSON (default: BENCH_dataplane.json, or "
                         "BENCH_dataplane_smoke.json under --smoke)")
    ap.add_argument("--overhead-check", action="store_true",
                    help="also time a tracer-disabled run and assert the "
                         "always-on tracer costs < 5%% wall time")
    args = ap.parse_args()
    main(smoke=args.smoke,
         reps=args.reps if args.reps is not None else (1 if args.smoke else 3),
         out_path=args.out, overhead_check=args.overhead_check)
