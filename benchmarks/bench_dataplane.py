"""Data-plane benchmark: per-bucket loop path vs batched columnar path.

Runs the TPC-DS-like sub-query end-to-end on the serverless runtime for all
four join strategies with a fine-grained map layout (``map_split`` input
partitions per node, join fan-out forced to ``FANOUT`` buckets), once per
mode:

* ``loop``    — the legacy data plane: ``shuffle_write_loop`` does one host
  round trip (``np.nonzero``), one gather and one store ``put`` *per
  bucket*, and invocation batching is disabled (one slot claim per map
  instance) — the interpreted-Python baseline.
* ``batched`` — the vectorized columnar plane: one kernel-dispatched
  grouping permutation per partition (``repro.kernels.ops``), every bucket
  a ``TableSlice`` of the permuted buffer published via one ``put_many``,
  and same-node map invocations coalesced under one slot claim.

Reported per strategy and phase (scan → exchange → join → aggregate):
rows/s from the summed per-stage invocation seconds, plus the
batched-over-loop speedup. Acceptance: the batched path sustains **>= 2x**
rows/s on the shuffle-heavy exchange phase (criteria in the summary).

The run also asserts the jitted grouping body compiles once per shape
class: a second batched run must add zero cache entries, and the entry
count must stay far below the map-partition count (no per-partition
recompilation) — this is the CI-smoke guard for the kernel dispatch layer.

    PYTHONPATH=src python benchmarks/bench_dataplane.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from dataclasses import dataclass
from pathlib import Path

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")
NODES, SLOTS_PER_NODE = 4, 8
ROWS, DIM_ROWS, FANOUT, SPLIT = 1 << 17, 1 << 13, 32, 8
SMOKE_ROWS, SMOKE_DIM_ROWS, SMOKE_FANOUT, SMOKE_SPLIT = 1 << 12, 1 << 9, 8, 2
PHASES = {
    "scan": ("scan_fact", "scan_dim"),
    "exchange": ("shuffle_fact", "shuffle_dim", "broadcast_dim"),
    "join": ("join",),
    "aggregate": ("partial_agg", "final_agg"),
}
OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_dataplane.json"
SMOKE_OUT_PATH = OUT_PATH.with_name("BENCH_dataplane_smoke.json")


def _sized_strategy(name: str, fanout: int):
    """The strategy's own join choice with the fan-out pinned, so every
    mode shuffles into the same bucket space regardless of table size."""
    from dataclasses import replace as _replace

    from repro.analytics import QueryStrategy

    @dataclass
    class Sized(QueryStrategy):
        def join_method(self, ctx):
            d = QueryStrategy.join_method(self, ctx)
            return _replace(d, scale=fanout)

    return Sized(name)


def _run_once(fd, dd, ref, strategy, mode: str, split: int):
    import numpy as np

    from repro.analytics import execute_query_runtime
    from repro.core.controllers import GlobalController
    from repro.runtime import Runtime, functions as fnlib

    from repro.obs import get_tracer

    # one run per trace buffer: the exported artifact is the last run
    get_tracer().clear()
    gc = GlobalController({n: SLOTS_PER_NODE for n in range(NODES)})
    rt = Runtime(gc, invoker="inline", batching=(mode == "batched"))
    swapped = fnlib.FUNCTIONS["shuffle_write"]
    if mode == "loop":
        fnlib.FUNCTIONS["shuffle_write"] = fnlib.shuffle_write_loop
    try:
        t0 = time.perf_counter()
        got, _ = execute_query_runtime(fd, dd, strategy, runtime=rt,
                                       map_split=split)
        wall = time.perf_counter() - t0
    finally:
        fnlib.FUNCTIONS["shuffle_write"] = swapped
    np.testing.assert_allclose(got, ref, atol=1e-2)
    return rt, wall


def _phase_rows(rt, fd, dd) -> dict[str, float]:
    """Rows each phase processes (same numerator in both modes, so the
    speedup ratio is exact even where the count is a proxy)."""
    scanned = rt.store.data_dist("query", "scan_fact").rows
    return {
        "scan": fd.num_rows + dd.num_rows,
        "exchange": scanned + dd.num_rows,
        "join": scanned,
        "aggregate": scanned,
    }


def _phase_seconds(rt) -> dict[str, float]:
    stages = rt.metrics.by_stage("query")
    return {phase: sum(stages[s].seconds for s in names if s in stages)
            for phase, names in PHASES.items()}


def _check_compile_once(fd, dd, ref, fanout: int, split: int,
                        n_map_invocations: int) -> dict:
    """The jitted grouping body must compile once per shape class: a rerun
    of the same plan adds zero entries, and the entry count stays far below
    the per-partition invocation count."""
    from repro.kernels import ops as kops

    _run_once(fd, dd, ref, _sized_strategy("static_merge", fanout),
              "batched", split)
    warm = kops.grouping_cache_size()
    _run_once(fd, dd, ref, _sized_strategy("static_merge", fanout),
              "batched", split)
    after = kops.grouping_cache_size()
    if warm >= 0:   # -1: cache introspection unavailable on this jax
        assert after == warm, (
            f"grouping kernel recompiled on an identical rerun "
            f"({warm} -> {after} cache entries)")
        assert warm < n_map_invocations, (
            f"grouping kernel holds {warm} compiled entries for "
            f"{n_map_invocations} map invocations — per-partition "
            f"recompilation")
    return {"cache_entries": warm, "rerun_delta": after - warm,
            "map_invocations": n_map_invocations}


def _tracing_overhead(fd, dd, ref, fanout: int, split: int,
                      reps: int = 3) -> dict:
    """Best-of-``reps`` wall time with the tracer on vs a disabled tracer —
    the CI guard that keeps always-on tracing under 5% overhead."""
    from repro.obs import Tracer, set_tracer

    strategy = _sized_strategy("static_merge", fanout)

    def best(n: int) -> float:
        return min(_run_once(fd, dd, ref, strategy, "batched", split)[1]
                   for _ in range(n))

    enabled_s = best(reps)
    prev = set_tracer(Tracer(enabled=False))
    try:
        disabled_s = best(reps)
    finally:
        set_tracer(prev)
    return {"enabled_s": enabled_s, "disabled_s": disabled_s,
            "overhead_pct": 100.0 * (enabled_s / disabled_s - 1.0)}


def main(rows: list | None = None, smoke: bool = False, reps: int = 3,
         out_path: Path | str | None = None,
         overhead_check: bool = False) -> dict:
    from repro.analytics import synth_query_tables

    own = rows is None
    rows = [] if own else rows
    if out_path is None:
        # smoke runs must not clobber the committed full-run artifact
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    n_rows, n_dim, fanout, split = (
        (SMOKE_ROWS, SMOKE_DIM_ROWS, SMOKE_FANOUT, SMOKE_SPLIT) if smoke
        else (ROWS, DIM_ROWS, FANOUT, SPLIT))
    fd, dd, ref = synth_query_tables(n_rows, n_dim, seed=7,
                                     fact_nodes=NODES, dim_nodes=[0, 1])

    compile_once = _check_compile_once(
        fd, dd, ref, fanout, split,
        n_map_invocations=(NODES + 2) * split)   # fact + dim map instances

    results: dict = {}
    for strat in STRATEGIES:
        strategy = _sized_strategy(strat, fanout)
        entry: dict = {}
        for mode in ("loop", "batched"):
            best_s, best_rt, best_wall = None, None, None
            for _ in range(reps):
                rt, wall = _run_once(fd, dd, ref, strategy, mode, split)
                secs = _phase_seconds(rt)
                if best_s is None or sum(secs.values()) < sum(best_s.values()):
                    best_s, best_rt, best_wall = secs, rt, wall
            nrows = _phase_rows(best_rt, fd, dd)
            entry[mode] = {
                "wall_s": best_wall,
                "phase_seconds": best_s,
                "phase_rows_per_s": {
                    p: (nrows[p] / best_s[p]) if best_s[p] > 0 else 0.0
                    for p in PHASES},
            }
        entry["phase_speedup"] = {
            p: (entry["batched"]["phase_rows_per_s"][p]
                / max(1e-9, entry["loop"]["phase_rows_per_s"][p]))
            for p in PHASES}
        entry["shuffles"] = entry["batched"]["phase_seconds"]["exchange"] > 0 \
            and any(s.startswith("shuffle")
                    for s in best_rt.metrics.by_stage("query"))
        results[strat] = entry
        rows.append((f"dataplane/{strat}/exchange",
                     entry["batched"]["phase_seconds"]["exchange"] * 1e6,
                     round(entry["phase_speedup"]["exchange"], 2)))

    shuffle_speedup = results["static_merge"]["phase_speedup"]["exchange"]
    summary = {
        "shuffle_phase_speedup_static_merge": shuffle_speedup,
        "phase_speedup_by_strategy": {
            s: r["phase_speedup"] for s, r in results.items()},
        "compile_once": compile_once,
        "criteria": {
            "batched_2x_on_shuffle_heavy_phase": shuffle_speedup >= 2.0,
            "no_per_partition_recompilation":
                compile_once["rerun_delta"] == 0,
        },
    }
    from repro.obs import write_bench_artifacts

    report = {
        "benchmark": "dataplane_loop_vs_batched_columnar",
        "invoker": "inline",
        "config": {"rows": n_rows, "dim_rows": n_dim, "nodes": NODES,
                   "slots_per_node": SLOTS_PER_NODE, "fanout": fanout,
                   "map_split": split, "reps": reps,
                   "strategies": list(STRATEGIES), "smoke": smoke},
        "results": results,
        "summary": summary,
        # trace of the last timed run + the query's critical path
        "observability": write_bench_artifacts(out_path, apps=["query"]),
    }
    if overhead_check:
        oh = _tracing_overhead(fd, dd, ref, fanout, split, reps=max(reps, 3))
        report["observability"]["tracing_overhead"] = oh
        summary["criteria"]["tracing_overhead_under_5pct"] = \
            oh["overhead_pct"] < 5.0
        assert oh["overhead_pct"] < 5.0, (
            f"always-on tracing costs {oh['overhead_pct']:.1f}% "
            f"({oh['enabled_s']:.3f}s vs {oh['disabled_s']:.3f}s disabled)")
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    rows.append(("dataplane/shuffle_speedup", 0.0,
                 round(shuffle_speedup, 2)))
    if own:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {out_path}: batched columnar shuffle phase "
          f"{shuffle_speedup:.1f}x rows/s over the per-bucket loop "
          f"(static_merge); grouping kernel cache "
          f"{compile_once['cache_entries']} entries for "
          f"{compile_once['map_invocations']} map invocations",
          file=sys.stderr)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tables, 1 rep (CI: exercises both data-plane "
                         "paths + the compile-once guard, no perf claim)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="output JSON (default: BENCH_dataplane.json, or "
                         "BENCH_dataplane_smoke.json under --smoke)")
    ap.add_argument("--overhead-check", action="store_true",
                    help="also time a tracer-disabled run and assert the "
                         "always-on tracer costs < 5%% wall time")
    args = ap.parse_args()
    main(smoke=args.smoke,
         reps=args.reps if args.reps is not None else (1 if args.smoke else 3),
         out_path=args.out, overhead_check=args.overhead_check)
