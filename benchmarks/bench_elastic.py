"""Elastic worker-plane benchmark: process pool vs threads, cold-start
economics, and cross-plane elasticity decision parity.

Three phases, one ``BENCH_elastic.json`` (repo root):

1. **Backend fan-out sweep.** A compute-bound map stage (``cpu_spin`` — a
   pure-Python loop that holds the GIL for its whole body) at fan-outs
   32→1024 on the ``threads`` and ``process`` invokers with identical slot
   budgets. On a multi-core host the process backend wins wall-clock at
   high fan-out because worker subprocesses escape the GIL; ``host_cores``
   is recorded so a single-vCPU run's numbers are read honestly.
2. **Cold-start economics.** The same stage on a warm pool (prewarmed,
   reused) vs cold-start-every-time (``idle_reap_s=0`` retires every
   worker as it idles), reporting the measured function-seconds ratio —
   the Lambada-style bill the warm pool exists to cut.
3. **Decision parity.** The full query planned through one workflow on
   both data planes with worker pools engaged (runtime: prewarmed
   ``ProcessPoolInvoker``; simulator: ``ClusterSim`` cold-start twin with
   the same warm pool) — the six-node decision sequences, including the
   ``elastic`` node's func/scale, must be identical.

    PYTHONPATH=src python benchmarks/bench_elastic.py [--smoke] [--reps N]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

FANOUTS = (32, 64, 256, 1024)
SMOKE_FANOUTS = (8, 16)
SPIN_ITERS = 50_000
SMOKE_SPIN_ITERS = 10_000
WORKERS = 4
SMOKE_WORKERS = 2          # single-vCPU CI runners
ECON_FANOUT, SMOKE_ECON_FANOUT = 12, 4
OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_elastic.json"
SMOKE_OUT_PATH = OUT_PATH.with_name("BENCH_elastic_smoke.json")


def _pin_xla_single_thread() -> None:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false"
                               " intra_op_parallelism_threads=1").strip()


def _spin_stage(app: str, fanout: int, iters: int):
    from repro.runtime import Invocation, RuntimeStage

    return RuntimeStage("spin", [
        Invocation(f"{app}/spin/{i}", app, "spin", i, "cpu_spin", 0,
                   priority=10,
                   params={"dst": "spun", "partition": i, "iters": iters})
        for i in range(fanout)])


def _expected_acc(partition: int, iters: int) -> int:
    x, acc = partition + 1, 0
    for i in range(iters):
        acc = (acc + x * i) % 1_000_003
    return acc


def _run_fanout(backend: str, fanout: int, iters: int, workers: int):
    """One compute-bound fan-out on one backend under an identical slot
    budget (``workers`` concurrent function slots). Returns (wall, extras).
    """
    import numpy as np

    from repro.core.controllers import GlobalController
    from repro.obs import get_tracer
    from repro.runtime import Runtime

    get_tracer().clear()
    gc = GlobalController({0: workers})
    rt = Runtime(gc, invoker=backend, max_workers=workers)
    try:
        if backend == "process":
            rt.invoker.resize(workers)          # pre-warm outside the clock
        t0 = time.perf_counter()
        rt.execute([_spin_stage("spin", fanout, iters)])
        wall = time.perf_counter() - t0
        # verify a sample of the deterministic outputs
        for part in (0, fanout // 2, fanout - 1):
            t = rt.store.get("spin", "spun", part, node=0)
            assert int(np.asarray(t["acc"])[0]) == _expected_acc(part, iters)
        assert sum(gc.used.values()) == 0
        extras = {}
        if backend == "process":
            extras = rt.invoker.pool.stats()
        return wall, extras
    finally:
        if backend == "process":
            rt.invoker.shutdown()


def _run_economics(fanout: int, iters: int, workers: int, warm: bool):
    """The same stage billed warm (prewarmed pool, reused) vs cold-start-
    every-time (idle workers retire immediately, so every lease pays a
    fresh provision)."""
    from repro.core.controllers import GlobalController
    from repro.runtime import Runtime
    from repro.runtime.workers import ProcessPoolInvoker

    gc = GlobalController({0: workers})
    if warm:
        rt = Runtime(gc, invoker="process", max_workers=workers)
        rt.invoker.resize(workers)     # prewarm: pays provision up front
    else:
        rt = Runtime(gc, invoker="inline")
        # idle_reap_s=0 retires every worker the moment it idles, so each
        # lease is a fresh provision — the no-warm-pool baseline bill
        rt.invoker = ProcessPoolInvoker(gc, rt.store, rt.metrics,
                                        max_workers=workers, idle_reap_s=0.0)
    try:
        t0 = time.perf_counter()
        rt.execute([_spin_stage("econ", fanout, iters)])
        wall = time.perf_counter() - t0
        stats = rt.invoker.pool.stats()
        stats["wall_s"] = round(wall, 6)
        return stats
    finally:
        rt.invoker.shutdown()


def _run_parity(pool: int):
    """Plan the query through one workflow on both planes with worker
    pools engaged; return both decision sequences."""
    from repro.analytics import (QueryStrategy, execute_query_runtime,
                                 synth_query_tables)
    from repro.analytics.planner import (build_query_workflow,
                                         plan_query_with_workflow)
    from repro.analytics.simulator import ClusterSim
    from repro.core.controllers import GlobalController, PrivateController
    from repro.runtime import Runtime

    import numpy as np

    fd, dd, ref = synth_query_tables(1 << 12, 1 << 10, seed=1,
                                     fact_nodes=range(2), dim_nodes=[2, 3])
    wf = build_query_workflow(QueryStrategy("dynamic"))
    gc_rt = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc_rt, invoker="process", max_workers=pool)
    try:
        rt.invoker.resize(pool)
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("dynamic"),
                                       runtime=rt, workflow=wf)
        np.testing.assert_allclose(got, ref, atol=1e-2)
    finally:
        rt.invoker.shutdown()
    seq_runtime = [(s, d.func, d.scale) for s, d in wf.last_run.sequence]

    gc_sim = GlobalController({n: 8 for n in range(4)})
    sim = ClusterSim(gc_sim, provision_s=0.5, warm_pool=pool)
    pc = PrivateController("query", gc_sim, priority=10)
    plan_query_with_workflow(sim, pc, fd, dd, QueryStrategy("dynamic"),
                             workflow=wf)
    sim.run()
    seq_sim = [(s, d.func, d.scale) for s, d in wf.last_run.sequence]
    return seq_runtime, seq_sim


def main(rows: list | None = None, smoke: bool = False, reps: int = 3,
         out_path: Path | str | None = None) -> dict:
    from repro.obs import write_bench_artifacts

    rows = [] if rows is None else rows
    if out_path is None:
        # smoke runs must not clobber the committed full-run artifact
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    fanouts = SMOKE_FANOUTS if smoke else FANOUTS
    iters = SMOKE_SPIN_ITERS if smoke else SPIN_ITERS
    workers = SMOKE_WORKERS if smoke else WORKERS
    econ_fanout = SMOKE_ECON_FANOUT if smoke else ECON_FANOUT
    host_cores = os.cpu_count() or 1

    # -- phase 1: backend fan-out sweep ------------------------------------
    sweep: dict = {}
    for fanout in fanouts:
        entry: dict = {}
        for backend in ("threads", "process"):
            walls, extras = [], {}
            for _ in range(reps):
                wall, extras = _run_fanout(backend, fanout, iters, workers)
                walls.append(wall)
            entry[f"{backend}_s"] = min(walls)
            if extras:
                entry["pool"] = extras
        entry["speedup_process_vs_threads"] = \
            entry["threads_s"] / entry["process_s"]
        sweep[str(fanout)] = entry
        for backend in ("threads", "process"):
            rows.append((f"elastic/fanout{fanout}/{backend}",
                         entry[f"{backend}_s"] * 1e6 / fanout,
                         round(entry["speedup_process_vs_threads"], 3)))
        print(f"# fanout {fanout}: threads {entry['threads_s']:.3f}s, "
              f"process {entry['process_s']:.3f}s "
              f"({entry['speedup_process_vs_threads']:.2f}x)",
              file=sys.stderr)

    # -- phase 2: warm pool vs cold-start-every-time -----------------------
    warm = _run_economics(econ_fanout, iters, workers, warm=True)
    cold = _run_economics(econ_fanout, iters, workers, warm=False)
    ratio = cold["cost_function_seconds"] / \
        max(warm["cost_function_seconds"], 1e-9)
    rows.append(("elastic/economics/warm_vs_cold",
                 warm["cost_function_seconds"] * 1e6, round(ratio, 3)))
    print(f"# economics: warm {warm['cost_function_seconds']:.2f} fn-s "
          f"({warm['cold_starts']} cold starts), cold-every-time "
          f"{cold['cost_function_seconds']:.2f} fn-s "
          f"({cold['cold_starts']} cold starts) -> {ratio:.2f}x",
          file=sys.stderr)

    # -- phase 3: elasticity decision parity across planes ------------------
    seq_runtime, seq_sim = _run_parity(pool=workers if not smoke else 2)
    parity = seq_runtime == seq_sim
    assert parity, (seq_runtime, seq_sim)
    assert [n for n, _ in seq_runtime[-2:]] == ["elastic", "tiering"]

    report = {
        "benchmark": "elastic_worker_plane",
        "host_cores": host_cores,
        # the wall-clock claim (process beats threads at fan-out >= 256)
        # requires real cores; on a single-vCPU host the sweep measures
        # protocol overhead only
        "multi_core_host": host_cores > 1,
        "config": {"fanouts": list(fanouts), "spin_iters": iters,
                   "workers": workers, "econ_fanout": econ_fanout,
                   "reps": reps, "smoke": smoke},
        "fanout_sweep": sweep,
        "economics": {"warm_pool": warm, "cold_every_time": cold,
                      "warm_vs_cold_fn_seconds_ratio": round(ratio, 3)},
        "decision_parity": {
            "identical": parity,
            "sequence": [{"node": s, "func": f, "scale": int(sc)}
                         for s, f, sc in seq_runtime]},
        "observability": write_bench_artifacts(out_path, apps=["spin"]),
    }
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path} (host_cores={host_cores}, "
          f"warm-vs-cold {ratio:.2f}x, parity={parity})", file=sys.stderr)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fan-outs, 2 workers, 1 rep (CI)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    _pin_xla_single_thread()
    main(smoke=args.smoke,
         reps=args.reps if args.reps is not None else (1 if args.smoke else 3),
         out_path=args.out)
