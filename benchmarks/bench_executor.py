"""Executor benchmark: barrier vs dependency-driven DAG execution.

Runs the TPC-DS-like sub-query end-to-end on the serverless runtime under
the ``threads`` invoker (``--invoker process`` runs the same sweep on the
process-backed worker plane) for all four strategies, once with the legacy
barrier-per-stage executor and once with the dependency-driven scheduler,
and emits ``BENCH_executor.json`` (repo root) with per-strategy wall-clock
and speedups.

The store runs in disaggregated mode (the Lambada/Pocket model: every byte
read from or written to the ephemeral store crosses the network at
``NET_BW``), which is where dependency-driven scheduling pays: one side's
storage transfers overlap the other side's compute instead of serializing
behind a per-stage barrier. XLA intra-op threading is pinned to one thread
(standalone runs) so the measurement isolates *inter-stage* scheduling.

    PYTHONPATH=src python benchmarks/bench_executor.py [--smoke] [--reps N]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")
NET_BW = 100e6            # bytes/s per function <-> storage link
ROWS, DIM_ROWS = 1 << 19, 1 << 18
SMOKE_ROWS, SMOKE_DIM_ROWS = 1 << 12, 1 << 11
OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_executor.json"
SMOKE_OUT_PATH = OUT_PATH.with_name("BENCH_executor_smoke.json")


def _pin_xla_single_thread() -> None:
    """Must run before jax initializes; isolates inter-stage scheduling."""
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false"
                               " intra_op_parallelism_threads=1").strip()


def _make_tables(rows: int, dim_rows: int):
    from repro.analytics import synth_query_tables

    # fact on nodes {0,1}, dim on {2,3}: scans and exchanges of the two
    # sides are fully independent stages on a 4-node cluster
    return synth_query_tables(rows, dim_rows, seed=1, fact_nodes=range(2),
                              dim_nodes=[2, 3])


def _run_once(fd, dd, strategy: str, barrier: bool,
              invoker: str = "threads", max_workers: int = 8,
              store_backend: str = "memory"):
    from repro.analytics import QueryStrategy, execute_query_runtime
    from repro.core.controllers import GlobalController
    from repro.runtime import Runtime

    from repro.obs import get_tracer

    # one run per trace buffer: the exported artifact is the last run
    get_tracer().clear()
    gc = GlobalController({n: 8 for n in range(4)})
    runtime = Runtime(gc, invoker=invoker, net_bw=NET_BW,
                      disaggregated=True, max_workers=max_workers,
                      storage=store_backend)
    try:
        t0 = time.perf_counter()
        got, _ = execute_query_runtime(fd, dd, QueryStrategy(strategy),
                                       runtime=runtime, barrier=barrier)
        wall = time.perf_counter() - t0
        return wall, got
    finally:
        if invoker == "process":
            runtime.invoker.shutdown()
        runtime.store.close()       # disk primary: remove the spill tempdir


def main(rows: list | None = None, smoke: bool = False, reps: int = 3,
         out_path: Path | str | None = None,
         invoker: str = "threads", max_workers: int = 8,
         store_backend: str = "memory") -> dict:
    import numpy as np

    from repro.obs import write_bench_artifacts

    own = rows is None
    rows = [] if own else rows
    if out_path is None:
        # smoke runs must not clobber the committed full-run artifact
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    n_rows, n_dim = (SMOKE_ROWS, SMOKE_DIM_ROWS) if smoke \
        else (ROWS, DIM_ROWS)
    fd, dd, ref = _make_tables(n_rows, n_dim)

    results: dict = {}
    for strat in STRATEGIES:
        entry = {}
        for mode, barrier in (("barrier", True), ("deps", False)):
            walls = []
            for _ in range(reps):
                wall, got = _run_once(fd, dd, strat, barrier,
                                      invoker=invoker,
                                      max_workers=max_workers,
                                      store_backend=store_backend)
                np.testing.assert_allclose(got, ref, atol=1e-2)
                walls.append(wall)
            entry[f"{mode}_s"] = min(walls)
        entry["speedup"] = entry["barrier_s"] / entry["deps_s"]
        results[strat] = entry
        rows.append((f"executor/{strat}/deps", entry["deps_s"] * 1e6,
                     round(entry["speedup"], 3)))

    barrier_total = sum(r["barrier_s"] for r in results.values())
    deps_total = sum(r["deps_s"] for r in results.values())
    report = {
        "benchmark": "executor_barrier_vs_deps",
        "invoker": invoker,
        "config": {"rows": n_rows, "dim_rows": n_dim, "nodes": 4,
                   "slots_per_node": 8, "net_bw": NET_BW,
                   "disaggregated": True, "reps": reps, "smoke": smoke,
                   "store_backend": store_backend},
        "results": results,
        "summary": {"barrier_total_s": barrier_total,
                    "deps_total_s": deps_total,
                    "speedup": barrier_total / deps_total},
        # trace of the last timed (deps) run + the query's critical path
        "observability": write_bench_artifacts(out_path, apps=["query"]),
    }
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    rows.append(("executor/total/deps", deps_total * 1e6,
                 round(barrier_total / deps_total, 3)))
    if own:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {out_path}: barrier {barrier_total * 1e3:.1f}ms, "
          f"deps {deps_total * 1e3:.1f}ms "
          f"({barrier_total / deps_total:.2f}x)", file=sys.stderr)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tables, 1 rep (CI: exercises the "
                         "dependency-driven path, no perf claim)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="output JSON (default: BENCH_executor.json, or "
                         "BENCH_executor_smoke.json under --smoke)")
    ap.add_argument("--invoker", default="threads",
                    choices=["threads", "process", "inline"],
                    help="function backend (process: real worker "
                         "subprocesses; cap --max-workers on small hosts)")
    ap.add_argument("--max-workers", type=int, default=8)
    ap.add_argument("--store-backend", default="memory",
                    choices=["memory", "disk"],
                    help="shuffle store primary tier (disk: every blob "
                         "round-trips through real files in a tempdir)")
    args = ap.parse_args()
    _pin_xla_single_thread()
    main(smoke=args.smoke,
         reps=args.reps if args.reps is not None else (1 if args.smoke else 3),
         out_path=args.out, invoker=args.invoker,
         max_workers=args.max_workers, store_backend=args.store_backend)
