"""Fault-tolerance benchmark: lineage recovery vs whole-query rerun, and
speculative execution vs straggler tails.

Section A (recovery): a seeded ``FaultPlan`` kills two invocations
(crash-before-commit on a scan, crash-after-write on the join), and evicts
one partition of the consumed ephemeral ``joined`` stage right as its
consumer first reads it. Each of the four join strategies runs twice under
the same plan:

* ``lineage`` — the executor heals the loss by re-executing only the lost
  partition's producer invocations (recursively through GC'd inputs; a
  store quota keeps consumed inputs sealed, so recovery stays shallow),
* ``rerun``   — the executor surfaces ``RecoveryError`` and the whole query
  re-executes from the base inputs (the Lambada-style baseline).

Reported per strategy: invocations re-executed beyond a fault-free run, and
wall time. Acceptance: lineage re-executes **< 50 %** of the invocations
the rerun baseline does (criteria in the summary).

Section B (speculation): one node straggles the fact scan by ``delay``
seconds; with a ``SpeculationPolicy`` installed the thread-pool invoker
launches a backup on another node once the invocation exceeds a p50
multiple (first completion wins). Reported: per-invocation completion p99
with and without speculation. Acceptance: speculation cuts the straggler
p99 below the injected delay.

    PYTHONPATH=src python benchmarks/bench_faults.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")
NODES, SLOTS_PER_NODE = 4, 8
ROWS, DIM_ROWS = 1 << 14, 1 << 10
SMOKE_ROWS, SMOKE_DIM_ROWS = 1 << 12, 1 << 9
DELAY, SMOKE_DELAY = 0.6, 0.25
OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_faults.json"
SMOKE_OUT_PATH = OUT_PATH.with_name("BENCH_faults_smoke.json")


def _recovery_plan():
    from repro.runtime import CrashFault, FaultPlan, StageLossFault

    return FaultPlan(
        crashes=[CrashFault("scan_fact", index=0, when="before"),
                 CrashFault("join", index=0, when="after")],
        losses=[StageLossFault("joined", partitions=(0,), on_read=1)])


def _make_runtime(quota: int | None = None):
    from repro.core.controllers import GlobalController
    from repro.runtime import Runtime

    gc = GlobalController({n: SLOTS_PER_NODE for n in range(NODES)})
    rt = Runtime(gc)
    if quota is not None:
        rt.store.set_quota("query", quota)
    return rt


def _bench_recovery(fd, dd, ref, strat: str) -> dict:
    import numpy as np

    from repro.analytics import QueryStrategy, execute_query_runtime
    from repro.runtime import FaultInjector, RecoveryError

    # fault-free execution count is the re-execution baseline
    got, rt = execute_query_runtime(fd, dd, QueryStrategy(strat),
                                    runtime=_make_runtime())
    np.testing.assert_allclose(got, ref, atol=1e-2)
    n_clean = len(rt.metrics.records)

    # lineage recovery (quota keeps consumed inputs sealed -> shallow heal)
    rt = _make_runtime(quota=1 << 30)
    FaultInjector(_recovery_plan()).install(rt)
    t0 = time.perf_counter()
    got, _ = execute_query_runtime(fd, dd, QueryStrategy(strat), runtime=rt)
    lineage_wall = time.perf_counter() - t0
    np.testing.assert_allclose(got, ref, atol=1e-2)
    assert rt.recoveries, "the loss was injected but never recovered"
    lineage_reexec = len(rt.metrics.records) - n_clean
    recovered = [list(ev.recovered) for ev in rt.recoveries]

    # whole-query rerun baseline: same plan, executor refuses to recompute
    rt = _make_runtime(quota=1 << 30)
    injector = FaultInjector(_recovery_plan()).install(rt)
    t0 = time.perf_counter()
    try:
        execute_query_runtime(fd, dd, QueryStrategy(strat), runtime=rt,
                              recovery="rerun")
        raise AssertionError("loss did not surface under rerun policy")
    except RecoveryError:
        pass
    rt.release("query")                      # tear down the failed attempt
    # the fault already fired; the rerun executes fault-free on the same
    # (still-armed but exhausted) injector — exactly once
    got, _ = execute_query_runtime(fd, dd, QueryStrategy(strat), runtime=rt)
    rerun_wall = time.perf_counter() - t0
    np.testing.assert_allclose(got, ref, atol=1e-2)
    rerun_reexec = len(rt.metrics.records) - n_clean
    assert injector.injected, "fault plan never fired"

    return {
        "clean_invocations": n_clean,
        "lineage_reexec": lineage_reexec,
        "rerun_reexec": rerun_reexec,
        "reexec_ratio": lineage_reexec / max(1, rerun_reexec),
        "lineage_wall_s": lineage_wall,
        "rerun_wall_s": rerun_wall,
        "recovered_stages": recovered,
    }


def _completion_p99(metrics, stage: str) -> float:
    """p99 over per-invocation completion times: for each invocation index
    the *first* successful copy counts (first-completion-wins)."""
    import numpy as np

    best: dict[str, float] = {}
    for r in metrics.records:
        if r.stage == stage and r.status == "ok":
            best[r.name] = min(best.get(r.name, float("inf")), r.seconds)
    return float(np.percentile(sorted(best.values()), 99))


def _bench_speculation(fd, dd, ref, delay: float) -> dict:
    import numpy as np

    from repro.analytics import QueryStrategy, execute_query_runtime
    from repro.core.controllers import GlobalController
    from repro.runtime import (
        FaultInjector,
        FaultPlan,
        MetricsSink,
        Runtime,
        ShuffleStore,
        SpeculationPolicy,
        StragglerFault,
        ThreadPoolInvoker,
    )

    out = {}
    for mode in ("no_speculation", "speculation"):
        plan = FaultPlan(stragglers=[StragglerFault(node=1, delay=delay,
                                                    stage="scan_fact")])
        gc = GlobalController({n: SLOTS_PER_NODE for n in range(NODES)})
        store, metrics = ShuffleStore(), MetricsSink()
        policy = SpeculationPolicy(multiple=3.0, floor=0.02,
                                   interval=0.01) \
            if mode == "speculation" else None
        invoker = ThreadPoolInvoker(gc, store, metrics, max_workers=8,
                                    speculation=policy)
        rt = Runtime(gc, invoker=invoker, store=store, metrics=metrics)
        FaultInjector(plan).install(rt)
        t0 = time.perf_counter()
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_hash"),
                                       runtime=rt)
        wall = time.perf_counter() - t0
        np.testing.assert_allclose(got, ref, atol=1e-2)
        invoker.drain()
        assert sum(gc.used.values()) == 0, "slot leak"
        out[mode] = {
            "scan_p99_s": _completion_p99(metrics, "scan_fact"),
            "query_wall_s": wall,
            "backups_launched": len(invoker.speculations),
        }
    return out


def main(rows: list | None = None, smoke: bool = False,
         out_path: Path | str | None = None) -> dict:
    from repro.analytics import synth_query_tables

    own = rows is None
    rows = [] if own else rows
    if out_path is None:
        # smoke runs must not clobber the committed full-run artifact
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    n_rows, n_dim = (SMOKE_ROWS, SMOKE_DIM_ROWS) if smoke \
        else (ROWS, DIM_ROWS)
    delay = SMOKE_DELAY if smoke else DELAY
    fd, dd, ref = synth_query_tables(n_rows, n_dim, seed=17,
                                     fact_nodes=NODES, dim_nodes=[0, 1])

    from repro.obs import get_tracer, write_bench_artifacts

    recovery = {s: _bench_recovery(fd, dd, ref, s) for s in STRATEGIES}
    # speculation runs last with a fresh buffer: the exported artifact shows
    # the straggler, the speculate/* markers and the backup invocations
    get_tracer().clear()
    speculation = _bench_speculation(fd, dd, ref, delay)

    total_lineage = sum(r["lineage_reexec"] for r in recovery.values())
    total_rerun = sum(r["rerun_reexec"] for r in recovery.values())
    frac = total_lineage / max(1, total_rerun)
    p99_no = speculation["no_speculation"]["scan_p99_s"]
    p99_spec = speculation["speculation"]["scan_p99_s"]
    summary = {
        "lineage_reexec_frac_vs_rerun": frac,
        "straggler_p99_no_spec_s": p99_no,
        "straggler_p99_spec_s": p99_spec,
        "straggler_p99_speedup": p99_no / max(1e-9, p99_spec),
        "criteria": {
            "lineage_reexecutes_under_half_of_rerun": frac < 0.5,
            "speculation_cuts_straggler_p99": p99_spec < p99_no,
        },
    }
    report = {
        "benchmark": "faults_lineage_recovery_and_speculation",
        "config": {"rows": n_rows, "dim_rows": n_dim, "nodes": NODES,
                   "slots_per_node": SLOTS_PER_NODE,
                   "straggler_delay_s": delay,
                   "strategies": list(STRATEGIES), "smoke": smoke},
        "recovery": recovery,
        "speculation": speculation,
        "summary": summary,
        # trace of the speculation runs + the query's critical path
        "observability": write_bench_artifacts(out_path, apps=["query"]),
    }
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")

    for strat in STRATEGIES:
        r = recovery[strat]
        rows.append((f"faults/{strat}/lineage_reexec",
                     r["lineage_wall_s"] * 1e6,
                     f"{r['lineage_reexec']}v{r['rerun_reexec']}"))
    rows.append(("faults/lineage_reexec_frac", 0.0, round(frac, 3)))
    rows.append(("faults/straggler_p99_speedup", 0.0,
                 round(summary["straggler_p99_speedup"], 2)))
    if own:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {out_path}: lineage re-executes {total_lineage} vs "
          f"rerun {total_rerun} invocations ({frac:.0%}); straggler p99 "
          f"{p99_no:.2f}s -> {p99_spec:.3f}s with speculation",
          file=sys.stderr)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tables, short straggler delay (CI: exercises "
                         "injection/recovery paths, no perf claim)")
    ap.add_argument("--out", default=None,
                    help="output JSON (default: BENCH_faults.json, or "
                         "BENCH_faults_smoke.json under --smoke)")
    args = ap.parse_args()
    main(smoke=args.smoke, out_path=args.out)
