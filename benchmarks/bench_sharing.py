"""Sharing benchmark: FIFO vs weighted fair-share for a mixed workload.

A closed-loop mix of 8 queries — alternating low/high priority, rotating
over all four join strategies — is submitted at t=0 to a ``QueryScheduler``
over one shared runtime (threads invoker, disaggregated store so queries
are transfer-bound and genuinely overlap). Two policies are compared:

* ``fifo``       — queries run one at a time in arrival order; a
                   high-priority query stuck behind low-priority work eats
                   its full latency (head-of-line blocking),
* ``fair_share`` — all queries run concurrently; the ``FairShareGate``
                   rations function slots by priority-derived weights, so
                   high-priority queries finish early while low-priority
                   work still progresses (no starvation).

Reported: high-priority p50/p99 closed-loop latency and aggregate makespan
per policy, written to ``BENCH_sharing.json``. The acceptance criteria the
report checks: fair-share beats FIFO on high-priority p99 latency, with
makespan within 10% of FIFO (overlap usually makes it strictly better).

    PYTHONPATH=src python benchmarks/bench_sharing.py [--smoke] [--reps N]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")
NET_BW = 10e6             # bytes/s per function <-> storage link
N_QUERIES = 8
HI_PRIORITY, LO_PRIORITY = 10, 0
# 8 nodes x 4 slots: per-stage demand (8 queries x 8 data-local scans)
# oversubscribes the 32 slots, so the policies actually ration something
NODES, SLOTS_PER_NODE = 8, 4
ROWS, DIM_ROWS = 1 << 17, 1 << 13
SMOKE_ROWS, SMOKE_DIM_ROWS = 1 << 12, 1 << 9
OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_sharing.json"
SMOKE_OUT_PATH = OUT_PATH.with_name("BENCH_sharing_smoke.json")


def _make_workload(n_rows: int, n_dim: int):
    """8 queries: arrival order lo,hi,lo,hi,... so FIFO exhibits
    head-of-line blocking of the high-priority class."""
    from repro.analytics import synth_query_tables

    jobs = []
    for i in range(N_QUERIES):
        fact, dim, ref = synth_query_tables(
            n_rows, n_dim, seed=10 + 3 * i, fact_nodes=NODES,
            dim_nodes=[0, 1])
        jobs.append({
            "app": f"q{i}",
            "fact": fact,
            "dim": dim,
            "strategy": STRATEGIES[i % 4],
            "priority": HI_PRIORITY if i % 2 else LO_PRIORITY,
            "ref": ref,
        })
    return jobs


def _run_policy(jobs, policy: str):
    import numpy as np

    from repro.core.controllers import GlobalController
    from repro.obs import get_tracer
    from repro.runtime import QueryJob, QueryScheduler, Runtime

    # one workload execution per trace buffer: after the last rep the
    # exported artifact is exactly the final policy's final run
    get_tracer().clear()
    gc = GlobalController({n: SLOTS_PER_NODE for n in range(NODES)})
    runtime = Runtime(gc, invoker="threads", max_workers=16,
                      net_bw=NET_BW, disaggregated=True)
    sched = QueryScheduler(runtime, policy=policy)
    for j in jobs:
        sched.submit(QueryJob(j["app"], j["fact"], j["dim"], j["strategy"],
                              priority=j["priority"]))
    results = sched.run()
    for j in jobs:
        res = results[j["app"]]
        if not res.ok:
            raise res.error
        np.testing.assert_allclose(res.sums, j["ref"], atol=1e-2)
    assert sum(gc.used.values()) == 0, "slot leak"
    per_query = {app: {"latency_s": r.latency, "queue_wait_s": r.queue_wait,
                       "priority": r.priority}
                 for app, r in results.items()}
    return {"makespan_s": sched.makespan(), "per_query": per_query}


def _warmup(jobs) -> None:
    """Compile every query's kernels on uncontended runtimes so the timed
    comparison measures scheduling, not XLA compilation."""
    from repro.analytics import QueryStrategy, execute_query_runtime
    from repro.core.controllers import GlobalController
    from repro.runtime import Runtime

    for j in jobs:
        gc = GlobalController({n: SLOTS_PER_NODE for n in range(NODES)})
        execute_query_runtime(j["fact"], j["dim"],
                              QueryStrategy(j["strategy"]),
                              runtime=Runtime(gc, invoker="threads"),
                              app=j["app"])


def main(rows: list | None = None, smoke: bool = False, reps: int = 5,
         out_path: Path | str | None = None) -> dict:
    import numpy as np

    own = rows is None
    rows = [] if own else rows
    if out_path is None:
        # smoke runs must not clobber the committed full-run artifact
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    n_rows, n_dim = (SMOKE_ROWS, SMOKE_DIM_ROWS) if smoke \
        else (ROWS, DIM_ROWS)
    jobs = _make_workload(n_rows, n_dim)
    _warmup(jobs)

    policies: dict = {}
    for policy in ("fifo", "fair_share"):
        rep_outs = []
        for _ in range(reps):
            t0 = time.perf_counter()
            rep_outs.append(_run_policy(jobs, policy))
            rep_outs[-1]["wall_s"] = time.perf_counter() - t0
        def class_lat(rep, prio):
            return [q["latency_s"] for q in rep["per_query"].values()
                    if q["priority"] == prio]

        # p50 over the pooled per-query latencies; p99 computed per rep
        # (one workload execution) and medianed across reps, so a single
        # noisy rep on a shared machine cannot set the tail figure
        hi = [lat for rep in rep_outs for lat in class_lat(rep, HI_PRIORITY)]
        lo = [lat for rep in rep_outs for lat in class_lat(rep, LO_PRIORITY)]
        policies[policy] = {
            "reps": rep_outs,
            "hi_p50_s": float(np.percentile(hi, 50)),
            "hi_p99_s": float(np.median(
                [np.percentile(class_lat(rep, HI_PRIORITY), 99)
                 for rep in rep_outs])),
            "lo_p50_s": float(np.percentile(lo, 50)),
            "lo_p99_s": float(np.median(
                [np.percentile(class_lat(rep, LO_PRIORITY), 99)
                 for rep in rep_outs])),
            "makespan_s": float(np.median([r["makespan_s"]
                                           for r in rep_outs])),
        }

    fifo, fair = policies["fifo"], policies["fair_share"]
    makespan_ratio = fair["makespan_s"] / fifo["makespan_s"]
    summary = {
        "hi_p50_speedup": fifo["hi_p50_s"] / fair["hi_p50_s"],
        "hi_p99_speedup": fifo["hi_p99_s"] / fair["hi_p99_s"],
        "makespan_ratio_fair_over_fifo": makespan_ratio,
        "criteria": {
            "fair_share_beats_fifo_hi_p99":
                fair["hi_p99_s"] < fifo["hi_p99_s"],
            "makespan_within_10pct_of_fifo": makespan_ratio <= 1.10,
        },
    }
    from repro.obs import write_bench_artifacts

    report = {
        "benchmark": "sharing_fifo_vs_fair_share",
        "config": {"queries": N_QUERIES, "rows": n_rows, "dim_rows": n_dim,
                   "nodes": NODES, "slots_per_node": SLOTS_PER_NODE,
                   "net_bw": NET_BW,
                   "disaggregated": True, "strategies": list(STRATEGIES),
                   "reps": reps, "smoke": smoke},
        "policies": policies,
        "summary": summary,
        # trace of the final fair_share rep + per-query critical paths
        "observability": write_bench_artifacts(
            out_path, apps=[j["app"] for j in jobs]),
    }
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")

    for policy in ("fifo", "fair_share"):
        p = policies[policy]
        rows.append((f"sharing/{policy}/hi_p99", p["hi_p99_s"] * 1e6,
                     round(p["hi_p50_s"], 4)))
        rows.append((f"sharing/{policy}/makespan", p["makespan_s"] * 1e6,
                     round(p["lo_p99_s"], 4)))
    rows.append(("sharing/hi_p99_speedup", 0.0,
                 round(summary["hi_p99_speedup"], 3)))
    rows.append(("sharing/makespan_ratio", 0.0, round(makespan_ratio, 3)))
    if own:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {out_path}: hi p99 fifo {fifo['hi_p99_s']:.2f}s vs "
          f"fair {fair['hi_p99_s']:.2f}s "
          f"({summary['hi_p99_speedup']:.2f}x); makespan ratio "
          f"{makespan_ratio:.2f}", file=sys.stderr)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tables, 1 rep (CI: exercises the scheduler "
                         "paths, no perf claim)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="output JSON (default: BENCH_sharing.json, or "
                         "BENCH_sharing_smoke.json under --smoke)")
    args = ap.parse_args()
    main(smoke=args.smoke,
         reps=args.reps if args.reps is not None else (1 if args.smoke else 5),
         out_path=args.out)
