"""Skew-adaptive exchange benchmark: the eighth decision node's win.

Two phases, one ``BENCH_skew.json`` (repo root):

1. **Zipf sweep.** The query at key-skew s in {0, 1.1, 1.5}, two arms per
   point on identical tables and runtime config: *unmitigated* (the skew
   node forced ``none`` — the pipelined plan as it was before the node
   existed) vs *auto* (the node binds on the observed shuffle histogram
   and picks none / salted / broadcast itself). The store emulates a
   disaggregated fabric (every byte a function reads or writes crosses
   the NIC at ``NET_BW``), so a heavy bucket's serialized read is what
   skew actually costs. Full runs assert: at s=1.5 the mitigated plan
   sustains >= 2x the unmitigated end-to-end rows/s, and at s=0 the node
   binds ``none`` within 5% of the baseline wall (same physical plan —
   the node's overhead is one histogram fold).
2. **Decision parity.** The same skewed workload planned through one
   workflow on both planes: the eight-node sequences — including the
   skew node's func/salt/heavy/hot extras — must be identical, because
   the simulator recomputes the exact histogram the runtime observes.

    PYTHONPATH=src python benchmarks/bench_skew.py [--smoke] [--reps N]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

ZIPFS = (0.0, 1.1, 1.5)
FACT_ROWS, DIM_ROWS, FACT_NODES = 1 << 19, 1 << 10, 32
SMOKE_FACT_ROWS, SMOKE_DIM_ROWS, SMOKE_FACT_NODES = 1 << 13, 1 << 9, 4
FANOUT = 8                     # pinned join fan-out (tables are synthetic)
NET_BW = 1e6                   # bytes/s per flow on the emulated fabric
SMOKE_NET_BW = 20e6
MAX_WORKERS = 32
OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_skew.json"
SMOKE_OUT_PATH = OUT_PATH.with_name("BENCH_skew_smoke.json")


def _pin_xla_single_thread() -> None:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false"
                               " intra_op_parallelism_threads=1").strip()


def _strategy():
    """``static_merge`` with the fan-out pinned to ``FANOUT``: the synthetic
    tables are small enough that the join decision's own scale rule would
    pick 1, which leaves a single bucket and nothing for skew to split."""
    from repro.analytics import QueryStrategy
    from repro.core.decisions import Decision

    class FanoutStrategy(QueryStrategy):
        def join_method(self, ctx):
            d = super().join_method(ctx)
            return Decision(d.func, FANOUT, d.schedule, extras=d.extras)

    return FanoutStrategy("static_merge")


def _run_arm(tables, fact_nodes: int, net_bw: float, force: str | None,
             reps: int):
    """One sweep arm: min-of-reps wall (plus one untimed warm-up rep so
    kernel compiles never land in a timed run) on a fresh runtime per rep.
    Returns ``(wall_s, skew_decision)``."""
    import numpy as np

    from repro.analytics import execute_query_runtime
    from repro.analytics.planner import build_query_workflow
    from repro.core.controllers import GlobalController

    from repro.runtime import Runtime

    fd, dd, ref = tables
    walls, last = [], None
    for rep in range(reps + 1):
        gc = GlobalController({n: 8 for n in range(fact_nodes)})
        rt = Runtime(gc, invoker="threads", net_bw=net_bw,
                     disaggregated=True, max_workers=MAX_WORKERS)
        wf = build_query_workflow(_strategy(), skew_force=force)
        try:
            t0 = time.perf_counter()
            got, _ = execute_query_runtime(fd, dd, _strategy(), runtime=rt,
                                           workflow=wf, pipeline=True)
            wall = time.perf_counter() - t0
            np.testing.assert_allclose(got, ref, atol=1e-3)
        finally:
            rt.store.close()
        if rep:                 # rep 0 is the compile warm-up
            walls.append(wall)
        last = wf.last_run.decisions["skew"]
    return min(walls), last


def _run_sweep(fact_rows: int, dim_rows: int, fact_nodes: int,
               net_bw: float, reps: int):
    from repro.analytics import synth_query_tables

    sweep = {}
    for s in ZIPFS:
        tables = synth_query_tables(fact_rows, dim_rows, seed=3, zipf=s,
                                    fact_nodes=fact_nodes)
        base_s, _ = _run_arm(tables, fact_nodes, net_bw, "none", reps)
        auto_s, skew_d = _run_arm(tables, fact_nodes, net_bw, None, reps)
        sweep[s] = {
            "unmitigated_s": base_s, "auto_s": auto_s,
            "unmitigated_rows_per_s": fact_rows / base_s,
            "auto_rows_per_s": fact_rows / auto_s,
            "speedup": base_s / auto_s,
            "decision": {"func": skew_d.func,
                         "salt": int(skew_d.extra("salt", 0)),
                         "hot_keys": [int(k) for k in
                                      skew_d.extra("hot_keys", ())],
                         "heavy_buckets": len(skew_d.extra("heavy", ())),
                         "ratio": round(float(skew_d.extra("ratio", 0.0)),
                                        3)},
        }
        print(f"# zipf={s}: unmitigated {base_s:.3f}s, auto[{skew_d.func}]"
              f" {auto_s:.3f}s ({base_s / auto_s:.2f}x)", file=sys.stderr)
    return sweep


def _run_parity(fact_rows: int, dim_rows: int):
    """Phase 2: eight-node decision parity, skew extras included, on the
    skewed workload (net emulation off — parity is about the control
    plane, not the clock)."""
    import numpy as np

    from repro.analytics import execute_query_runtime, synth_query_tables
    from repro.analytics.planner import (build_query_workflow,
                                         plan_query_with_workflow)
    from repro.analytics.simulator import ClusterSim
    from repro.core.controllers import GlobalController, PrivateController
    from repro.runtime import Runtime

    def view(run):
        return [(s, d.func, int(d.scale),
                 tuple(d.extra("heavy", ())), int(d.extra("salt", 0)),
                 tuple(d.extra("hot_keys", ())))
                for s, d in run.sequence]

    fd, dd, ref = synth_query_tables(fact_rows, dim_rows, seed=3, zipf=1.5,
                                     fact_nodes=4)
    wf = build_query_workflow(_strategy())
    rt = Runtime(GlobalController({n: 8 for n in range(4)}),
                 invoker="threads")
    try:
        got, _ = execute_query_runtime(fd, dd, _strategy(), runtime=rt,
                                       workflow=wf, pipeline=True)
        np.testing.assert_allclose(got, ref, atol=1e-3)
        seq_rt = view(wf.last_run)
    finally:
        rt.store.close()

    gc_sim = GlobalController({n: 8 for n in range(4)})
    sim = ClusterSim(gc_sim)
    pc = PrivateController("query", gc_sim, priority=10)
    plan_query_with_workflow(sim, pc, fd, dd, _strategy(), workflow=wf)
    sim.run()
    return seq_rt, view(wf.last_run)


def main(rows: list | None = None, smoke: bool = False, reps: int = 2,
         out_path: Path | str | None = None) -> dict:
    from repro.obs import write_bench_artifacts

    rows = [] if rows is None else rows
    if out_path is None:
        # smoke runs must not clobber the committed full-run artifact
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    fact_rows = SMOKE_FACT_ROWS if smoke else FACT_ROWS
    dim_rows = SMOKE_DIM_ROWS if smoke else DIM_ROWS
    fact_nodes = SMOKE_FACT_NODES if smoke else FACT_NODES
    net_bw = SMOKE_NET_BW if smoke else NET_BW

    # -- phase 1: zipf sweep, unmitigated vs auto --------------------------
    sweep = _run_sweep(fact_rows, dim_rows, fact_nodes, net_bw, reps)
    hot = sweep[1.5]
    assert hot["decision"]["func"] in ("salted", "broadcast"), hot
    assert sweep[0.0]["decision"]["func"] == "none", sweep[0.0]
    if not smoke:      # tiny smoke runs are dominated by fixed overheads
        # the tentpole claim: mitigation at least doubles end-to-end
        # throughput on the heavy-tailed workload ...
        assert hot["speedup"] >= 2.0, hot
        # ... and costs nothing when there is no skew to mitigate (the
        # uniform point binds "none": both arms run the identical plan)
        assert sweep[0.0]["speedup"] >= 0.95, sweep[0.0]
    rows.append(("skew/unmitigated_zipf1.5", sweep[1.5]["unmitigated_s"]
                 * 1e6, round(sweep[1.5]["unmitigated_rows_per_s"], 1)))
    rows.append(("skew/auto_zipf1.5", sweep[1.5]["auto_s"] * 1e6,
                 round(hot["speedup"], 3)))
    rows.append(("skew/auto_uniform", sweep[0.0]["auto_s"] * 1e6,
                 round(sweep[0.0]["speedup"], 3)))

    # -- phase 2: skew decision parity across planes -----------------------
    seq_rt, seq_sim = _run_parity(fact_rows, dim_rows)
    parity = seq_rt == seq_sim
    assert parity, (seq_rt, seq_sim)
    assert [s for s, *_ in seq_rt] == ["scan", "join", "exchange", "skew",
                                       "aggregate", "pipeline", "elastic",
                                       "tiering"]
    rows.append(("skew/decision_parity", 0.0, int(parity)))

    report = {
        "benchmark": "skew_adaptive_exchange",
        "config": {"fact_rows": fact_rows, "dim_rows": dim_rows,
                   "fact_nodes": fact_nodes, "fanout": FANOUT,
                   "net_bw": net_bw, "reps": reps, "smoke": smoke},
        "sweep": {str(s): v for s, v in sweep.items()},
        "decision_parity": {
            "identical": parity,
            "sequence": [{"node": s, "func": f, "scale": sc,
                          "heavy_buckets": len(h), "salt": salt,
                          "hot_keys": list(hk)}
                         for s, f, sc, h, salt, hk in seq_rt]},
        "observability": write_bench_artifacts(out_path, apps=["query"]),
    }
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path} (zipf1.5 {hot['speedup']:.2f}x via "
          f"{hot['decision']['func']}, uniform "
          f"{sweep[0.0]['speedup']:.2f}x, parity={parity})",
          file=sys.stderr)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small tables, 1 rep (CI)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    _pin_xla_single_thread()
    main(smoke=args.smoke,
         reps=args.reps if args.reps is not None
         else (1 if args.smoke else 2),
         out_path=args.out)
