"""Tiered shuffle-storage benchmark: cold-data scans, spill-vs-recompute
under quota pressure, and cross-plane tiering decision parity.

Three phases, one ``BENCH_tiering.json`` (repo root):

1. **Cold data.** Inputs seeded straight into the emulated object store
   (latency + bandwidth + dollars), then the query runs twice on the same
   runtime: the first touch scans through the object tier (paying its cost
   model, promoting the inputs into memory), the warm re-query reuses the
   promoted inputs in place. Warm must beat first-touch on makespan, and
   the second run bills zero additional storage dollars.
2. **Spill vs evict-and-recompute.** The query with a fault plan that
   loses the partial-aggregate stage at its first read — forcing recovery
   to re-read reclaimed upstream state. The spill arm runs under a store
   quota with a disk backend: the tiering node demotes reclaimed stages,
   so recovery reads the spilled join output back (shallow). The baseline
   arm is the pre-tiering always-evict behavior (eager reclaim drops
   consumed stages outright): the same loss replays the whole producer
   chain — scan, shuffle, join — before the aggregate can retry. Spill
   must win on both re-executed invocations and (full runs) makespan.
3. **Decision parity.** The full query planned through one workflow on
   both planes with quota and cold tiers engaged: the seven-node decision
   sequences — including the tiering node's per-stage spill plan — must
   be identical.

    PYTHONPATH=src python benchmarks/bench_tiering.py [--smoke] [--reps N]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

FACT_ROWS, DIM_ROWS = 1 << 14, 1 << 11
SMOKE_FACT_ROWS, SMOKE_DIM_ROWS = 1 << 12, 1 << 9
OBJ_LATENCY_S = 0.002          # per-request first-byte latency (emulated)
OBJ_BW = 200e6                 # bytes/s per stream (emulated)
OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_tiering.json"
SMOKE_OUT_PATH = OUT_PATH.with_name("BENCH_tiering_smoke.json")


def _pin_xla_single_thread() -> None:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false"
                               " intra_op_parallelism_threads=1").strip()


def _tables(fact_rows: int, dim_rows: int):
    from repro.analytics import synth_query_tables

    return synth_query_tables(fact_rows, dim_rows, seed=5)


def _run_cold_then_warm(tables, reps: int):
    """Phase 1: object-store-seeded inputs, first touch vs warm re-query."""
    import numpy as np

    from repro.analytics import QueryStrategy, execute_query_runtime
    from repro.core.controllers import GlobalController
    from repro.runtime import ObjectStoreBackend, Runtime

    fd, dd, ref = tables
    first_walls, warm_walls = [], []
    cost_first = promotions = 0
    for _ in range(reps):
        gc = GlobalController({n: 8 for n in range(4)})
        rt = Runtime(gc, spill_backends=[
            ObjectStoreBackend(latency_s=OBJ_LATENCY_S, bw=OBJ_BW)])
        try:
            t0 = time.perf_counter()
            got, _ = execute_query_runtime(fd, dd,
                                           QueryStrategy("static_merge"),
                                           runtime=rt, seed_tier="object")
            first_walls.append(time.perf_counter() - t0)
            np.testing.assert_allclose(got, ref, atol=1e-3)
            cost_first = rt.store.storage_cost["query"]
            promotions = len(rt.store.promotions)
            t0 = time.perf_counter()
            got, _ = execute_query_runtime(fd, dd,
                                           QueryStrategy("static_merge"),
                                           runtime=rt, reuse_inputs=True)
            warm_walls.append(time.perf_counter() - t0)
            np.testing.assert_allclose(got, ref, atol=1e-3)
            # the warm run must not touch the object tier again
            assert rt.store.storage_cost["query"] == cost_first
        finally:
            rt.store.close()
    return {"first_touch_s": min(first_walls), "warm_s": min(warm_walls),
            "warm_speedup": min(first_walls) / min(warm_walls),
            "storage_cost_dollars": cost_first,
            "input_promotions": promotions}


def _run_quota(tables, spill: bool, reps: int):
    """Phase 2, one arm. ``spill=True``: store quota + disk backend, the
    tiering node demotes reclaimed stages. ``spill=False``: the pre-tiering
    always-evict behavior — eager reclaim drops consumed stages, recovery
    recomputes them through lineage."""
    import numpy as np

    from repro.analytics import QueryStrategy, execute_query_runtime
    from repro.core.controllers import GlobalController
    from repro.runtime import (DiskBackend, FaultInjector, FaultPlan,
                               Runtime, StageLossFault)

    fd, dd, ref = tables
    quota = None
    if spill:
        # the tightest quota the barrier-less executor admits is the
        # query's own unconstrained peak; it is what engages the tiering
        # decision (no quota -> "keep" -> no spill policy)
        got, rt0 = execute_query_runtime(fd, dd,
                                         QueryStrategy("static_merge"))
        np.testing.assert_allclose(got, ref, atol=1e-3)
        quota = rt0.store.peak_bytes["query"]

    walls, reexec, recovered, demos = [], 0, (), 0
    for _ in range(reps):
        gc = GlobalController({n: 8 for n in range(4)})
        rt = Runtime(gc, spill_backends=[DiskBackend()] if spill else None)
        if quota is not None:
            rt.store.set_quota("query", quota)
        FaultInjector(FaultPlan(losses=[
            StageLossFault("partials", on_read=1)])).install(rt)
        try:
            t0 = time.perf_counter()
            got, _ = execute_query_runtime(fd, dd,
                                           QueryStrategy("static_merge"),
                                           runtime=rt)
            walls.append(time.perf_counter() - t0)
            np.testing.assert_allclose(got, ref, atol=1e-3)
            assert rt.recoveries
            reexec = sum(ev.invocations for ev in rt.recoveries)
            recovered = tuple(s for ev in rt.recoveries
                              for s in ev.recovered)
            demos = len(rt.store.demotions)
        finally:
            rt.store.close()
    return {"makespan_s": min(walls), "reexecuted_invocations": reexec,
            "recovered_stages": list(recovered), "demotions": demos,
            "quota_bytes": quota}


def _run_parity(tables):
    """Phase 3: seven-node decision parity with quota + cold tiers."""
    import numpy as np

    from repro.analytics import QueryStrategy, execute_query_runtime
    from repro.analytics.planner import (build_query_workflow,
                                         plan_query_with_workflow)
    from repro.analytics.simulator import ClusterSim
    from repro.core.controllers import GlobalController, PrivateController
    from repro.runtime import DiskBackend, ObjectStoreBackend, Runtime

    fd, dd, ref = tables
    got, rt0 = execute_query_runtime(fd, dd, QueryStrategy("dynamic"))
    quota = rt0.store.peak_bytes["query"]

    wf = build_query_workflow(QueryStrategy("dynamic"))
    gc_rt = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc_rt, spill_backends=[
        DiskBackend(),
        ObjectStoreBackend(latency_s=0.0, bw=None)])
    rt.store.set_quota("query", quota)
    try:
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("dynamic"),
                                       runtime=rt, workflow=wf)
        np.testing.assert_allclose(got, ref, atol=1e-3)
        spec = rt.store.storage_spec()
        seq_rt = [(s, d.func, d.scale, d.extra("plan", None))
                  for s, d in wf.last_run.sequence]
    finally:
        rt.store.close()

    gc_sim = GlobalController({n: 8 for n in range(4)})
    sim = ClusterSim(gc_sim, storage_spec=spec,
                     store_quotas={"query": quota})
    pc = PrivateController("query", gc_sim, priority=10)
    plan_query_with_workflow(sim, pc, fd, dd, QueryStrategy("dynamic"),
                             workflow=wf)
    sim.run()
    seq_sim = [(s, d.func, d.scale, d.extra("plan", None))
               for s, d in wf.last_run.sequence]
    return seq_rt, seq_sim


def main(rows: list | None = None, smoke: bool = False, reps: int = 3,
         out_path: Path | str | None = None) -> dict:
    from repro.obs import write_bench_artifacts

    rows = [] if rows is None else rows
    if out_path is None:
        # smoke runs must not clobber the committed full-run artifact
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    fact_rows = SMOKE_FACT_ROWS if smoke else FACT_ROWS
    dim_rows = SMOKE_DIM_ROWS if smoke else DIM_ROWS
    tables = _tables(fact_rows, dim_rows)

    # -- phase 1: cold-data first touch vs warm re-query -------------------
    cold = _run_cold_then_warm(tables, reps)
    assert cold["warm_speedup"] > 1.0, cold
    rows.append(("tiering/cold_first_touch", cold["first_touch_s"] * 1e6,
                 round(cold["warm_speedup"], 3)))
    rows.append(("tiering/warm_requery", cold["warm_s"] * 1e6,
                 cold["input_promotions"]))
    print(f"# cold data: first touch {cold['first_touch_s']:.3f}s "
          f"(${cold['storage_cost_dollars']:.2e}), warm re-query "
          f"{cold['warm_s']:.3f}s ({cold['warm_speedup']:.2f}x)",
          file=sys.stderr)

    # -- phase 2: spill vs evict-and-recompute under quota -----------------
    spill = _run_quota(tables, spill=True, reps=reps)
    evict = _run_quota(tables, spill=False, reps=reps)
    assert spill["demotions"], spill
    # the spilled join output is read back, not recomputed: recovery stays
    # shallow, the always-evict arm replays the whole producer chain
    assert spill["reexecuted_invocations"] < \
        evict["reexecuted_invocations"], (spill, evict)
    if not smoke:       # tiny smoke runs are dominated by fixed overheads
        assert spill["makespan_s"] < evict["makespan_s"], (spill, evict)
    speedup = evict["makespan_s"] / spill["makespan_s"]
    rows.append(("tiering/quota_spill", spill["makespan_s"] * 1e6,
                 round(speedup, 3)))
    rows.append(("tiering/always_evict", evict["makespan_s"] * 1e6,
                 evict["reexecuted_invocations"]))
    print(f"# recovery: spill {spill['makespan_s']:.3f}s "
          f"({spill['reexecuted_invocations']} re-exec, "
          f"{spill['demotions']} demotions) vs always-evict "
          f"{evict['makespan_s']:.3f}s "
          f"({evict['reexecuted_invocations']} re-exec) -> {speedup:.2f}x",
          file=sys.stderr)

    # -- phase 3: tiering decision parity across planes --------------------
    seq_rt, seq_sim = _run_parity(tables)
    parity = seq_rt == seq_sim
    assert parity, (seq_rt, seq_sim)
    assert [s for s, *_ in seq_rt] == ["scan", "join", "exchange",
                                      "skew", "aggregate", "pipeline",
                                      "elastic", "tiering"]

    report = {
        "benchmark": "tiered_shuffle_storage",
        "config": {"fact_rows": fact_rows, "dim_rows": dim_rows,
                   "reps": reps, "smoke": smoke,
                   "object_latency_s": OBJ_LATENCY_S, "object_bw": OBJ_BW},
        "cold_data": cold,
        "quota_pressure": {"spill": spill, "evict_and_recompute": evict,
                           "spill_makespan_speedup": round(speedup, 3)},
        "decision_parity": {
            "identical": parity,
            "sequence": [{"node": s, "func": f, "scale": int(sc),
                          "plan": list(map(list, p)) if p else p}
                         for s, f, sc, p in seq_rt]},
        "observability": write_bench_artifacts(out_path, apps=["query"]),
    }
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path} (warm {cold['warm_speedup']:.2f}x, "
          f"spill {speedup:.2f}x, parity={parity})", file=sys.stderr)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small tables, 1 rep (CI)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    _pin_xla_single_thread()
    main(smoke=args.smoke,
         reps=args.reps if args.reps is not None else (1 if args.smoke else 3),
         out_path=args.out)
