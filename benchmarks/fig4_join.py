"""Paper Fig. 4 — Join strategies under varying table size, cluster size,
and data skew.

(a,b) completion time + normalized cost: A = 400 MB vs B in 10..100 MB on a
      12-node cluster;
(c,d) the same at B = 80 MB across cluster sizes 4..20;
(e)   round-robin vs packing scheduling under uniform vs Pareto data.

Compute rates are calibrated from the real JAX operators; network is the
modeled 1.25 GB/s/NIC of c5.2xlarge. Prints ``name,us_per_call,derived`` CSV
rows (us_per_call = simulated completion in microseconds; derived =
normalized cost in slot-seconds).
"""

from __future__ import annotations

from repro.analytics import QueryStrategy, make_cluster, plan_query_tasks
from repro.analytics.decisions import ALPHA, scheduling_decision
from repro.analytics.simulator import SimTask, calibrated_rates
from repro.analytics.table import phantom
from repro.core.controllers import PrivateController
from repro.core.decisions import DataDist, DecisionContext

MB = 1 << 20


def run_join(nodes: int, a_mb: int, b_mb: int, method: str) -> tuple[float,
                                                                     float]:
    gc, sim = make_cluster(nodes)
    pc = PrivateController("query", gc, priority=10)
    fact = phantom("A", a_mb * MB, range(nodes))
    dim = phantom("B", b_mb * MB, range(min(2, nodes)))
    strat = QueryStrategy(
        "static_merge" if method == "merge" else "static_hash")
    plan_query_tasks(sim, pc, fact, dim, strat)
    out = sim.run()
    return out["completion"]["query"], out["cost_slot_seconds"]["query"]


def fig4_ab(rows: list):
    """Completion/cost vs small-table size (A=400MB, 12 nodes)."""
    for b_mb in (10, 20, 30, 50, 80, 100):
        for method in ("hash", "merge"):
            t, c = run_join(12, 400, b_mb, method)
            rows.append((f"fig4ab/{method}_join/B={b_mb}MB", t * 1e6, c))


def fig4_cd(rows: list):
    """Completion/cost vs cluster size (A=400MB, B=80MB)."""
    for nodes in (4, 8, 12, 16, 20):
        for method in ("hash", "merge"):
            t, c = run_join(nodes, 400, 80, method)
            rows.append((f"fig4cd/{method}_join/nodes={nodes}", t * 1e6, c))


def run_sched(policy: str, distribution: str, nodes: int = 8,
              total_mb: int = 800) -> float:
    """Fig. 4(e): process a distributed table under a scheduling policy."""
    gc, sim = make_cluster(nodes)
    rates = calibrated_rates()
    table = phantom("A", total_mb * MB, range(nodes),
                    distribution=distribution, seed=3)
    dist = table.data_dist()
    if policy == "decision":  # the scheduling decision node picks
        ctx = DecisionContext(data_dist={"A": dist},
                              node_status=gc.node_status())
        decision = scheduling_decision(ctx)
        policy_used = decision.schedule.policy
        placement = decision.schedule.place(decision.scale)
    else:
        policy_used = policy
        n_tasks = max(1, dist.size // ALPHA)
        if policy == "packing":
            heavy = sorted(dist.bytes_per_node,
                           key=lambda n: -dist.bytes_per_node[n])
            from repro.core.decisions import Schedule
            placement = Schedule("packing", tuple(heavy),
                                 slots_per_node=8).place(n_tasks)
        else:
            from repro.core.decisions import Schedule
            placement = Schedule("round-robin",
                                 tuple(range(nodes))).place(n_tasks)
    # tasks process equal shares; data lives where the skew put it
    n_tasks = len(placement)
    per = dist.size / n_tasks
    homes = sorted(dist.bytes_per_node, key=lambda n: -dist.bytes_per_node[n])
    # task i's input lives on the node holding that byte range
    acc, ranges = 0, []
    for node in homes:
        ranges.append((acc, acc + dist.bytes_per_node[node], node))
        acc += dist.bytes_per_node[node]
    for i, node in enumerate(placement):
        lo = i * per
        src = next((h for (a, b, h) in ranges if a <= lo < b), homes[0])
        transfers = {src: int(per)} if src != node else {}
        sim.submit(SimTask(f"t{i}", "app", per / rates["scan"], node=node,
                           priority=5, transfers=transfers))
    return sim.run()["completion"]["app"]


def fig4_e(rows: list):
    for distribution in ("uniform", "pareto"):
        for policy in ("round-robin", "packing", "decision"):
            t = run_sched(policy, distribution)
            rows.append((f"fig4e/{policy}/{distribution}", t * 1e6, 0.0))


def main(rows: list | None = None):
    own = rows is None
    rows = [] if own else rows
    fig4_ab(rows)
    fig4_cd(rows)
    fig4_e(rows)
    if own:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.3f}")
    return rows


if __name__ == "__main__":
    main()
