"""Paper Fig. 7 — TPC-DS sub-query completion under S-M / S-H / DYN.

Two MapReduce phases + Join on a 6-node cluster, inputs 2/4/6 GB (90% fact,
5% dim as in the paper's scale ratio). DYN is the cost-model decision node
(with the literal Fig. 6 threshold node reported alongside).
"""

from __future__ import annotations

from repro.analytics import QueryStrategy, make_cluster, plan_query_tasks
from repro.analytics.table import phantom
from repro.core.controllers import PrivateController

GB = 1 << 30
STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")


def run_query(strategy: str, total_gb: float, nodes: int = 6):
    gc, sim = make_cluster(nodes)
    pc = PrivateController("query", gc, priority=10)
    fact = phantom("A", int(total_gb * 0.9 * GB), range(nodes))
    dim = phantom("B", int(total_gb * 0.05 * GB), range(2))
    plan_query_tasks(sim, pc, fact, dim, QueryStrategy(strategy))
    out = sim.run()
    return out["completion"]["query"], out["cost_slot_seconds"]["query"]


def main(rows: list | None = None):
    own = rows is None
    rows = [] if own else rows
    for gb in (2, 4, 6):
        results = {}
        for strat in STRATEGIES:
            t, c = run_query(strat, gb)
            results[strat] = t
            rows.append((f"fig7/{strat}/{gb}GB", t * 1e6, c))
        best_static = min(results["static_merge"], results["static_hash"])
        rows.append((f"fig7/dyn_vs_best_static/{gb}GB",
                     results["dynamic"] * 1e6,
                     results["dynamic"] / best_static))
    if own:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.3f}")
    return rows


if __name__ == "__main__":
    main()
