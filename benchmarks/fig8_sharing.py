"""Paper Fig. 8 — fine-grained resource sharing: the query co-runs with
low-priority, delay-tolerant background function chains (XFaaS-style).

Reports CPU allocation rates with and without background work, and verifies
the query's completion is not hurt (priority arbitration through the real
GlobalController). The query's shuffle phases leave CPU troughs that the
backfill fills — the paper's Fig. 8 effect.
"""

from __future__ import annotations

from repro.analytics import QueryStrategy, make_cluster, plan_query_tasks
from repro.analytics.simulator import SimTask
from repro.analytics.table import phantom
from repro.core.controllers import PrivateController

GB = 1 << 30


def run(with_background: bool, total_gb: float = 6.0, nodes: int = 6,
        bg_chains: int = 40, chain_len: int = 6):
    gc, sim = make_cluster(nodes)
    pc = PrivateController("query", gc, priority=10)
    fact = phantom("A", int(total_gb * 0.9 * GB), range(nodes))
    dim = phantom("B", int(total_gb * 0.05 * GB), range(2))
    plan_query_tasks(sim, pc, fact, dim, QueryStrategy("dynamic"))
    if with_background:
        for c in range(bg_chains):
            prev = None
            for i in range(chain_len):
                name = f"bg/{c}/{i}"
                sim.submit(SimTask(name, "background", 0.2, priority=0,
                                   deps=(prev,) if prev else ()))
                prev = name
    out = sim.run()
    query_t = out["completion"]["query"]
    alloc = out["allocation"].allocation_rate(0.0, query_t)
    return query_t, alloc, out


def main(rows: list | None = None):
    own = rows is None
    rows = [] if own else rows
    solo_t, solo_alloc, _ = run(False)
    shared_t, shared_alloc, _ = run(True)
    rows.append(("fig8/query_solo", solo_t * 1e6, solo_alloc))
    rows.append(("fig8/query_with_background", shared_t * 1e6, shared_alloc))
    rows.append(("fig8/allocation_gain", 0.0, shared_alloc - solo_alloc))
    rows.append(("fig8/query_slowdown", 0.0,
                 shared_t / max(solo_t, 1e-9)))
    if own:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.3f}")
    return rows


if __name__ == "__main__":
    main()
