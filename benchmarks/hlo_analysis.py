"""Re-export: the trip-count-aware HLO analyzer lives in repro.launch."""

from repro.launch.hlo_analysis import (  # noqa: F401
    Costs,
    analyze,
    split_computations,
)
