"""Emit the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run artifacts. Usage:

    PYTHONPATH=src python -m benchmarks.report [--tag hillclimb1]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.roofline import DRYRUN_DIR, load_records, terms


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def dryrun_table(mesh: str, tag: str = "") -> str:
    recs = load_records(mesh, tag)
    lines = [
        "| arch | shape | strategy (attn/moe) | mb | fsdp | peak HBM/dev |"
        " HLO flops/dev | coll bytes/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        pc = r["parallel_config"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {pc['attn_strategy']}/"
            f"{pc['moe_strategy']} | {pc['microbatches']} | {pc['fsdp']} | "
            f"{fmt_bytes(r.get('peak_memory_in_bytes'))} | "
            f"{r['flops_per_device']:.3e} | {r['collective_bytes']:.3e} | "
            f"{r['compile_s']:.0f} |")
    # skipped cells
    suffix = f"-{tag}" if tag else ""
    for path in sorted(DRYRUN_DIR.glob(f"*--{mesh}{suffix}.json")):
        if tag == "" and path.stem.count("--") != 2:
            continue
        rec = json.loads(path.read_text())
        if rec["status"] == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | SKIPPED "
                         f"(full attention @500k, see DESIGN.md) | | | | | | |")
    return "\n".join(lines)


def roofline_table(tag: str = "") -> str:
    recs = load_records("single", tag)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        t = terms(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"**{t['dominant']}** | {t['useful_ratio']:.3f} | "
            f"{t['roofline_frac']:.4f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    if args.section in ("all", "dryrun"):
        print("### Single-pod (16x16, 256 chips)\n")
        print(dryrun_table("single", args.tag))
        print("\n### Multi-pod (2x16x16, 512 chips)\n")
        print(dryrun_table("multi", args.tag))
    if args.section in ("all", "roofline"):
        print("\n### Roofline (single-pod)\n")
        print(roofline_table(args.tag))


if __name__ == "__main__":
    main()
