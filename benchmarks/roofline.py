"""Roofline analysis (§g) — three terms per (arch x shape x mesh) cell,
derived from the dry-run artifacts in experiments/dryrun/.

Terms (seconds, per step, per device — the SPMD program is per-device):

  compute    = HLO_dot_flops_per_device / PEAK_FLOPS
               (trip-count-corrected parse of the optimized HLO)
  memory     = modeled HBM traffic / HBM_BW, with
               traffic_train   = 3*mb*P + 14*P + 6*T
               traffic_prefill = P + 4*T
               traffic_decode  = P + C            (weights + cache, the
                                                   classic decode bound)
               P = exact param bytes/device (from the sharding rules),
               T = XLA temp_size/device (activation working set),
               C = KV/state cache bytes/device
  collective = collective wire bytes per device / ICI_BW
               (all-gather/all-reduce/reduce-scatter/all-to-all/permute
               result bytes, trip-count-corrected)

  MODEL_FLOPS   = 6*N_active*D (train) or 2*N_active*D (prefill/decode)
  ideal_time    = MODEL_FLOPS / (devices * PEAK_FLOPS)
  roofline_frac = ideal_time / max(terms)   <- the score: 1.0 means the
                  step is bound only by useful model FLOPs at peak.
"""

from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = Path("experiments/dryrun")


def attention_model_flops(rec: dict, mode: str) -> float:
    """Useful (causal-half) attention score+PV FLOPs — 6·N·D ignores them,
    which would make long-context ideals dishonest."""
    from repro.configs import get_config
    from repro.core.config import SHAPES, BlockKind

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    l_attn = sum(cfg.block_kind(i) == BlockKind.ATTENTION
                 for i in range(cfg.num_layers))
    if l_attn == 0:
        return 0.0
    hd = cfg.resolved_head_dim
    tokens = shape.tokens_per_step
    ctx = shape.seq_len
    passes = 3 if mode == "train" else 1
    causal = 0.5 if mode != "decode" else 1.0
    # scores + PV, 2 flops/MAC each
    return l_attn * passes * 4 * tokens * ctx * cfg.num_heads * hd * causal


def param_bytes_per_device(rec: dict) -> float:
    """Exact per-device param bytes via the sharding rules (recomputed)."""
    # cached in the record when available
    if "param_bytes_per_device" in rec:
        return rec["param_bytes_per_device"]
    # fall back: params are at most bf16 fully sharded over the mesh and at
    # least sharded over the model axis
    return rec["params"] * 2 / 16


def cache_bytes_per_device(rec: dict) -> float:
    if rec["shape"] not in ("decode_32k", "long_500k"):
        return 0.0
    # argument size includes params + cache; subtract params
    arg = rec.get("argument_size_in_bytes", 0)
    return max(0.0, arg - param_bytes_per_device(rec))


def terms(rec: dict) -> dict:
    mode = ("train" if rec["shape"].startswith("train") else
            "prefill" if rec["shape"].startswith("prefill") else "decode")
    p = param_bytes_per_device(rec)
    t = rec.get("temp_size_in_bytes", 0)
    mb = rec["parallel_config"]["microbatches"]

    compute = rec["flops_per_device"] / PEAK_FLOPS
    if mode == "train":
        traffic = 3 * mb * p + 14 * p + 6 * t
    elif mode == "prefill":
        traffic = p + 4 * t
    else:
        traffic = p + cache_bytes_per_device(rec) + 2 * t
    memory = traffic / HBM_BW
    collective = rec["collective_bytes"] / ICI_BW

    n_active = rec["active_params"]
    d_tokens = rec["tokens_per_step"]
    model_flops = (6 if mode == "train" else 2) * n_active * d_tokens
    model_flops += attention_model_flops(rec, mode)
    # the ideal step is bound by useful FLOPs at peak OR the *unavoidable*
    # HBM traffic (weights/opt once per pass; weights+cache for decode) —
    # otherwise decode cells would be scored against an impossible
    # compute-only ideal.
    if mode == "train":
        min_traffic = 16 * p            # fwd+bwd reads, grad, fp32 opt r/w
    elif mode == "prefill":
        min_traffic = p
    else:
        min_traffic = p + cache_bytes_per_device(rec)
    ideal = max(model_flops / (rec["devices"] * PEAK_FLOPS),
                min_traffic / HBM_BW)

    out = {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "model_flops": model_flops,
        "hlo_flops_total": rec["flops_per_device"] * rec["devices"],
        "ideal_s": ideal,
    }
    out["useful_ratio"] = (model_flops / out["hlo_flops_total"]
                           if out["hlo_flops_total"] else 0.0)
    bound = max(compute, memory, collective)
    out["bound_s"] = bound
    out["dominant"] = max(
        (("compute", compute), ("memory", memory),
         ("collective", collective)), key=lambda kv: kv[1])[0]
    out["roofline_frac"] = ideal / bound if bound else 0.0
    return out


ADVICE = {
    "compute": ("cut non-model FLOPs: causal-block skipping in attention, "
                "remat policy 'dots' instead of full-block recompute, drop "
                "capacity-factor padding"),
    "memory": ("raise arithmetic intensity: larger microbatch, fuse "
               "norm/gate reads, quantize optimizer state / KV cache"),
    "collective": ("reshard: cheaper attention/MoE strategy (KV broadcast "
                   "vs a2a), shard_map the MoE dispatch, compress gradient "
                   "all-reduce, overlap via async collectives"),
}


def load_records(mesh: str = "single", tag: str = "") -> list[dict]:
    suffix = f"-{tag}" if tag else ""
    recs = []
    for path in sorted(DRYRUN_DIR.glob(f"*--{mesh}{suffix}.json")):
        if tag == "" and path.stem.count("--") != 2:
            continue
        rec = json.loads(path.read_text())
        if rec["status"] == "ok":
            recs.append(rec)
    return recs


def main(rows: list | None = None):
    own = rows is None
    rows = [] if own else rows
    table = []
    for tag, label in (("", "roofline_baseline"), ("opt", "roofline_opt")):
        for rec in load_records("single", tag):
            t = terms(rec)
            cell = f"{rec['arch']}/{rec['shape']}"
            rows.append((f"{label}/{cell}", t["bound_s"] * 1e6,
                         round(t["roofline_frac"], 4)))
            table.append((cell, t))
    if own:
        print("cell,compute_s,memory_s,collective_s,dominant,"
              "useful_ratio,roofline_frac")
        for cell, t in table:
            print(f"{cell},{t['compute_s']:.4f},{t['memory_s']:.4f},"
                  f"{t['collective_s']:.4f},{t['dominant']},"
                  f"{t['useful_ratio']:.3f},{t['roofline_frac']:.4f}")
    return rows


if __name__ == "__main__":
    main()
