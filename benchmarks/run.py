"""Benchmark aggregator — one section per paper table/figure plus the
roofline report. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run \
        [--only fig4|fig7|fig8|roofline|executor|sharing|faults|dataplane|
               elastic|tiering]
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import (
    bench_dataplane,
    bench_elastic,
    bench_executor,
    bench_faults,
    bench_sharing,
    bench_skew,
    bench_tiering,
    fig4_join,
    fig7_query,
    fig8_sharing,
    roofline,
)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["fig4", "fig7", "fig8", "roofline", "executor",
                             "sharing", "faults", "dataplane", "elastic",
                             "tiering", "skew"])
    args = ap.parse_args(argv)

    sections = {
        "fig4": fig4_join.main,
        "fig7": fig7_query.main,
        "fig8": fig8_sharing.main,
        "roofline": roofline.main,
        "executor": bench_executor.main,
        "sharing": bench_sharing.main,
        "faults": bench_faults.main,
        "dataplane": bench_dataplane.main,
        "elastic": bench_elastic.main,
        "tiering": bench_tiering.main,
        "skew": bench_skew.main,
    }
    if args.only:
        sections = {args.only: sections[args.only]}

    rows: list = []
    for name, fn in sections.items():
        try:
            fn(rows)
        except Exception as e:  # noqa: BLE001 - report and continue
            rows.append((f"{name}/ERROR:{type(e).__name__}", 0.0, 0.0))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
