"""The paper's case study end-to-end: a TPC-DS-like sub-query executed on
the real JAX operator data plane AND planned/simulated on a 6-node cluster
under all four strategies.

    PYTHONPATH=src python examples/analytics_query.py
"""

import jax.numpy as jnp
import numpy as np

from repro.analytics import (
    QueryStrategy,
    Table,
    execute_query_jax,
    make_cluster,
    plan_query_tasks,
    reference_query_numpy,
    synth_table,
)
from repro.analytics.table import phantom
from repro.core.controllers import PrivateController


def main():
    # -- real data plane -------------------------------------------------------
    fact = synth_table("fact", 1 << 14, 1 << 12, seed=1)
    dim_cols = synth_table("dim", 1 << 10, 1 << 12, seed=2, unique_keys=True)
    dim = Table({**dim_cols.columns,
                 "cat": jnp.arange(1 << 10, dtype=jnp.int32) % 64})
    ref = reference_query_numpy(fact, dim)
    for method in ("hash", "merge"):
        got = np.asarray(execute_query_jax(fact, dim, method=method))
        err = np.abs(got - ref).max()
        print(f"[data plane] {method}_join groupby-sum max err vs numpy "
              f"oracle: {err:.2e}")

    # -- control plane: strategies on a 6-node cluster, 4 GB input ------------
    print(f"\n{'strategy':14s} {'completion':>11s} {'cost(slot-s)':>13s}")
    for strat in ("static_merge", "static_hash", "dynamic", "dynamic_fig6"):
        gc, sim = make_cluster(6)
        pc = PrivateController("query", gc, priority=10)
        f = phantom("A", int(3.6 * 2 ** 30), range(6))
        d = phantom("B", int(0.2 * 2 ** 30), range(2))
        plan_query_tasks(sim, pc, f, d, QueryStrategy(strat))
        out = sim.run()
        print(f"{strat:14s} {out['completion']['query']:10.2f}s "
              f"{out['cost_slot_seconds']['query']:13.1f}")


if __name__ == "__main__":
    main()
