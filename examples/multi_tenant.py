"""Fine-grained resource sharing (paper Fig. 8) through the real control
plane: a high-priority analytics query co-runs with low-priority background
function chains; the GlobalController arbitrates by priority, background
work backfills the shuffle troughs.

    PYTHONPATH=src python examples/multi_tenant.py
"""

from repro.analytics import QueryStrategy, make_cluster, plan_query_tasks
from repro.analytics.simulator import SimTask
from repro.analytics.table import phantom
from repro.core.controllers import PrivateController

GB = 1 << 30


def run(background: bool):
    gc, sim = make_cluster(6)
    query = PrivateController("query", gc, priority=10)
    fact = phantom("A", int(5.4 * GB), range(6))
    dim = phantom("B", int(0.3 * GB), range(2))
    plan_query_tasks(sim, query, fact, dim, QueryStrategy("dynamic"))
    if background:
        for c in range(40):
            prev = None
            for i in range(6):
                name = f"bg/{c}/{i}"
                sim.submit(SimTask(name, "background", 0.2, priority=0,
                                   deps=(prev,) if prev else ()))
                prev = name
    out = sim.run()
    t_query = out["completion"]["query"]
    return t_query, out["allocation"].allocation_rate(0, t_query), gc


def main():
    t_solo, alloc_solo, _ = run(False)
    t_shared, alloc_shared, gc = run(True)
    print(f"query solo:            {t_solo:6.2f}s  allocation "
          f"{alloc_solo:5.1%}")
    print(f"query + background:    {t_shared:6.2f}s  allocation "
          f"{alloc_shared:5.1%}")
    print(f"allocation gain: +{(alloc_shared - alloc_solo):.1%}  "
          f"query slowdown: {t_shared / t_solo:.2f}x")
    print(f"priority preemptions recorded by the controller: "
          f"{len(gc.preemptions)}")
    assert t_shared <= t_solo * 1.25, "background must not hurt the query"


if __name__ == "__main__":
    main()
