"""Fine-grained resource sharing (paper Fig. 8) through the real control
plane: a high-priority analytics query co-runs with low-priority background
function chains; the GlobalController arbitrates by priority, background
work backfills the shuffle troughs.

Part 2 runs two *real* queries concurrently on one serverless runtime: both
tenants share the function slots, the shuffle store, and the global
controller — slot claims from the two apps interleave through the same
Omega-style commit path the simulator models.

    PYTHONPATH=src python examples/multi_tenant.py
"""

import threading

import jax.numpy as jnp
import numpy as np

from repro.analytics import (
    QueryStrategy,
    Table,
    execute_query_runtime,
    make_cluster,
    plan_query_tasks,
    reference_query_numpy,
    synth_table,
)
from repro.analytics.simulator import SimTask
from repro.analytics.table import distribute, phantom
from repro.core.controllers import GlobalController, PrivateController
from repro.runtime import Runtime

GB = 1 << 30


def run(background: bool):
    gc, sim = make_cluster(6)
    query = PrivateController("query", gc, priority=10)
    fact = phantom("A", int(5.4 * GB), range(6))
    dim = phantom("B", int(0.3 * GB), range(2))
    plan_query_tasks(sim, query, fact, dim, QueryStrategy("dynamic"))
    if background:
        for c in range(40):
            prev = None
            for i in range(6):
                name = f"bg/{c}/{i}"
                sim.submit(SimTask(name, "background", 0.2, priority=0,
                                   deps=(prev,) if prev else ()))
                prev = name
    out = sim.run()
    t_query = out["completion"]["query"]
    return t_query, out["allocation"].allocation_rate(0, t_query), gc


def run_two_queries_one_runtime():
    """Two tenants, one substrate: concurrent real execution."""
    gc = GlobalController({n: 4 for n in range(4)})
    runtime = Runtime(gc, invoker="threads", max_workers=8)

    def make_query(seed):
        fact = synth_table("fact", 1 << 13, 1 << 11, seed=seed)
        dimc = synth_table("dim", 1 << 8, 1 << 11, seed=seed + 1,
                           unique_keys=True)
        dim = Table({**dimc.columns,
                     "cat": jnp.arange(1 << 8, dtype=jnp.int32) % 64})
        return (distribute(fact, range(4), "A"), distribute(dim, range(2), "B"),
                reference_query_numpy(fact, dim))

    tenants = {"etl_hi": (10, "dynamic", make_query(11)),
               "adhoc_lo": (0, "static_hash", make_query(23))}
    results, errors = {}, []

    def worker(app, priority, strat, fd, dd):
        try:
            got, _ = execute_query_runtime(
                fd, dd, QueryStrategy(strat), runtime=runtime, app=app,
                priority=priority)
            results[app] = got
        except Exception as e:  # noqa: BLE001
            errors.append((app, e))

    threads = [threading.Thread(target=worker, args=(app, prio, strat, fd, dd))
               for app, (prio, strat, (fd, dd, _)) in tenants.items()]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors

    print("\ntwo concurrent queries on one runtime "
          "(shared slots, store, controller):")
    for app, (prio, strat, (_, _, ref)) in tenants.items():
        err = np.abs(results[app] - ref).max()
        print(f"  {app:9s} prio {prio:2d} [{strat:12s}] "
              f"max err vs oracle {err:.2e}")
        assert err < 1e-3, app
    print(runtime.metrics.format_table("etl_hi"))
    preempted = sum(r.status == "preempted" for r in runtime.metrics.records)
    print(f"  shuffle store cross-node bytes: "
          f"{runtime.store.cross_node_bytes}; preempted invocations "
          f"retried: {preempted}")


def main():
    t_solo, alloc_solo, _ = run(False)
    t_shared, alloc_shared, gc = run(True)
    print(f"query solo:            {t_solo:6.2f}s  allocation "
          f"{alloc_solo:5.1%}")
    print(f"query + background:    {t_shared:6.2f}s  allocation "
          f"{alloc_shared:5.1%}")
    print(f"allocation gain: +{(alloc_shared - alloc_solo):.1%}  "
          f"query slowdown: {t_shared / t_solo:.2f}x")
    print(f"priority preemptions recorded by the controller: "
          f"{len(gc.preemptions)}")
    assert t_shared <= t_solo * 1.25, "background must not hurt the query"
    run_two_queries_one_runtime()


if __name__ == "__main__":
    main()
