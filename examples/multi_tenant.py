"""Fine-grained resource sharing (paper Fig. 8) through the real control
plane: a high-priority analytics query co-runs with low-priority background
function chains; the GlobalController arbitrates by priority, background
work backfills the shuffle troughs.

Part 2 runs two *real* queries concurrently on one serverless runtime: both
tenants share the function slots, the shuffle store, and the global
controller — slot claims from the two apps interleave through the same
Omega-style commit path the simulator models.

Part 3 drives a six-query mixed workload through the ``QueryScheduler``:
FIFO head-of-line blocking vs weighted fair-share slot rationing, with a
store quota capping one tenant's live shuffle footprint.

    PYTHONPATH=src python examples/multi_tenant.py
"""

import threading

import numpy as np

from repro.analytics import (
    QueryStrategy,
    execute_query_runtime,
    make_cluster,
    plan_query_tasks,
    synth_query_tables,
)
from repro.analytics.simulator import SimTask
from repro.analytics.table import phantom
from repro.core.controllers import GlobalController, PrivateController
from repro.runtime import QueryJob, QueryScheduler, Runtime

GB = 1 << 30


def run(background: bool):
    gc, sim = make_cluster(6)
    query = PrivateController("query", gc, priority=10)
    fact = phantom("A", int(5.4 * GB), range(6))
    dim = phantom("B", int(0.3 * GB), range(2))
    plan_query_tasks(sim, query, fact, dim, QueryStrategy("dynamic"))
    if background:
        for c in range(40):
            prev = None
            for i in range(6):
                name = f"bg/{c}/{i}"
                sim.submit(SimTask(name, "background", 0.2, priority=0,
                                   deps=(prev,) if prev else ()))
                prev = name
    out = sim.run()
    t_query = out["completion"]["query"]
    return t_query, out["allocation"].allocation_rate(0, t_query), gc


def run_two_queries_one_runtime():
    """Two tenants, one substrate: concurrent real execution."""
    gc = GlobalController({n: 4 for n in range(4)})
    runtime = Runtime(gc, invoker="threads", max_workers=8)

    def make_query(seed):
        return synth_query_tables(1 << 13, 1 << 8, keyspace=1 << 11,
                                  seed=seed)

    tenants = {"etl_hi": (10, "dynamic", make_query(11)),
               "adhoc_lo": (0, "static_hash", make_query(23))}
    results, errors = {}, []

    def worker(app, priority, strat, fd, dd):
        try:
            got, _ = execute_query_runtime(
                fd, dd, QueryStrategy(strat), runtime=runtime, app=app,
                priority=priority)
            results[app] = got
        except Exception as e:  # noqa: BLE001
            errors.append((app, e))

    threads = [threading.Thread(target=worker, args=(app, prio, strat, fd, dd))
               for app, (prio, strat, (fd, dd, _)) in tenants.items()]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors

    print("\ntwo concurrent queries on one runtime "
          "(shared slots, store, controller):")
    for app, (prio, strat, (_, _, ref)) in tenants.items():
        err = np.abs(results[app] - ref).max()
        print(f"  {app:9s} prio {prio:2d} [{strat:12s}] "
              f"max err vs oracle {err:.2e}")
        assert err < 1e-3, app
    print(runtime.metrics.format_table("etl_hi"))
    preempted = sum(r.status == "preempted" for r in runtime.metrics.records)
    print(f"  shuffle store cross-node bytes: "
          f"{runtime.store.cross_node_bytes}; preempted invocations "
          f"retried: {preempted}")


def run_scheduled_mix():
    """Part 3: a mixed workload under FIFO vs weighted fair-share."""
    queries = [synth_query_tables(1 << 15, 1 << 9, keyspace=1 << 12,
                                  seed=100 + 7 * i)
               for i in range(6)]
    # warm the kernels once so the policy comparison measures scheduling,
    # not which policy happened to pay XLA compilation first
    for i, (fd, dd, _) in enumerate(queries):
        execute_query_runtime(
            fd, dd,
            QueryStrategy(["static_hash", "dynamic", "static_merge"][i % 3]),
            gc=GlobalController({n: 4 for n in range(4)}), app=f"warm{i}")
    print("\nsix-query mix through the QueryScheduler "
          "(lo,hi alternating arrivals):")
    for policy in ("fifo", "fair_share"):
        from repro.obs import get_tracer
        get_tracer().clear()      # trace exactly this policy's mix
        # 2 slots/node + disaggregated store (5 MB/s): function slots are
        # the contended resource, which is what the policies ration
        gc = GlobalController({n: 2 for n in range(4)})
        runtime = Runtime(gc, invoker="threads", max_workers=8,
                          net_bw=5e6, disaggregated=True)
        sched = QueryScheduler(runtime, policy=policy)
        for i, (fd, dd, _) in enumerate(queries):
            sched.submit(QueryJob(
                f"q{i}", fd, dd,
                ["static_hash", "dynamic", "static_merge"][i % 3],
                priority=10 if i % 2 else 0,
                quota=64 << 20 if i == 0 else None))
        results = sched.run()
        for i, (_, _, ref) in enumerate(queries):
            res = results[f"q{i}"]
            assert res.ok, res.error
            assert np.abs(res.sums - ref).max() < 1e-3, f"q{i}"
        hi = sched.latencies(min_priority=10)
        print(f"  {policy:10s} makespan {sched.makespan():6.2f}s  "
              f"hi-prio latency p50 {hi[len(hi) // 2]:5.2f}s  "
              f"worst {hi[-1]:5.2f}s")
        # observability: where did q0's makespan actually go under this
        # policy? (compute vs store transfer vs slot/admission waits)
        from repro.obs import critical_path
        cp = critical_path(get_tracer().spans(), app="q0")
        if cp is not None:
            b = cp.breakdown
            print(f"  {'':10s} q0 critical path: dominant {cp.dominant} "
                  f"(compute {b['compute']:.2f}s store {b['store']:.2f}s "
                  f"slot_wait {b['slot_wait']:.2f}s queue {b['queue']:.2f}s)")


def main():
    t_solo, alloc_solo, _ = run(False)
    t_shared, alloc_shared, gc = run(True)
    print(f"query solo:            {t_solo:6.2f}s  allocation "
          f"{alloc_solo:5.1%}")
    print(f"query + background:    {t_shared:6.2f}s  allocation "
          f"{alloc_shared:5.1%}")
    print(f"allocation gain: +{(alloc_shared - alloc_solo):.1%}  "
          f"query slowdown: {t_shared / t_solo:.2f}x")
    print(f"priority preemptions recorded by the controller: "
          f"{len(gc.preemptions)}")
    assert t_shared <= t_solo * 1.25, "background must not hurt the query"
    run_two_queries_one_runtime()
    run_scheduled_mix()


if __name__ == "__main__":
    main()
