"""Quickstart: the whole stack in one minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. Control plane: a decision workflow resolves strategy/scale/schedule.
2. Training: a few steps of a reduced llama3.2 config.
3. Serving: greedy-decode a few tokens through the batching engine.
4. Analytics: the paper's Fig. 6 join decision on a synthetic cluster.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics.decisions import join_decision
from repro.configs import get_config
from repro.core.config import OptimizerConfig, ShapeConfig
from repro.core.controllers import GlobalController
from repro.core.decisions import DataDist, DecisionContext
from repro.data import SyntheticSource
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_lm
from repro.parallel.strategies import plan_cell
from repro.serving import Request, ServingEngine
from repro.training import init_opt_state, make_train_step


def main():
    cfg = get_config("llama3.2-3b", smoke=True)
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=4,
                        mode="train")
    mesh = make_smoke_mesh()

    # 1. control plane --------------------------------------------------------
    pc = plan_cell(cfg, shape, mesh)
    print(f"[1] decision tuple: func=attn:{pc.attn_strategy} "
          f"scale={pc.microbatches} layout={pc.layout} "
          f"schedule={pc.pod_axis_role}")

    # 2. train a few steps ----------------------------------------------------
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(cfg, shape, OptimizerConfig(lr=1e-3,
                                                               warmup_steps=0),
                                   pc, q_chunk=32, ssm_chunk=16))
    src = SyntheticSource(cfg, shape, seed=0)
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
        state, metrics = step(state, batch)
        print(f"[2] step {i} loss={float(metrics['loss']):.4f}")

    # 3. serve ---------------------------------------------------------------
    engine = ServingEngine(cfg, state["params"], max_batch=2, max_seq=48)
    for i in range(3):
        engine.submit(Request(i, list(np.random.default_rng(i).integers(
            0, cfg.vocab_size, 8)), max_new_tokens=4))
    done = engine.run()
    print(f"[3] served {len(done)} requests; outputs: "
          f"{[r.output for r in done]}")

    # 4. the paper's join decision --------------------------------------------
    gc = GlobalController({n: 8 for n in range(12)})
    ctx = DecisionContext(
        data_dist={"A": DataDist("A", {n: 400 * 2 ** 20 // 12
                                       for n in range(12)}),
                   "B": DataDist("B", {0: 10 * 2 ** 20})},
        node_status=gc.node_status())
    d = join_decision(ctx)
    print(f"[4] Fig.6 decision for 400MB JOIN 10MB on 12 nodes: "
          f"{d.func} x{d.scale} via {d.schedule.policy}")


if __name__ == "__main__":
    main()
