"""The TPC-DS-like sub-query executed for real on the serverless runtime.

One decision workflow per query (scan → join → exchange → aggregate) drives
actual partitioned function invocations through the dependency-driven DAG
executor: the scan decision binds up front, the scans run (concurrently
under the ``threads`` invoker), and when the fact scan lands the planner
folds the observed post-filter distribution back into the workflow context
and late-binds the join/exchange/aggregate decisions — re-planning the
query mid-flight. The invocation trace is then replayed into ``ClusterSim``
so the simulated benchmarks and the real data plane share one plan.

    PYTHONPATH=src python examples/runtime_query.py
"""

import jax.numpy as jnp
import numpy as np

from repro.analytics import (
    QueryStrategy,
    Table,
    build_query_workflow,
    execute_query_runtime,
    make_cluster,
    reference_query_numpy,
    synth_table,
)
from repro.analytics.simulator import calibrated_rates
from repro.analytics.table import distribute


def main():
    rows, dim_rows, keyspace = 1 << 15, 1 << 10, 1 << 12
    fact = synth_table("fact", rows, keyspace, seed=1)
    dimc = synth_table("dim", dim_rows, keyspace, seed=2, unique_keys=True)
    dim = Table({**dimc.columns,
                 "cat": jnp.arange(dim_rows, dtype=jnp.int32) % 64})
    ref = reference_query_numpy(fact, dim)

    fact_dist = distribute(fact, range(6), "A")
    dim_dist = distribute(dim, range(2), "B")

    for strat in ("static_hash", "static_merge", "dynamic"):
        wf = build_query_workflow(QueryStrategy(strat))
        got, runtime = execute_query_runtime(
            fact_dist, dim_dist, QueryStrategy(strat), workflow=wf,
            invoker="threads")
        err = np.abs(got - ref).max()
        print(f"\n=== strategy {strat}: group-sum max err vs numpy oracle "
              f"{err:.2e} ===")
        assert err < 1e-3, strat
        run = wf.last_run
        print("decision sequence (bound in order, join late-bound on the "
              "observed post-filter scan output):")
        for name, d in run.sequence:
            print(f"  {name:10s} -> func={d.func:12s} scale={d.scale:3d} "
                  f"schedule={d.schedule.policy}")
        scanned = run.ctx.data_dist.get("A_scanned")
        print(f"observed post-filter fact side: {scanned.size} bytes over "
              f"{len(scanned.loc)} nodes (raw input {fact_dist.nbytes})")
        print(runtime.metrics.format_table("query"))
        store = runtime.store
        print(f"shuffle store: {store.cross_node_bytes} cross-node bytes, "
              f"{sum(store.written_bytes.values())} written, "
              f"{sum(store.resident_bytes.values())} still resident")

        # one plan, two data planes: replay the trace into the simulator
        gc2, sim = make_cluster(6)
        n = runtime.replay_into(sim, rates=calibrated_rates())
        out = sim.run()
        print(f"trace replay: {n} invocations -> simulated completion "
              f"{out['completion']['query'] * 1e3:.2f} ms")

        # observability: the span DAG's critical path and the audit log's
        # record of every decision binding (diffable vs run.sequence above)
        from repro.obs import critical_path, get_audit_log, get_tracer
        cp = critical_path(get_tracer().spans(), app="query")
        if cp is not None:
            print(cp.format())
        audited = get_audit_log().sequence("query",
                                           nodes=[s for s, _ in run.sequence])
        print(f"audit log: {audited} "
              f"{'==' if audited == [(s, d.func) for s, d in run.sequence] else '!='} "
              f"run.sequence")
        get_tracer().clear()      # fresh trace + audit buffers per strategy
        get_audit_log().clear()


if __name__ == "__main__":
    main()
