"""Serve a small model with batched requests + the adaptive batching
decision node (the paper's §7 ML-inference use case).

    PYTHONPATH=src python examples/serve_lm.py --requests 12
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_lm
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config("qwen1.5-4b", smoke=True)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_seq=64, slo_ms=2000.0)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        engine.submit(Request(i, rng.integers(
            0, cfg.vocab_size, rng.integers(4, 16)).tolist(),
            max_new_tokens=args.max_new))
    done = engine.run(max_steps=2048)
    wall = time.time() - t0
    occ = float(np.mean(engine.metrics["batch_occupancy"]))
    print(f"[serve_lm] {len(done)}/{args.requests} requests, "
          f"{engine.metrics['generated']} tokens in {wall:.1f}s, "
          f"occupancy {occ:.2f}")
    print(f"[serve_lm] sample continuation req0: {done[0].output}")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
