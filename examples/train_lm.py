"""End-to-end training driver: a ~100M-parameter llama-style model trained
for a few hundred steps with checkpoint/restart and an injected node
failure.

    PYTHONPATH=src python examples/train_lm.py --steps 200

On CPU this takes a few minutes; pass --steps 30 for a quick check. The
same driver scales to the production mesh (see repro/launch/train.py).
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.config import OptimizerConfig, ShapeConfig
from repro.ckpt import Supervisor
from repro.data import Prefetcher, SyntheticSource
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_lm
from repro.parallel.sharding import use_rules
from repro.parallel.strategies import make_rules, plan_cell
from repro.training import init_opt_state, make_train_step


def hundred_m_config():
    """~100M params: 12L, d=512, 8H, d_ff=2048, 32k vocab."""
    base = get_config("llama3.2-3b", smoke=True)
    return dataclasses.replace(
        base, name="llama-100m", num_layers=12, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        tie_embeddings=False)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = hundred_m_config()
    print(f"[train_lm] {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    shape = ShapeConfig("train100m", args.seq, args.batch, "train")
    mesh = make_smoke_mesh()
    pc = plan_cell(cfg, shape, mesh)
    rules = make_rules(mesh, cfg, shape, pc)

    with jax.set_mesh(mesh), use_rules(rules):
        params, _ = init_lm(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "opt": init_opt_state(params)}
        step_fn = jax.jit(make_train_step(
            cfg, shape, OptimizerConfig(lr=3e-4, warmup_steps=20), pc,
            total_steps=args.steps, q_chunk=min(256, args.seq),
            ssm_chunk=64))
        src = SyntheticSource(cfg, shape, seed=7)
        prefetch = Prefetcher(src)

        log = {"losses": [], "t": time.time()}

        def wrapped(st, batch):
            st, m = step_fn(st, batch)
            log["losses"].append(float(m["loss"]))
            n = len(log["losses"])
            if n % 20 == 0:
                dt = time.time() - log["t"]
                log["t"] = time.time()
                tput = 20 * shape.tokens_per_step / dt
                print(f"[train_lm] step {n:4d} loss "
                      f"{log['losses'][-1]:7.4f} ({tput:,.0f} tok/s)")
            return st, m

        def batch_fn(_):
            return {k: jnp.asarray(v) for k, v in prefetch.next()[1].items()}

        failures = {"armed": args.inject_failure}

        def fault(step):
            if failures["armed"] and step == args.steps // 2:
                failures["armed"] = False
                print("[train_lm] >>> injecting simulated node failure <<<")
                raise RuntimeError("node lost")

        sup = Supervisor(wrapped, batch_fn, args.ckpt, ckpt_every=25)
        state, final = sup.run(state, args.steps, fault_hook=fault)
        prefetch.close()
        print(f"[train_lm] done at step {final}; restarts={sup.restarts}; "
              f"loss {log['losses'][0]:.4f} -> {log['losses'][-1]:.4f}")
        assert log["losses"][-1] < log["losses"][0], "loss must descend"


if __name__ == "__main__":
    main()
