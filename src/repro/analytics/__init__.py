"""Serverless data-analytics case study (the paper's §3/§6 workload)."""

from repro.analytics.table import (  # noqa: F401
    DistTable,
    Table,
    distribute,
    synth_table,
)
from repro.analytics.decisions import (  # noqa: F401
    join_decision_node,
    scheduling_decision_node,
)
from repro.analytics.planner import (  # noqa: F401
    AdaptiveQueryPlan,
    build_query_workflow,
    estimate_scan_output,
    plan_query_with_workflow,
    stages_for_run,
)
from repro.analytics.simulator import (  # noqa: F401
    ClusterSim,
    SimTask,
    calibrated_rates,
    make_cluster,
    sim_fault_models,
)
from repro.analytics.query import (  # noqa: F401
    QueryStrategy,
    execute_query_jax,
    execute_query_runtime,
    plan_query_tasks,
    plan_runtime_stages,
    prepare_query_plan,
    reference_query_numpy,
    resolve_join_decision,
    split_partitions,
    synth_query_tables,
)
