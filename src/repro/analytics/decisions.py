"""The paper's Fig. 6 Join decision node, verbatim logic.

    input  data_dist, node_status
    output decision tuple (func, scale, schedule)

    sizeA, sizeB = data_dist.A.size, data_dist.B.size
    nodeA, nodeB = data_dist.A.loc, data_dist.B.loc
    if sizeA / sizeB < T1 and |nodeA| > T2:
        func  = "merge_join"
        scale = (sizeA + sizeB) / alpha          # proportional to size
        schedule = ("round-robin", nodeA ∪ nodeB)
    else:
        func  = "hash_join"
        scale = num_of_avail_slots(node_status, nodeA)
        schedule = ("packing", nodeA)

plus the scheduling decision node for Fig. 4(e): round-robin under uniform
data, packing under skew.
"""

from __future__ import annotations

from repro.core.decisions import (
    Decision,
    DecisionContext,
    DecisionNode,
    Schedule,
)

# Thresholds measured from Fig. 4: hash join wins while the small table is
# <~30 MB against a 400 MB probe side (ratio ~13) and on small clusters.
T1 = 13.0            # size ratio below which tables are "comparable"
T2 = 8               # cluster size above which broadcast gets expensive
ALPHA = 32 << 20     # bytes of input per function instance


def join_decision(ctx: DecisionContext) -> Decision:
    dist_a, dist_b = ctx.data_dist["A"], ctx.data_dist["B"]
    size_a, size_b = dist_a.size, dist_b.size
    node_a, node_b = dist_a.loc, dist_b.loc

    if size_a / max(size_b, 1) < T1 and len(node_a) > T2:
        func = "merge_join"
        scale = max(1, int((size_a + size_b) / ALPHA))
        schedule = Schedule("round-robin", tuple(sorted(node_a | node_b)))
    else:
        func = "hash_join"
        scale = max(1, ctx.node_status.free(node_a))
        slots = ctx.node_status.total_slots
        schedule = Schedule("packing", tuple(sorted(node_a)),
                            slots_per_node=max(slots.values()) if slots else 8)
    return Decision(func, scale, schedule)


def join_decision_node() -> DecisionNode:
    return DecisionNode("join", join_decision)


def cost_model_join_decision(ctx: DecisionContext) -> Decision:
    """Refined DYN strategy (paper Fig. 5 step 4: developers fold profiling
    feedback into the decision node): choose the join plan by napkin-math
    over calibrated operator rates + link bandwidth instead of fixed T1/T2.
    """
    rates = ctx.profile.get("rates")
    if rates is None:
        from repro.analytics.simulator import calibrated_rates
        rates = calibrated_rates()
    dist_a, dist_b = ctx.data_dist["A"], ctx.data_dist["B"]
    size_a, size_b = dist_a.size, max(dist_b.size, 1)
    node_a = dist_a.loc or frozenset(ctx.node_status.total_slots)
    status = ctx.node_status
    nodes = sorted(status.total_slots)
    slots = max(status.total_slots.values()) if status.total_slots else 8
    bw = ctx.app.get("net_bw", 1.25e9)
    n_nodes = len(nodes)
    scale = max(1, int((size_a + size_b) / ALPHA))   # paper: ∝ data size
    par = max(1, min(scale, status.free()))          # slot-limited waves

    # merge join: all-to-all shuffle of both tables + sort-merge compute
    shuffle_t = (size_a + size_b) / (n_nodes * bw)
    merge_t = shuffle_t + (size_a + size_b) / par / rates["merge_join"]

    # hash join: broadcast B to every node (senders = B's homes, serialized),
    # one build per node, parallel probe
    homes = max(1, len(dist_b.loc))
    bcast_t = size_b * n_nodes / (homes * bw)
    hash_t = bcast_t + size_b / rates["hash_build"] \
        + size_a / par / rates["hash_probe"]

    # consolidation (the paper's 2 GB case): pull everything to one node,
    # no shuffle, limited to `slots` parallel functions
    pull_t = (size_a + size_b) * (n_nodes - 1) / n_nodes / bw
    consol_t = pull_t + size_a / min(par, slots) / rates["hash_probe"] \
        + size_b / rates["hash_build"]

    best = min(merge_t, hash_t, consol_t)
    if best == consol_t:
        target = max(dist_a.bytes_per_node, key=dist_a.bytes_per_node.get)
        return Decision("hash_join", min(scale, slots),
                        Schedule("packing", (target,), slots_per_node=slots),
                        extras=(("consolidate", True),
                                ("est_seconds", consol_t)))
    if best == merge_t:
        return Decision("merge_join", scale,
                        Schedule("round-robin", tuple(nodes)),
                        extras=(("est_seconds", merge_t),))
    return Decision("hash_join", scale,
                    Schedule("round-robin", tuple(sorted(node_a))),
                    extras=(("est_seconds", hash_t),))


def cost_model_join_node() -> DecisionNode:
    return DecisionNode("join_cost_model", cost_model_join_decision,
                        fallback=join_decision)


def scheduling_decision(ctx: DecisionContext) -> Decision:
    """Fig. 4(e): packing beats round-robin under skewed (Pareto) data."""
    dist = next(iter(ctx.data_dist.values()))
    nodes = tuple(sorted(ctx.node_status.total_slots))
    scale = max(1, int(dist.size / ALPHA))
    slots = max(ctx.node_status.total_slots.values())
    if dist.skew > 1.5:
        # skewed: consolidate onto the data-heavy nodes
        heavy = tuple(sorted(dist.bytes_per_node,
                             key=lambda n: -dist.bytes_per_node[n]))
        return Decision("process", scale,
                        Schedule("packing", heavy, slots_per_node=slots))
    return Decision("process", scale, Schedule("round-robin", nodes))


def scheduling_decision_node() -> DecisionNode:
    return DecisionNode("schedule", scheduling_decision)
