"""Analytics operators in JAX — the data plane of the case study.

Two join implementations with genuinely different execution structure (the
paper's Fig. 3):

  * ``sort_merge_join`` — sort both sides, linear merge via searchsorted
    (the shuffle-heavy plan: records with equal keys must be co-located).
  * ``hash_join``       — build an open-addressing hash table over the
    (smaller) build side, probe with the (larger) probe side (the
    broadcast-heavy plan).

Join contract: the build side has unique keys (fact ⋈ dim); output is one row
per probe row with a ``found`` mask — static shapes, as JAX requires. The
radix ``partition`` shuffle primitive mirrors the Pallas kernel in
``repro/kernels/partition.py`` (kernel validated against this reference).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics.table import Table

HASH_MULT = jnp.uint32(0x9E3779B1)   # Knuth multiplicative hash
EMPTY = jnp.int32(-1)


def _hash(keys: jax.Array, bits: int) -> jax.Array:
    h = keys.astype(jnp.uint32) * HASH_MULT
    return (h >> (32 - bits)).astype(jnp.int32)


# -- partition (shuffle primitive) ---------------------------------------------


@partial(jax.jit, static_argnames=("num_partitions",))
def partition_ids(keys: jax.Array, num_partitions: int) -> jax.Array:
    """Radix/hash partition id per row."""
    bits = max(1, int(np.ceil(np.log2(num_partitions))))
    return _hash(keys, bits) % num_partitions


@partial(jax.jit, static_argnames=("num_partitions",))
def partition_permutation(keys: jax.Array, num_partitions: int):
    """Stable permutation grouping rows by partition + per-partition counts."""
    pids = partition_ids(keys, num_partitions)
    order = jnp.argsort(pids, stable=True)
    counts = jnp.bincount(pids, length=num_partitions)
    return order, counts, pids


# -- joins -----------------------------------------------------------------------


@jax.jit
def sort_merge_join_indices(probe_keys: jax.Array, build_keys: jax.Array):
    """Sort-merge: sort build side, binary-merge probe side.

    Returns (idx_into_build, found) aligned with probe rows.
    """
    build_order = jnp.argsort(build_keys)
    sorted_build = build_keys[build_order]
    pos = jnp.searchsorted(sorted_build, probe_keys)
    pos = jnp.clip(pos, 0, build_keys.shape[0] - 1)
    found = sorted_build[pos] == probe_keys
    idx = jnp.where(found, build_order[pos], 0)
    return idx, found


def _hash_table_size(n: int) -> int:
    # load factor <= 0.25: linear-probing cluster lengths stay far below
    # the probe budget even for multi-million-row build sides
    return max(16, int(2 ** np.ceil(np.log2(4 * n))))


@partial(jax.jit, static_argnames=("max_probes",))
def build_hash_table(build_keys: jax.Array, max_probes: int = 16):
    """Open-addressing (linear probing) insert of unique build keys.

    Parallel insertion: each round, every unplaced key writes its row index
    to its current probe slot; scatter conflicts resolve last-writer-wins,
    losers advance to the next probe position. With load factor <= 0.5 this
    converges in a handful of rounds.
    """
    n = build_keys.shape[0]
    cap = _hash_table_size(n)
    bits = int(np.log2(cap))
    slots = jnp.full((cap,), EMPTY)            # stored row index, -1 = empty
    h0 = _hash(build_keys, bits)
    rows = jnp.arange(n, dtype=jnp.int32)

    def round_(p, carry):
        slots, placed = carry
        pos = (h0 + p) % cap
        # only unplaced keys contending for currently-empty slots
        want = jnp.logical_and(jnp.logical_not(placed), slots[pos] == EMPTY)
        cand = jnp.where(want, rows, EMPTY)
        tgt = jnp.where(want, pos, cap)        # park non-contenders off-table
        slots_ext = jnp.concatenate([slots, jnp.full((1,), EMPTY)])
        slots_ext = slots_ext.at[tgt].max(cand)   # max = deterministic winner
        slots = slots_ext[:cap]
        placed = jnp.logical_or(placed, slots[pos] == rows)
        return slots, placed

    slots, _ = jax.lax.fori_loop(0, max_probes, round_,
                                 (slots, jnp.zeros((n,), bool)))
    return slots


@partial(jax.jit, static_argnames=("max_probes",))
def hash_join_indices(probe_keys: jax.Array, build_keys: jax.Array,
                      slots: jax.Array, max_probes: int = 16):
    """Probe the hash table. Returns (idx_into_build, found) per probe row."""
    cap = slots.shape[0]
    bits = int(np.log2(cap))
    h = _hash(probe_keys, bits)

    def probe(p, carry):
        idx, found = carry
        pos = (h + p) % cap
        cand = slots[pos]
        hit = jnp.logical_and(
            cand != EMPTY,
            jnp.logical_and(build_keys[jnp.maximum(cand, 0)] == probe_keys,
                            jnp.logical_not(found)))
        idx = jnp.where(hit, cand, idx)
        return idx, jnp.logical_or(found, hit)

    idx0 = jnp.zeros_like(probe_keys)
    found0 = jnp.zeros(probe_keys.shape, bool)
    idx, found = jax.lax.fori_loop(0, max_probes, probe, (idx0, found0))
    return idx, found


def join(probe: Table, build: Table, key: str = "key",
         method: str = "hash", suffix: str = "_b") -> Table:
    """Inner-join (probe ⋈ build); returns probe columns + matched build
    columns + 'found' mask column."""
    pk, bk = probe[key], build[key]
    if method == "hash":
        slots = build_hash_table(bk)
        idx, found = hash_join_indices(pk, bk, slots)
    elif method == "merge":
        idx, found = sort_merge_join_indices(pk, bk)
    else:
        raise ValueError(method)
    cols = dict(probe.columns)
    for name, col in build.columns.items():
        if name == key:
            continue
        out_name = name + (suffix if name in cols else "")
        cols[out_name] = jnp.where(
            found if col.ndim == 1 else found[:, None], col[idx], 0)
    cols["found"] = found
    return Table(cols)


# -- aggregation ------------------------------------------------------------------


@partial(jax.jit, static_argnames=("num_groups",))
def groupby_sum(group_ids: jax.Array, values: jax.Array, num_groups: int):
    """segment-sum values by group id."""
    return jax.ops.segment_sum(values, group_ids, num_segments=num_groups)


def filter_table(t: Table, keep: jax.Array) -> Table:
    """Static-shape filter: zero out dropped rows, keep a validity column."""
    cols = {k: jnp.where(keep if v.ndim == 1 else keep[:, None], v, 0)
            for k, v in t.columns.items()}
    cols["valid"] = keep & t.columns.get("valid", jnp.ones_like(keep))
    return Table(cols)
