"""Table-level analytics operators — thin columnar shells over the kernel
dispatch layer (``repro.kernels.ops``).

Two join implementations with genuinely different execution structure (the
paper's Fig. 3):

  * ``sort_merge_join_indices`` — sort both sides, linear merge via
    searchsorted (the shuffle-heavy plan: records with equal keys must be
    co-located).
  * ``hash_join_indices``       — build an open-addressing hash table over
    the (smaller) build side, probe with the (larger) probe side (the
    broadcast-heavy plan).

Join contract: the build side has unique keys (fact ⋈ dim); output is one row
per probe row with a ``found`` mask — static shapes, as JAX requires.

Since the vectorized-data-plane refactor the jitted primitives themselves
(hashing, partition permutation, join index computation, segment sums) live
in ``repro.kernels.ops``, which dispatches each to the Pallas kernel on TPU
or the jitted jnp fallback elsewhere; this module only lifts them to
``Table``s. The names below re-export the primitives so existing callers
and tests keep working.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.analytics.table import Table
from repro.kernels.ops import (  # noqa: F401  (re-exported primitives)
    EMPTY,
    HASH_MULT,
    build_hash_table,
    grouping_indices,
    hash_join_indices,
    partition_ids,
    partition_permutation,
    segment_sum,
    sort_merge_join_indices,
)


def join(probe: Table, build: Table, key: str = "key",
         method: str = "hash", suffix: str = "_b") -> Table:
    """Inner-join (probe ⋈ build); returns probe columns + matched build
    columns + 'found' mask column. The index computation is one kernel
    dispatch per side (build + probe for hash, sort + merge for merge)."""
    pk, bk = probe[key], build[key]
    if method == "hash":
        slots = build_hash_table(bk)
        idx, found = hash_join_indices(pk, bk, slots)
    elif method == "merge":
        idx, found = sort_merge_join_indices(pk, bk)
    else:
        raise ValueError(method)
    cols = dict(probe.columns)
    for name, col in build.columns.items():
        if name == key:
            continue
        out_name = name + (suffix if name in cols else "")
        cols[out_name] = jnp.where(
            found if col.ndim == 1 else found[:, None], col[idx], 0)
    cols["found"] = found
    return Table(cols)


def groupby_sum(group_ids, values, num_groups: int):
    """Segment-sum values by group id (kernel-dispatched)."""
    return segment_sum(values, group_ids, num_groups)


def filter_table(t: Table, keep) -> Table:
    """Static-shape filter: zero out dropped rows, keep a validity column."""
    cols = {k: jnp.where(keep if v.ndim == 1 else keep[:, None], v, 0)
            for k, v in t.columns.items()}
    cols["valid"] = keep & t.columns.get("valid", jnp.ones_like(keep))
    return Table(cols)
