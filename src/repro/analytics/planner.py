"""Workflow-driven adaptive query planner (paper Fig. 5 step 4, Fig. 6).

One ``DecisionWorkflow`` per query carries five per-phase decision nodes —
``scan``, ``join``, ``exchange``, ``aggregate``, ``pipeline`` — and drives
*both* data planes. ``AdaptiveQueryPlan`` is the runtime side: the DAG executor calls it
back as physical stages complete, it folds the observed metrics and the
**post-filter** scan output distribution into the workflow context, binds the
next decisions, and emits the newly materialized stages — a mid-query
re-plan. ``plan_query_with_workflow`` is the simulator side: it walks the
identical workflow, substituting an *estimated* scan output for the measured
one, and submits ``SimTask``s. Because both planners evaluate the same
workflow object, the simulated and real plans come from identical decision
sequences.

The join node is late-bound on the scan stage: it sees ``A_scanned`` (the
post-filter fact distribution) instead of the raw input, so a highly
selective filter observed at runtime can flip the join variant mid-query —
a decision impossible under a plan-everything-up-front planner.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.analytics.decisions import ALPHA
from repro.core.decisions import (
    DataDist,
    Decision,
    DecisionContext,
    DecisionNode,
    DecisionWorkflow,
    Schedule,
    WorkflowRun,
    elasticity_node,
    merge_hot_keys,
    partition_skew,
    skew_node,
    tiering_node,
)

MAX_JOIN_FANOUT = 64      # runtime join bucket-space cap


# ---------------------------------------------------------------------------
# Per-phase decision nodes
# ---------------------------------------------------------------------------


def observed_join_ctx(ctx: DecisionContext) -> DecisionContext:
    """The join node's view: the post-scan distribution (``A_scanned``),
    when observed, replaces the raw fact input as side ``A``."""
    scanned = ctx.data_dist.get("A_scanned")
    if scanned is None:
        return ctx
    return DecisionContext(
        data_dist=dict(ctx.data_dist, A=scanned),
        node_status=ctx.node_status, app=ctx.app, profile=ctx.profile,
        decisions=ctx.decisions)


def scan_decision(ctx: DecisionContext) -> Decision:
    """Scans are data-local: one wave per ~ALPHA bytes over the input homes."""
    dist_f = ctx.data_dist["A"]
    nodes = tuple(sorted(dist_f.loc)) or \
        tuple(sorted(ctx.node_status.total_slots))
    scale = max(1, int(dist_f.size / ALPHA))
    return Decision("scan_filter", scale, Schedule("round-robin", nodes))


def consolidation_applies(strategy_name: str, decision: Decision,
                          total_bytes: int, threshold: int) -> bool:
    """The paper's consolidation policy, shared by the workflow join node
    and the legacy up-front shim: either the decision node itself opted in
    (cost model) or the literal Fig. 6 strategy sees the whole input fit
    one node."""
    return bool(decision.extra("consolidate", False)) or (
        strategy_name == "dynamic_fig6" and total_bytes <= threshold)


def strategy_join_fn(strategy, consolidate_threshold: int = 2 << 30):
    """Wrap a strategy's join choice as a late-bound workflow node fn.

    The wrapped node sees the observed post-filter fact distribution. When
    the paper's consolidation applies (whole input fits one node) the
    decision itself is rewritten to what will actually run — hash join,
    packed onto the data-heaviest node — so the recorded sequence never
    contradicts the materialized plan.
    """

    def fn(ctx: DecisionContext) -> Decision:
        decision = strategy.join_method(observed_join_ctx(ctx))
        dist_f = ctx.data_dist["A"]
        total = dist_f.size + ctx.data_dist["B"].size
        if consolidation_applies(strategy.name, decision, total,
                                 consolidate_threshold) and \
                not decision.extra("consolidate", False):
            slots = ctx.node_status.total_slots
            cap = max(slots.values()) if slots else 8
            target = max(dist_f.bytes_per_node,
                         key=dist_f.bytes_per_node.get) \
                if dist_f.bytes_per_node else 0
            decision = Decision(
                "hash_join", min(join_fanout(decision), cap),
                Schedule("packing", (target,), slots_per_node=cap),
                extras=decision.extras + (("consolidate", True),))
        return decision

    return fn


def join_fanout(join: Decision) -> int:
    return max(1, min(int(join.scale), MAX_JOIN_FANOUT))


def decide_elastic(run: WorkflowRun, fanout: int, pool: int) -> Decision:
    """Plant the elastic node's context contract — the upcoming fan-out and
    the current pool size — and bind it. One helper shared by both planes,
    so the profile keys (and therefore the bound sequences) cannot drift
    between the simulator and the runtime."""
    run.ctx.profile["elastic.fanout"] = int(fanout)
    run.ctx.profile["elastic.pool"] = int(pool)
    return run.decide("elastic")


def decide_skew(run: WorkflowRun, rows_hist, bytes_hist,
                hot_keys) -> Decision:
    """Plant the skew node's context contract — the observed (runtime) or
    exactly recomputed (simulator) shuffle histogram and merged
    heavy-hitter sketch — and bind it. One helper shared by both planes,
    so the profile keys (and therefore the bound sequences) cannot drift
    between the simulator and the runtime."""
    run.ctx.profile["skew.partition_rows"] = tuple(
        int(r) for r in rows_hist)
    run.ctx.profile["skew.partition_bytes"] = tuple(
        int(b) for b in bytes_hist)
    run.ctx.profile["skew.hot_keys"] = tuple(
        (int(k), int(c)) for k, c in hot_keys)
    return run.decide("skew")


def shuffle_skew_feedback(fact, n_join: int, filter_col: str = "v0",
                          filter_gt: float = 0.0) -> tuple:
    """The simulator's stand-in for the runtime's observed shuffle
    feedback: ``(partition_rows, partition_bytes, hot_keys)`` of the
    post-filter fact side, computed with the same kernels
    (``partition_ids`` / ``heavy_hitter_sketch``) over the same partition
    contents the runtime's shuffle writers see. Exact for materialized
    tables (the scan filter is replayed per partition, exactly like
    ``estimate_scan_output``), so both planes bind the identical skew
    decision; ``PhantomTable``s yield empty histograms — the node then
    decides ``none`` on either plane."""
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    parts = getattr(fact, "partitions", None)
    if not parts:
        return ((), (), ())
    n_join = int(n_join)
    rows = np.zeros(n_join, dtype=np.int64)
    nbytes = np.zeros(n_join, dtype=np.int64)
    sketches = []
    for _node, t in sorted(parts.items()):
        if t.num_rows == 0:
            continue
        keys = np.asarray(t["key"])
        if filter_col in t.columns:
            keys = keys[np.asarray(t[filter_col]) > filter_gt]
        if keys.size == 0:
            continue
        row_nb = sum(int(np.prod(v.shape[1:])) * v.dtype.itemsize
                     for v in t.columns.values())
        pids = np.asarray(kops.partition_ids(jnp.asarray(keys, jnp.int32),
                                             n_join))
        hist = np.bincount(pids, minlength=n_join)[:n_join]
        rows += hist
        nbytes += hist * row_nb
        sketches.append(kops.heavy_hitter_sketch(
            jnp.asarray(keys, jnp.int32)))
    return (tuple(int(r) for r in rows), tuple(int(b) for b in nbytes),
            merge_hot_keys(sketches))


# rough per-row bytes of a two-phase partial-aggregate bucket (group key +
# accumulator), used only to *estimate* the partials stage for tiering
PARTIAL_AGG_ROW_BYTES = 16


def ephemeral_stage_profile(scanned: DataDist, dist_b: DataDist,
                            join: Decision, exchange: Decision,
                            num_groups: int,
                            skew: Decision | None = None) -> tuple:
    """``(stage, est_bytes, lineage_depth, downstream_remaining)`` for each
    ephemeral data stage the chosen physical plan will reclaim, in reclaim
    order — the tiering node's sizing input. Every number is derived from
    the bound plan (estimated scan output, dim distribution, join fan-out,
    skew mitigation extras), never measured, so the runtime and the
    simulator price the same stages identically."""
    n_join = join_fanout(join)
    partials = PARTIAL_AGG_ROW_BYTES * int(num_groups) * n_join
    if exchange.func == "shuffle":
        stages = [("fact_buckets", int(scanned.size), 2, 2),
                  ("dim_buckets", int(dist_b.size), 2, 2)]
        # salted sub-joins write straight into extra ``joined`` partitions,
        # so the ``joined`` entry below already covers their output bytes
        if skew is not None and skew.func == "broadcast":
            # replicated hot build side: ~one dim row per heavy-hitter key
            row_b = (int(dist_b.size) // max(1, int(dist_b.rows))) \
                if dist_b.rows else 0
            stages.append(("dim_hot",
                           row_b * len(skew.extra("hot_keys", ())), 2, 1))
        stages += [("joined", int(scanned.size), 3, 1),
                   ("partials", partials, 4, 0)]
        return tuple(stages)
    # broadcast path: the dim broadcast is never reclaimed (no ephemeral
    # input names it), so only the join output and the partials spill
    return (("joined", int(scanned.size), 2, 1),
            ("partials", partials, 3, 0))


def decide_tiering(run: WorkflowRun, stages, quota: int | None,
                   tiers) -> Decision:
    """Plant the tiering node's context contract — the plan's ephemeral
    stages, the app's store quota, and the cold-tier specs — and bind it.
    One helper shared by both planes, so the profile keys (and therefore
    the bound sequences) cannot drift between simulator and runtime."""
    run.ctx.profile["tiering.stages"] = tuple(stages)
    run.ctx.profile["tiering.quota"] = None if quota is None else int(quota)
    run.ctx.profile["tiering.tiers"] = dict(tiers or {})
    return run.decide("tiering")


def exchange_decision(ctx: DecisionContext) -> Decision:
    """The exchange pattern follows the bound join decision: merge join
    hash-shuffles both sides into the join's bucket space, hash join
    broadcasts the (small) dim side from its home nodes."""
    join = ctx.decisions["join"]
    dist_a = ctx.data_dist.get("A_scanned", ctx.data_dist["A"])
    dist_b = ctx.data_dist["B"]
    n_join = join_fanout(join)
    if join.func == "merge_join":
        producers = tuple(sorted(dist_a.loc | dist_b.loc)) or \
            tuple(sorted(ctx.node_status.total_slots))
        return Decision("shuffle", n_join,
                        Schedule("round-robin", producers),
                        extras=(("num_buckets", n_join),))
    homes = tuple(sorted(dist_b.loc)) or \
        tuple(sorted(ctx.node_status.total_slots))
    return Decision("broadcast", max(1, len(homes)),
                    Schedule("round-robin", homes))


def aggregate_decision(ctx: DecisionContext) -> Decision:
    """Two-phase aggregation co-located with the join outputs."""
    join = ctx.decisions["join"]
    return Decision("two_phase", join_fanout(join), join.schedule)


# per-bucket bytes under which the fused partition+probe kernel's build
# side comfortably fits VMEM (one-hot probe over the whole bucket)
FUSED_BUCKET_BYTES = 4 << 20
PREFETCH_DEPTH = 2            # in-flight partition fetches per join side


def pipeline_decision(ctx: DecisionContext) -> Decision:
    """Shuffle→join coupling: stage ``barrier`` vs partition-``pipelined``
    consumption vs the ``fused`` partition+probe kernel.

    A control-plane choice, not a data-plane flag: it binds from the
    *observed* post-scan volume (bucket size = both sides over the join
    fan-out) and the controller's free-slot view. Small buckets take the
    fused single-dispatch kernel (its build side must fit VMEM); otherwise
    free slots make partition-granularity pipelining worthwhile (consumers
    can launch while producers still hold slots); a saturated cluster keeps
    the stage barrier — pipelining would only queue behind producers. The
    ``scale`` is the per-side prefetch depth (double buffering)."""
    join = ctx.decisions["join"]
    dist_a = ctx.data_dist.get("A_scanned", ctx.data_dist["A"])
    dist_b = ctx.data_dist["B"]
    n_join = join_fanout(join)
    bucket = (dist_a.size + dist_b.size) / max(1, n_join)
    if bucket <= FUSED_BUCKET_BYTES:
        return Decision("fused", PREFETCH_DEPTH, join.schedule,
                        extras=(("bucket_bytes", int(bucket)),))
    if ctx.node_status.free() > 0:
        return Decision("pipelined", PREFETCH_DEPTH, join.schedule,
                        extras=(("bucket_bytes", int(bucket)),))
    return Decision("barrier", 1, join.schedule,
                    extras=(("bucket_bytes", int(bucket)),))


def build_query_workflow(strategy, name: str | None = None,
                         consolidate_threshold: int = 2 << 30,
                         elastic_max_workers: int = 16,
                         skew_threshold: float = 2.0,
                         skew_min_rows: int = 4096,
                         skew_force: str | None = None,
                         ) -> DecisionWorkflow:
    """The query's decision workflow (paper Fig. 5): eight per-phase nodes.

    ``join`` is late-bound on the scan stage's feedback; ``exchange``,
    ``aggregate`` and ``pipeline`` follow the join *decision* (their
    physical effect brackets the join stage) but await only the scan
    feedback. ``skew`` is the latest-bound node of all: it awaits the
    *exchange* stage's feedback — the observed per-bucket shuffle
    histogram — and fires between exchange and join, choosing none /
    salted / broadcast mitigation (``skew_force`` pins the choice for A/B
    benchmarking). ``elastic`` sizes the worker pool for the join fan-out
    about to queue, and ``tiering`` chooses spill-vs-evict per ephemeral
    stage of the chosen plan — both decided from plan-derived inputs
    planted in the profile by the planner, so the simulator and the
    runtime bind identical sequences.
    """
    wf = DecisionWorkflow(name or f"query[{strategy.name}]")
    wf.add(DecisionNode("scan", scan_decision,
                        candidates=("scan_filter",)))
    wf.add(DecisionNode("join",
                        strategy_join_fn(strategy, consolidate_threshold),
                        candidates=("hash_join", "merge_join")),
           depends_on=("scan",))
    wf.add(DecisionNode("exchange", exchange_decision,
                        candidates=("shuffle", "broadcast")),
           depends_on=("join",), await_feedback=("scan",))
    wf.add(skew_node(threshold=skew_threshold, min_rows=skew_min_rows,
                     force=skew_force),
           depends_on=("exchange",), await_feedback=("exchange",))
    wf.add(DecisionNode("aggregate", aggregate_decision,
                        candidates=("two_phase",)),
           depends_on=("exchange",), await_feedback=("scan",))
    wf.add(DecisionNode("pipeline", pipeline_decision,
                        candidates=("barrier", "pipelined", "fused")),
           depends_on=("exchange",), await_feedback=("scan",))
    wf.add(elasticity_node(max_workers=elastic_max_workers),
           depends_on=("join",), await_feedback=("scan",))
    wf.add(tiering_node(),
           depends_on=("exchange",), await_feedback=("scan",))
    return wf


def resolve_query_workflow(workflow: DecisionWorkflow | None, strategy,
                           consolidate_threshold: int | None,
                           ) -> DecisionWorkflow:
    """Reuse a caller-supplied workflow or build one. The consolidation
    threshold is baked into a workflow's join node at build time, so
    passing both is a contradiction, not a merge."""
    if workflow is not None:
        if consolidate_threshold is not None:
            raise ValueError(
                "consolidate_threshold is fixed when the workflow is built; "
                "pass it to build_query_workflow, not alongside an existing "
                "workflow")
        return workflow
    return build_query_workflow(
        strategy,
        consolidate_threshold=2 << 30 if consolidate_threshold is None
        else consolidate_threshold)


# ---------------------------------------------------------------------------
# Scan feedback estimation (simulator stand-in for measured store state)
# ---------------------------------------------------------------------------


def estimate_scan_output(fact, name: str = "A_scanned",
                         filter_col: str = "v0", filter_gt: float = 0.0,
                         selectivity: float | None = None) -> DataDist:
    """Simulated scan feedback: the post-filter output distribution.

    For materialized ``DistTable``s the filter is evaluated per partition —
    exact, byte-for-byte what the runtime's scan stage writes to the store —
    so a shared workflow binds identical decisions on either plane. For
    ``PhantomTable``s (GB-scale, size-only) a selectivity factor scales the
    input distribution; the default 1.0 preserves the planner's historical
    sizing.
    """
    parts = getattr(fact, "partitions", None)
    if parts is not None and selectivity is None:
        per_node: dict[int, int] = {}
        rows_per_part: list[int] = []
        total_rows = 0
        for node, t in sorted(parts.items()):
            rows = t.num_rows
            kept = rows
            if rows and filter_col in t.columns:
                kept = int((np.asarray(t[filter_col]) > filter_gt).sum())
            row_bytes = (t.nbytes // rows) if rows else 0
            per_node[node] = per_node.get(node, 0) + kept * row_bytes
            rows_per_part.append(kept)
            total_rows += kept
        return DataDist(name, per_node, rows=total_rows,
                        skew=partition_skew(rows_per_part))
    dist = fact.data_dist()
    s = 1.0 if selectivity is None else float(selectivity)
    per = {n: int(b * s) for n, b in dist.bytes_per_node.items()}
    return DataDist(name, per, rows=int(dist.rows * s), skew=dist.skew)


# ---------------------------------------------------------------------------
# Runtime materialization: decisions -> RuntimeStages
# ---------------------------------------------------------------------------


def _inv(app: str, stage: str, i: int, fn: str, node: int, params: dict,
         priority: int, batchable: bool = False, needs: tuple = ()):
    from repro.runtime.invoker import Invocation
    return Invocation(f"{app}/{stage}/{i}", app, stage, i, fn, node,
                      priority=priority, params=params, batchable=batchable,
                      needs=needs)


def scan_stages(app: str, fact_layout: Sequence[tuple[int, int]],
                dim_layout: Sequence[tuple[int, int]],
                priority: int = 0) -> list:
    """Data-local scan stages; independent, so the dependency-driven
    executor runs them concurrently under a parallel invoker. Scans are
    map-shaped (one partition in, one out): ``batchable`` lets the invoker
    coalesce co-located instances into one slot claim."""
    from repro.runtime.executor import RuntimeStage
    return [
        RuntimeStage("scan_fact", [
            _inv(app, "scan_fact", i, "scan_filter", node,
                 {"src": "input/fact", "dst": "scan_fact", "partition": i,
                  "filter_col": "v0", "filter_gt": 0.0}, priority,
                 batchable=True)
            for i, node in fact_layout], decision="scan"),
        RuntimeStage("scan_dim", [
            _inv(app, "scan_dim", j, "scan_filter", node,
                 {"src": "input/dim", "dst": "scan_dim", "partition": j},
                 priority, batchable=True)
            for j, node in dim_layout], decision="scan"),
    ]


def _tail_shape(fact_layout, dim_layout, decision: Decision,
                dist_f: DataDist, consolidated: bool,
                exchange: Decision | None, aggregate: Decision | None,
                pipeline: Decision | None):
    """Shared geometry of the post-scan plan: join fan-out, placements,
    exchange pattern and pipeline mode — one derivation for the exchange
    wave and the join/aggregate wave, so a plan emitted in two waves is
    identical to the same plan emitted at once."""
    all_nodes = tuple(sorted({n for _, n in fact_layout} |
                             {n for _, n in dim_layout}))
    plan_mode = pipeline.func if pipeline is not None else "barrier"
    n_join = join_fanout(decision)
    join_nodes = decision.schedule.place(n_join) or \
        tuple(all_nodes[i % len(all_nodes)] for i in range(n_join))
    func = decision.func
    if consolidated:
        target = max(dist_f.bytes_per_node, key=dist_f.bytes_per_node.get) \
            if dist_f.bytes_per_node else all_nodes[0]
        join_nodes = (target,) * n_join
        func = "hash_join"
    pattern = exchange.func if exchange is not None else \
        ("shuffle" if func == "merge_join" else "broadcast")
    agg_nodes = (aggregate.schedule.place(n_join) or join_nodes) \
        if aggregate is not None and not consolidated else join_nodes
    return all_nodes, plan_mode, n_join, join_nodes, pattern, agg_nodes


def exchange_stages(app: str, fact_layout: Sequence[tuple[int, int]],
                    dim_layout: Sequence[tuple[int, int]],
                    decision: Decision, dist_f: DataDist,
                    consolidated: bool = False, priority: int = 0,
                    exchange: Decision | None = None) -> list:
    """The shuffle half of the post-scan plan — emitted as its own wave so
    the skew node can bind on the *observed* shuffle histogram before the
    join/aggregate wave materializes. Only meaningful for the shuffle
    exchange pattern (the broadcast pattern has nothing to observe; its
    whole tail is emitted at once)."""
    from repro.runtime.executor import RuntimeStage

    _, _, n_join, _, pattern, _ = _tail_shape(
        fact_layout, dim_layout, decision, dist_f, consolidated, exchange,
        None, None)
    if pattern != "shuffle":
        return []
    return [
        RuntimeStage("shuffle_fact", [
            _inv(app, "shuffle_fact", i, "shuffle_write", node,
                 {"src": "scan_fact", "dst": "fact_buckets",
                  "partition": i, "num_buckets": n_join}, priority,
                 batchable=True, needs=(f"{app}/scan_fact/{i}",))
            for i, node in fact_layout], deps=("scan_fact",),
            decision="exchange"),
        RuntimeStage("shuffle_dim", [
            _inv(app, "shuffle_dim", j, "shuffle_write", node,
                 {"src": "scan_dim", "dst": "dim_buckets",
                  "partition": j, "num_buckets": n_join}, priority,
                 batchable=True, needs=(f"{app}/scan_dim/{j}",))
            for j, node in dim_layout], deps=("scan_dim",),
            decision="exchange"),
    ]


def join_agg_stages(app: str, fact_layout: Sequence[tuple[int, int]],
                    dim_layout: Sequence[tuple[int, int]],
                    decision: Decision, dist_f: DataDist,
                    consolidated: bool = False, num_groups: int = 64,
                    priority: int = 0,
                    exchange: Decision | None = None,
                    aggregate: Decision | None = None,
                    pipeline: Decision | None = None,
                    skew: Decision | None = None) -> list:
    """Materialize the join + aggregation wave from the bound decisions:
    the ``exchange`` decision picks the pattern (``shuffle`` both sides
    into the join's bucket space vs ``broadcast`` the dim side), the join
    decision's ``scale``/``schedule`` set the join fan-out and placement,
    and the ``aggregate`` decision places the two-phase aggregation. When
    only the join decision is given (legacy up-front path) the exchange
    pattern is derived from its ``func`` and aggregation co-locates with
    the join; ``consolidated`` then packs the whole tail onto the
    data-heaviest node (workflow-built consolidated decisions already
    carry that placement).

    The ``pipeline`` decision (barrier / pipelined / fused) rides along as
    a ``plan`` parameter on every join invocation, and every invocation
    carries ``needs`` — the producer invocations whose commits complete its
    inputs — so a pipelining executor can launch it at partition
    granularity. Both are *always* materialized from the bound decision:
    whether the executor honors them is its own flag, so the emitted plan
    (and the decision audit) is byte-identical with pipelining on or off.

    The ``skew`` decision rewrites the heavy part of the shuffle join's
    fan-in without touching anything downstream:

      * ``salted`` — each heavy bucket becomes ``salt`` *writer-sharded*
        sub-joins (``salted_join`` stage): each sub-join reads only its
        round-robin share of the bucket's per-writer slices (the store
        keeps every shuffle writer's slice separately, so a shard read
        moves 1/salt of the bucket's bytes) and writes straight into an
        extra ``joined`` partition the aggregation folds like any other.
        The normal join stage simply skips the heavy buckets, and no
        single invocation ever pulls a heavy bucket whole — the read, not
        just the probe, is what skew serializes. Sub-join ``needs`` edges
        are per-shard: a shard launches as soon as ITS writers (plus the
        dim side's) committed. Bucket reclaim moves from the join stage
        to partial_agg, whose deps cover every bucket reader.
      * ``broadcast`` — the heavy-hitter keys are joined separately: one
        ``hot_build`` invocation replicates their dim rows from the scan
        output, and per-fact-partition ``hot_join`` probes write extra
        ``joined`` partitions. The buckets that contain the hot keys are
        still heavy to *read*, so they get the same writer-sharded
        sub-joins with ``drop_keys`` folded in (single-shard fallback:
        a plain ``drop_keys`` join).

    Either way the ``partials``/``result`` layout downstream stages see
    is exactly the unmitigated plan's — mitigation is control-plane-
    visible (audited) but invisible to the aggregation contract.
    """
    from repro.runtime.executor import RuntimeStage

    all_nodes, plan_mode, n_join, join_nodes, pattern, agg_nodes = \
        _tail_shape(fact_layout, dim_layout, decision, dist_f, consolidated,
                    exchange, aggregate, pipeline)

    stages = []
    if pattern == "shuffle":
        skew_func = skew.func if skew is not None else "none"
        heavy = {int(b): int(r)
                 for b, r in (skew.extra("heavy", ()) if skew else ())}
        hot = tuple(int(k) for k in
                    (skew.extra("hot_keys", ()) if skew else ()))
        salt = int(skew.extra("salt", 0)) if skew is not None else 0
        # hash distribution is all-to-all: every join bucket may hold rows
        # from every writer, so a join's inputs are complete only once ALL
        # shuffle writers committed
        fact_writers = tuple(f"{app}/shuffle_fact/{i}"
                             for i, _ in fact_layout)
        dim_writers_sh = tuple(f"{app}/shuffle_dim/{j}"
                               for j, _ in dim_layout)
        writers = fact_writers + dim_writers_sh
        broadcast_hot = skew_func == "broadcast" and bool(hot)
        hot_buckets: set[int] = set()
        if broadcast_hot:
            from repro.kernels import ops as kops
            hot_buckets = {int(b) for b in np.asarray(
                kops.partition_ids(np.asarray(hot, np.int32), n_join))}
        # which buckets get writer-sharded sub-joins, and the extra params
        # their sub-joins carry. Sharding needs >= 2 fact writers: with one
        # writer a "shard" would be the whole bucket again
        n_shard = min(salt, len(fact_writers))
        shard: dict[int, dict] = {}
        if n_shard > 1:
            if skew_func == "salted" and heavy:
                shard = {r: {} for r in sorted(heavy)}
            elif broadcast_hot:
                shard = {r: {"drop_keys": hot} for r in sorted(hot_buckets)}
        # buckets stay alive until partial_agg when a sharded stage also
        # reads them; the unmitigated plan reclaims at the join stage
        # exactly as before
        join_ephemeral = () if shard else ("fact_buckets", "dim_buckets")
        join_invs = []
        for r in range(n_join):
            if r in shard:
                continue   # the writer-sharded sub-joins cover this bucket
            params = {"fact_stage": "fact_buckets", "fact_partitions": [r],
                      "dim_stage": "dim_buckets", "dim_partitions": [r],
                      "dst": "joined", "partition": r,
                      "num_groups": num_groups, "plan": plan_mode}
            if broadcast_hot and r in hot_buckets:
                params["drop_keys"] = hot
            join_invs.append(
                _inv(app, "join", r, "merge_join_partition", join_nodes[r],
                     params, priority, needs=writers))
        stages += [
            RuntimeStage("join", join_invs,
                         deps=("shuffle_fact", "shuffle_dim"),
                         ephemeral_inputs=join_ephemeral, decision="join"),
        ]
        agg_parts = [r for r in range(n_join) if r not in shard]
        agg_needs = {r: (f"{app}/join/{r}",) for r in agg_parts}
        agg_deps = ("join",)
        agg_ephemeral = ("joined",)
        if shard:
            # extra joined partitions: hot_join probes (broadcast) own
            # n_join .. n_join+len(fact_layout)-1, shard outputs follow
            base = n_join + (len(fact_layout) if broadcast_hot else 0)
            salt_nodes = skew.schedule.place(len(shard) * n_shard) \
                or join_nodes
            sub_invs = []
            si = 0
            for r in sorted(shard):
                for g in range(n_shard):
                    group = fact_writers[g::n_shard]
                    params = {"fact_stage": "fact_buckets",
                              "fact_partitions": [r],
                              "fact_writers": group,
                              "dim_stage": "dim_buckets",
                              "dim_partitions": [r],
                              "dst": "joined", "partition": base + si,
                              "num_groups": num_groups, "plan": plan_mode}
                    params.update(shard[r])
                    sub_invs.append(_inv(
                        app, "salted_join", si, "salted_join_partition",
                        salt_nodes[si % len(salt_nodes)], params, priority,
                        needs=group + dim_writers_sh))
                    agg_needs[base + si] = (f"{app}/salted_join/{si}",)
                    agg_parts.append(base + si)
                    si += 1
            stages += [
                RuntimeStage("salted_join", sub_invs,
                             deps=("shuffle_fact", "shuffle_dim"),
                             decision="skew"),
            ]
            agg_deps = ("join", "salted_join")
            agg_ephemeral = ("joined", "fact_buckets", "dim_buckets")
        if broadcast_hot:
            dim_writers = tuple(f"{app}/scan_dim/{j}" for j, _ in dim_layout)
            stages += [
                RuntimeStage("hot_build", [
                    _inv(app, "hot_build", 0, "hot_filter_write",
                         dim_layout[0][1],
                         {"src": "scan_dim",
                          "src_partitions": [j for j, _ in dim_layout],
                          "keys": hot, "dst": "dim_hot"}, priority,
                         needs=dim_writers)],
                    deps=("scan_dim",), decision="skew"),
                RuntimeStage("hot_join", [
                    _inv(app, "hot_join", i, "hot_join_partition", node,
                         {"fact_stage": "scan_fact", "fact_partitions": [i],
                          "dim_stage": "dim_hot", "dim_partitions": [0],
                          "keep_keys": hot, "dst": "joined",
                          "partition": n_join + i,
                          "num_groups": num_groups, "plan": plan_mode},
                         priority,
                         needs=(f"{app}/scan_fact/{i}",
                                f"{app}/hot_build/0"))
                    for i, node in fact_layout],
                    deps=("scan_fact", "hot_build"), decision="skew"),
            ]
            for i, _node in fact_layout:
                agg_needs[n_join + i] = (f"{app}/hot_join/{i}",)
                agg_parts.append(n_join + i)
            agg_deps = agg_deps + ("hot_join",)
            agg_ephemeral = agg_ephemeral + ("dim_hot",)
    else:
        agg_parts = list(range(n_join))
        agg_needs = {r: (f"{app}/join/{r}",) for r in range(n_join)}
        agg_deps = ("join",)
        agg_ephemeral = ("joined",)
        bcast = tuple(f"{app}/broadcast_dim/{j}" for j, _ in dim_layout)
        stages += [
            RuntimeStage("broadcast_dim", [
                _inv(app, "broadcast_dim", j, "broadcast_write", node,
                     {"src": "scan_dim", "dst": "dim_bcast", "partition": j},
                     priority, batchable=True,
                     needs=(f"{app}/scan_dim/{j}",))
                for j, node in dim_layout], deps=("scan_dim",),
                decision="exchange"),
            RuntimeStage("join", [
                _inv(app, "join", k, "hash_join_partition", join_nodes[k],
                     {"fact_stage": "scan_fact",
                      "fact_partitions": [i for i, _ in fact_layout
                                          if i % n_join == k],
                      "dim_stage": "dim_bcast", "dim_partitions": "all",
                      "dst": "joined", "partition": k,
                      "num_groups": num_groups, "plan": plan_mode},
                     priority,
                     needs=bcast + tuple(
                         f"{app}/scan_fact/{i}" for i, _ in fact_layout
                         if i % n_join == k))
                for k in range(n_join)],
                deps=("scan_fact", "broadcast_dim"), decision="join"),
        ]

    pagg_nodes = {k: agg_nodes[j % len(agg_nodes)]
                  for j, k in enumerate(agg_parts)}
    stages += [
        RuntimeStage("partial_agg", [
            _inv(app, "partial_agg", k, "partial_aggregate", pagg_nodes[k],
                 {"src": "joined", "dst": "partials", "partition": k,
                  "num_groups": num_groups}, priority, batchable=True,
                 needs=agg_needs[k])
            for k in agg_parts], deps=agg_deps,
            ephemeral_inputs=agg_ephemeral, decision="aggregate"),
        RuntimeStage("final_agg", [
            _inv(app, "final_agg", 0, "final_aggregate", agg_nodes[0],
                 {"src": "partials", "dst": "result",
                  "num_groups": num_groups}, priority,
                 needs=tuple(f"{app}/partial_agg/{k}"
                             for k in agg_parts))],
            deps=("partial_agg",), ephemeral_inputs=("partials",),
            decision="aggregate"),
    ]
    return stages


def tail_stages(app: str, fact_layout: Sequence[tuple[int, int]],
                dim_layout: Sequence[tuple[int, int]], decision: Decision,
                dist_f: DataDist, consolidated: bool = False,
                num_groups: int = 64, priority: int = 0,
                exchange: Decision | None = None,
                aggregate: Decision | None = None,
                pipeline: Decision | None = None,
                skew: Decision | None = None) -> list:
    """The full post-scan plan in one list: the exchange wave (when the
    pattern shuffles) followed by the join/aggregate wave — what the
    adaptive planner emits in two callbacks, concatenated. Static callers
    (``stages_for_run``, the up-front legacy path) use this; they already
    hold every decision, including skew."""
    return exchange_stages(
        app, fact_layout, dim_layout, decision, dist_f,
        consolidated=consolidated, priority=priority, exchange=exchange,
    ) + join_agg_stages(
        app, fact_layout, dim_layout, decision, dist_f,
        consolidated=consolidated, num_groups=num_groups, priority=priority,
        exchange=exchange, aggregate=aggregate, pipeline=pipeline,
        skew=skew)


class AdaptiveQueryPlan:
    """Stage planner driving one ``WorkflowRun`` against the runtime.

    The DAG executor calls ``on_stage_complete`` as physical stages finish.
    The decide→execute→re-decide loop now has two re-plan points:

    1. Once ``scan_fact`` lands, the measured metrics and the observed
       post-filter distribution bind ``join`` and ``exchange``. A shuffle
       exchange emits only the shuffle wave; a broadcast exchange has no
       shuffle histogram to wait for, so the skew node binds immediately
       (trivially ``none``) and the whole tail is emitted.
    2. Once both shuffle stages land, the observed per-bucket histogram
       and heavy-hitter sketch from ``profile_feedback`` bind ``skew``,
       then ``aggregate``/``pipeline``/``elastic``/``tiering``, and the
       join/aggregate wave — including any mitigation stages — is emitted.

    Two-wave emission costs nothing at s=0: every join invocation needs
    ALL shuffle writers (hash distribution is all-to-all), so no join
    could have launched before the shuffle completed anyway.
    """

    def __init__(self, run: WorkflowRun, app: str,
                 fact_layout: Sequence[tuple[int, int]],
                 dim_layout: Sequence[tuple[int, int]],
                 num_groups: int = 64, priority: int = 0):
        self.run = run
        self.app = app
        self.fact_layout = list(fact_layout)
        self.dim_layout = list(dim_layout)
        self.num_groups = num_groups
        self.priority = priority
        self._completed: set[str] = set()
        self._tail_planned = False
        self._join_planned = False
        self._join_d: Decision | None = None
        self._exchange_d: Decision | None = None
        self._scanned: DataDist | None = None

    def initial_stages(self) -> list:
        self.run.decide("scan")
        return scan_stages(self.app, self.fact_layout, self.dim_layout,
                           self.priority)

    def on_stage_complete(self, stage: str, runtime, pc=None) -> list:
        self._completed.add(stage)
        # The join decision needs only the *fact* side's observed post-filter
        # output (the dim side has no filter, its input dist is app
        # knowledge) — so the first wave binds as soon as scan_fact lands,
        # and e.g. shuffle_fact overlaps a still-running scan_dim.
        if not self._tail_planned:
            if "scan_fact" not in self._completed:
                return []
            return self._plan_exchange(runtime, pc)
        if not self._join_planned and self._exchange_d is not None and \
                self._exchange_d.func == "shuffle" and \
                {"shuffle_fact", "shuffle_dim"} <= self._completed:
            return self._plan_join_tail(runtime)
        return []

    def _plan_exchange(self, runtime, pc) -> list:
        self._tail_planned = True
        # Fig. 5 step 4: fold observed output + metrics, then decide late.
        scanned = runtime.store.data_dist(self.app, "scan_fact",
                                          name="A_scanned")
        if pc is not None:
            pc.observe_data(scanned)
        self.run.observe(scanned)
        self.run.refresh_status(runtime.gc.node_status())
        self.run.feedback("scan",
                          runtime.metrics.profile_feedback(self.app))
        join_d = self.run.decide("join")
        exchange_d = self.run.decide("exchange")
        self._join_d, self._exchange_d, self._scanned = \
            join_d, exchange_d, scanned
        if exchange_d.func == "shuffle":
            # emit only the shuffle wave: the skew node (and everything
            # after it) binds on the observed bucket histogram in wave 2
            return exchange_stages(
                self.app, self.fact_layout, self.dim_layout, join_d,
                self.run.ctx.data_dist["A"], priority=self.priority,
                exchange=exchange_d)
        # broadcast exchange: no shuffle to observe — skew binds now, on
        # an empty histogram, and trivially decides "none"
        self._join_planned = True
        self.run.feedback("exchange", {})
        skew_d = decide_skew(self.run, (), (), ())
        return self._plan_rest(runtime, skew_d)

    def _plan_join_tail(self, runtime) -> list:
        self._join_planned = True
        # wave 2, Fig. 5 step 4 again: the *observed* shuffle histogram
        # and merged heavy-hitter sketch feed the skew node
        fb = runtime.metrics.profile_feedback(self.app)
        self.run.feedback("exchange", fb)
        rows = tuple(fb.get("shuffle_fact.partition_rows", ()))
        nbytes = tuple(fb.get("shuffle_fact.partition_bytes", ()))
        hot = tuple(fb.get("shuffle_fact.hot_keys", ()))
        skew_d = decide_skew(self.run, rows, nbytes, hot)
        # partition balance as counter tracks: visible in the Chrome trace
        # next to slot occupancy and store bytes
        from repro.obs.tracer import get_tracer
        tr = get_tracer()
        if tr.enabled and nbytes:
            tr.count(f"skew/{self.app}/max_partition_bytes", max(nbytes))
            tr.count(f"skew/{self.app}/mean_partition_bytes",
                     int(sum(nbytes) / len(nbytes)))
            tr.count(f"skew/{self.app}/hot_keys", len(hot))
        return self._plan_rest(runtime, skew_d)

    def _plan_rest(self, runtime, skew_d: Decision) -> list:
        join_d, exchange_d, scanned = \
            self._join_d, self._exchange_d, self._scanned
        aggregate_d = self.run.decide("aggregate")
        pipeline_d = self.run.decide("pipeline")
        # elasticity: size the worker pool for the join fan-out about to
        # queue; on backends without a pool (threads, inline) the decision
        # still binds and is audited, it just has nothing to resize
        pool_size = getattr(runtime.invoker, "pool_size", None)
        elastic_d = decide_elastic(
            self.run, join_fanout(join_d),
            int(pool_size()) if callable(pool_size) else 0)
        resize = getattr(runtime.invoker, "resize", None)
        if callable(resize) and elastic_d.func != "hold":
            resize(int(elastic_d.scale))
        # tiering: price spill-vs-evict for the plan's ephemeral stages
        # against the store's cold tiers; the bound plan becomes the spill
        # policy reclaim/eviction consults. Stores without spill backends
        # (or apps without quotas) bind "keep" — today's behavior
        store = runtime.store
        tier_d = decide_tiering(
            self.run,
            ephemeral_stage_profile(scanned, self.run.ctx.data_dist["B"],
                                    join_d, exchange_d, self.num_groups,
                                    skew=skew_d),
            store.quota(self.app), store.storage_spec())
        if tier_d.func != "keep":
            store.set_spill_policy(self.app, dict(tier_d.extra("plan", ())))
        # consolidated join decisions already carry their packed placement,
        # so the materialization is exactly what the sequence records
        return join_agg_stages(
            self.app, self.fact_layout, self.dim_layout, join_d,
            self.run.ctx.data_dist["A"], num_groups=self.num_groups,
            priority=self.priority, exchange=exchange_d,
            aggregate=aggregate_d, pipeline=pipeline_d, skew=skew_d)


def stages_for_run(run: WorkflowRun, app: str,
                   fact_layout: Sequence[tuple[int, int]],
                   dim_layout: Sequence[tuple[int, int]],
                   num_groups: int = 64, priority: int = 0) -> list:
    """Materialize the full physical stage list from an already-bound
    ``WorkflowRun`` — the *static* twin of ``AdaptiveQueryPlan``'s
    incremental emission, used by the simulator-side fault model to predict
    recovery stage sets (``repro.runtime.lineage.expected_recovery``) for
    the exact plan the decisions imply."""
    return scan_stages(app, fact_layout, dim_layout, priority) + tail_stages(
        app, fact_layout, dim_layout, run.decisions["join"],
        run.ctx.data_dist["A"], num_groups=num_groups, priority=priority,
        exchange=run.decisions.get("exchange"),
        aggregate=run.decisions.get("aggregate"),
        pipeline=run.decisions.get("pipeline"),
        skew=run.decisions.get("skew"))


# ---------------------------------------------------------------------------
# Simulator materialization: the same workflow -> SimTasks
# ---------------------------------------------------------------------------


def plan_query_with_workflow(sim, pc, fact, dim, strategy,
                             app: str = "query",
                             workflow: DecisionWorkflow | None = None,
                             consolidate_threshold: int | None = None,
                             scan_selectivity: float | None = None,
                             num_groups: int = 64,
                             storage_spec=None,
                             store_quota: int | None = None,
                             ) -> WorkflowRun:
    """Plan the TPC-DS-like sub-query into ``sim`` through the decision
    workflow; the scan stage's feedback is *estimated* (exactly, for
    materialized tables) instead of measured. ``storage_spec`` /
    ``store_quota`` mirror the runtime store's cold-tier specs and app
    quota into the tiering decision (default: the sim's own
    ``storage_spec``/``store_quotas`` attributes when set, else no tiers —
    matching a store without spill backends). Returns the ``WorkflowRun``
    whose decision sequence the submitted tasks materialize."""
    from repro.analytics.simulator import calibrated_rates

    rates = calibrated_rates()
    gc = pc.gc
    status = gc.node_status()
    nodes = sorted(status.total_slots)
    slots = max(status.total_slots.values())

    dist_f, dist_d = fact.data_dist(), dim.data_dist()
    pc.observe_data(dist_f)
    pc.observe_data(dist_d)
    wf = resolve_query_workflow(workflow, strategy, consolidate_threshold)
    ctx = DecisionContext(data_dist={"A": dist_f, "B": dist_d},
                          node_status=status, profile=dict(pc.profile))
    run = wf.start(ctx)
    run.app = app
    run.decide("scan")

    # simulate the scan stage: the estimated post-filter output distribution
    # is the feedback the late-bound join decision consumes
    scanned = estimate_scan_output(fact, selectivity=scan_selectivity)
    run.observe(scanned)
    run.feedback("scan", {"scan_fact.bytes_out": scanned.size,
                          "scan_fact.estimated": True})
    decision = run.decide("join")
    exchange_d = run.decide("exchange")
    # skew feedback: the sim *recomputes* exactly what the runtime's shuffle
    # writers would observe — same partition_ids kernel, same sketch, same
    # post-filter rows — so both planes bind the skew node on identical
    # evidence and materialize identical decision sequences
    if exchange_d.func == "shuffle":
        rows_h, bytes_h, hot = shuffle_skew_feedback(
            fact, join_fanout(decision))
        run.feedback("exchange",
                     {"shuffle_fact.partition_rows": rows_h,
                      "shuffle_fact.partition_bytes": bytes_h,
                      "shuffle_fact.hot_keys": hot})
    else:
        rows_h, bytes_h, hot = (), (), ()
        run.feedback("exchange", {})
    skew_d = decide_skew(run, rows_h, bytes_h, hot)
    run.decide("aggregate")
    run.decide("pipeline")
    # elasticity, through the same helper as the runtime plane: the sim's
    # cold-start model (when enabled) pre-warms on "grow" exactly where the
    # runtime resizes its process pool
    elastic_d = decide_elastic(run, join_fanout(decision), sim.pool_size()
                               if hasattr(sim, "pool_size") else 0)
    if elastic_d.func == "grow" and hasattr(sim, "prewarm"):
        sim.prewarm(int(elastic_d.scale), app)
    # tiering, through the same helper and the same plan-derived estimates
    # as the runtime plane (estimate_scan_output is exact for materialized
    # tables, so both planes price identical stage profiles)
    if storage_spec is None:
        storage_spec = getattr(sim, "storage_spec", None)
    if store_quota is None:
        store_quota = (getattr(sim, "store_quotas", None) or {}).get(app)
    decide_tiering(run,
                   ephemeral_stage_profile(scanned, dist_d, decision,
                                           exchange_d, num_groups,
                                           skew=skew_d),
                   store_quota, storage_spec)
    consolidated = bool(decision.extra("consolidate", False))

    _submit_sim_tasks(sim, app, dist_f, dist_d, scanned, decision,
                      consolidated, nodes, slots, rates)
    return run


def _submit_sim_tasks(sim, app, dist_f, dist_d, scanned, decision,
                      consolidated, nodes, slots, rates) -> None:
    from repro.analytics.simulator import SimTask

    # ---- scan phase 1: map over fact partitions (scan+filter+project) -----
    map1 = []
    if consolidated:
        # paper Fig. 7 (2 GB case): pack everything onto one node; the only
        # transfers are the initial partition pulls.
        target = max(dist_f.bytes_per_node, key=dist_f.bytes_per_node.get)
        n_tasks = min(slots, max(1, int(dist_f.size / ALPHA)))
        per = dist_f.size / n_tasks
        for i in range(n_tasks):
            src = nodes[i % len(nodes)]
            sim.submit(SimTask(
                f"{app}/map1/{i}", app, per / rates["scan"], node=target,
                priority=10,
                transfers={src: int(per)} if src != target else {}))
            map1.append(f"{app}/map1/{i}")
    else:
        n_tasks = max(1, int(dist_f.size / ALPHA))
        placement = Schedule("round-robin", tuple(nodes)).place(n_tasks)
        per = dist_f.size / n_tasks
        for i, node in enumerate(placement):
            data_node = nodes[i % len(nodes)]
            sim.submit(SimTask(
                f"{app}/map1/{i}", app, per / rates["scan"], node=node,
                priority=10,
                transfers={data_node: int(per)} if data_node != node else {}))
            map1.append(f"{app}/map1/{i}")

    # ---- scan phase 2: map over dim partitions ----------------------------
    map2 = []
    n_tasks2 = max(1, int(dist_d.size / ALPHA))
    place2 = Schedule("round-robin", tuple(sorted(dist_d.loc))).place(n_tasks2)
    per2 = dist_d.size / n_tasks2
    for i, node in enumerate(place2):
        sim.submit(SimTask(f"{app}/map2/{i}", app, per2 / rates["scan"],
                           node=node, priority=10))
        map2.append(f"{app}/map2/{i}")

    # ---- join phase: sized by the *post-scan* volume ----------------------
    join_nodes = decision.schedule.place(decision.scale) or tuple(nodes)
    n_join = len(join_nodes)
    per_join = scanned.size / n_join

    if consolidated:
        target = max(dist_f.bytes_per_node, key=dist_f.bytes_per_node.get)
        for i in range(min(slots, n_join)):
            sim.submit(SimTask(
                f"{app}/join/{i}", app,
                per_join / rates["hash_probe"]
                + dist_d.size / max(1, n_join) / rates["hash_build"],
                node=target, priority=10, deps=tuple(map1 + map2)))
    elif decision.func == "merge_join":
        # shuffle both sides by key: every join task pulls its hash range
        # from every map task's node (all-to-all), then sort-merges.
        for i, node in enumerate(join_nodes):
            pulls = {n: int((per_join + dist_d.size / n_join)
                            / max(1, len(nodes)))
                     for n in nodes if n != node}
            sim.submit(SimTask(
                f"{app}/join/{i}", app,
                (per_join + dist_d.size / n_join) / rates["merge_join"],
                node=node, priority=10, deps=tuple(map1 + map2),
                transfers=pulls))
    else:
        # hash join: broadcast the whole dim table once per *node* (senders =
        # dim's home nodes, serialized — the Fig. 4c effect); the first task
        # on a node builds the table, co-located tasks share it and probe.
        dim_homes = sorted(dist_d.loc) or nodes
        seen_nodes: set[int] = set()
        for i, node in enumerate(join_nodes):
            first_on_node = node not in seen_nodes
            seen_nodes.add(node)
            src = dim_homes[i % len(dim_homes)]
            pulls = {src: int(dist_d.size)} \
                if (first_on_node and src != node) else {}
            dur = per_join / rates["hash_probe"]
            if first_on_node:
                dur += dist_d.size / rates["hash_build"]
            sim.submit(SimTask(
                f"{app}/join/{i}", app, dur, node=node, priority=10,
                deps=tuple(map1 + map2), transfers=pulls))

    # ---- final aggregation ------------------------------------------------
    join_names = [t for t in sim.tasks if t.startswith(f"{app}/join/")]
    agg_node = join_nodes[0] if join_nodes else nodes[0]
    pulls = {n: int(scanned.size / max(1, n_join) / 16)
             for n in set(join_nodes) if n != agg_node}
    sim.submit(SimTask(f"{app}/agg", app,
                       scanned.size / 16 / rates["agg"], node=agg_node,
                       priority=10, deps=tuple(join_names),
                       transfers=pulls))
