"""Workflow-driven adaptive query planner (paper Fig. 5 step 4, Fig. 6).

One ``DecisionWorkflow`` per query carries five per-phase decision nodes —
``scan``, ``join``, ``exchange``, ``aggregate``, ``pipeline`` — and drives
*both* data planes. ``AdaptiveQueryPlan`` is the runtime side: the DAG executor calls it
back as physical stages complete, it folds the observed metrics and the
**post-filter** scan output distribution into the workflow context, binds the
next decisions, and emits the newly materialized stages — a mid-query
re-plan. ``plan_query_with_workflow`` is the simulator side: it walks the
identical workflow, substituting an *estimated* scan output for the measured
one, and submits ``SimTask``s. Because both planners evaluate the same
workflow object, the simulated and real plans come from identical decision
sequences.

The join node is late-bound on the scan stage: it sees ``A_scanned`` (the
post-filter fact distribution) instead of the raw input, so a highly
selective filter observed at runtime can flip the join variant mid-query —
a decision impossible under a plan-everything-up-front planner.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.analytics.decisions import ALPHA
from repro.core.decisions import (
    DataDist,
    Decision,
    DecisionContext,
    DecisionNode,
    DecisionWorkflow,
    Schedule,
    WorkflowRun,
    elasticity_node,
    partition_skew,
    tiering_node,
)

MAX_JOIN_FANOUT = 64      # runtime join bucket-space cap


# ---------------------------------------------------------------------------
# Per-phase decision nodes
# ---------------------------------------------------------------------------


def observed_join_ctx(ctx: DecisionContext) -> DecisionContext:
    """The join node's view: the post-scan distribution (``A_scanned``),
    when observed, replaces the raw fact input as side ``A``."""
    scanned = ctx.data_dist.get("A_scanned")
    if scanned is None:
        return ctx
    return DecisionContext(
        data_dist=dict(ctx.data_dist, A=scanned),
        node_status=ctx.node_status, app=ctx.app, profile=ctx.profile,
        decisions=ctx.decisions)


def scan_decision(ctx: DecisionContext) -> Decision:
    """Scans are data-local: one wave per ~ALPHA bytes over the input homes."""
    dist_f = ctx.data_dist["A"]
    nodes = tuple(sorted(dist_f.loc)) or \
        tuple(sorted(ctx.node_status.total_slots))
    scale = max(1, int(dist_f.size / ALPHA))
    return Decision("scan_filter", scale, Schedule("round-robin", nodes))


def consolidation_applies(strategy_name: str, decision: Decision,
                          total_bytes: int, threshold: int) -> bool:
    """The paper's consolidation policy, shared by the workflow join node
    and the legacy up-front shim: either the decision node itself opted in
    (cost model) or the literal Fig. 6 strategy sees the whole input fit
    one node."""
    return bool(decision.extra("consolidate", False)) or (
        strategy_name == "dynamic_fig6" and total_bytes <= threshold)


def strategy_join_fn(strategy, consolidate_threshold: int = 2 << 30):
    """Wrap a strategy's join choice as a late-bound workflow node fn.

    The wrapped node sees the observed post-filter fact distribution. When
    the paper's consolidation applies (whole input fits one node) the
    decision itself is rewritten to what will actually run — hash join,
    packed onto the data-heaviest node — so the recorded sequence never
    contradicts the materialized plan.
    """

    def fn(ctx: DecisionContext) -> Decision:
        decision = strategy.join_method(observed_join_ctx(ctx))
        dist_f = ctx.data_dist["A"]
        total = dist_f.size + ctx.data_dist["B"].size
        if consolidation_applies(strategy.name, decision, total,
                                 consolidate_threshold) and \
                not decision.extra("consolidate", False):
            slots = ctx.node_status.total_slots
            cap = max(slots.values()) if slots else 8
            target = max(dist_f.bytes_per_node,
                         key=dist_f.bytes_per_node.get) \
                if dist_f.bytes_per_node else 0
            decision = Decision(
                "hash_join", min(join_fanout(decision), cap),
                Schedule("packing", (target,), slots_per_node=cap),
                extras=decision.extras + (("consolidate", True),))
        return decision

    return fn


def join_fanout(join: Decision) -> int:
    return max(1, min(int(join.scale), MAX_JOIN_FANOUT))


def decide_elastic(run: WorkflowRun, fanout: int, pool: int) -> Decision:
    """Plant the elastic node's context contract — the upcoming fan-out and
    the current pool size — and bind it. One helper shared by both planes,
    so the profile keys (and therefore the bound sequences) cannot drift
    between the simulator and the runtime."""
    run.ctx.profile["elastic.fanout"] = int(fanout)
    run.ctx.profile["elastic.pool"] = int(pool)
    return run.decide("elastic")


# rough per-row bytes of a two-phase partial-aggregate bucket (group key +
# accumulator), used only to *estimate* the partials stage for tiering
PARTIAL_AGG_ROW_BYTES = 16


def ephemeral_stage_profile(scanned: DataDist, dist_b: DataDist,
                            join: Decision, exchange: Decision,
                            num_groups: int) -> tuple:
    """``(stage, est_bytes, lineage_depth, downstream_remaining)`` for each
    ephemeral data stage the chosen physical plan will reclaim, in reclaim
    order — the tiering node's sizing input. Every number is derived from
    the bound plan (estimated scan output, dim distribution, join fan-out),
    never measured, so the runtime and the simulator price the same
    stages identically."""
    n_join = join_fanout(join)
    partials = PARTIAL_AGG_ROW_BYTES * int(num_groups) * n_join
    if exchange.func == "shuffle":
        return (("fact_buckets", int(scanned.size), 2, 2),
                ("dim_buckets", int(dist_b.size), 2, 2),
                ("joined", int(scanned.size), 3, 1),
                ("partials", partials, 4, 0))
    # broadcast path: the dim broadcast is never reclaimed (no ephemeral
    # input names it), so only the join output and the partials spill
    return (("joined", int(scanned.size), 2, 1),
            ("partials", partials, 3, 0))


def decide_tiering(run: WorkflowRun, stages, quota: int | None,
                   tiers) -> Decision:
    """Plant the tiering node's context contract — the plan's ephemeral
    stages, the app's store quota, and the cold-tier specs — and bind it.
    One helper shared by both planes, so the profile keys (and therefore
    the bound sequences) cannot drift between simulator and runtime."""
    run.ctx.profile["tiering.stages"] = tuple(stages)
    run.ctx.profile["tiering.quota"] = None if quota is None else int(quota)
    run.ctx.profile["tiering.tiers"] = dict(tiers or {})
    return run.decide("tiering")


def exchange_decision(ctx: DecisionContext) -> Decision:
    """The exchange pattern follows the bound join decision: merge join
    hash-shuffles both sides into the join's bucket space, hash join
    broadcasts the (small) dim side from its home nodes."""
    join = ctx.decisions["join"]
    dist_a = ctx.data_dist.get("A_scanned", ctx.data_dist["A"])
    dist_b = ctx.data_dist["B"]
    n_join = join_fanout(join)
    if join.func == "merge_join":
        producers = tuple(sorted(dist_a.loc | dist_b.loc)) or \
            tuple(sorted(ctx.node_status.total_slots))
        return Decision("shuffle", n_join,
                        Schedule("round-robin", producers),
                        extras=(("num_buckets", n_join),))
    homes = tuple(sorted(dist_b.loc)) or \
        tuple(sorted(ctx.node_status.total_slots))
    return Decision("broadcast", max(1, len(homes)),
                    Schedule("round-robin", homes))


def aggregate_decision(ctx: DecisionContext) -> Decision:
    """Two-phase aggregation co-located with the join outputs."""
    join = ctx.decisions["join"]
    return Decision("two_phase", join_fanout(join), join.schedule)


# per-bucket bytes under which the fused partition+probe kernel's build
# side comfortably fits VMEM (one-hot probe over the whole bucket)
FUSED_BUCKET_BYTES = 4 << 20
PREFETCH_DEPTH = 2            # in-flight partition fetches per join side


def pipeline_decision(ctx: DecisionContext) -> Decision:
    """Shuffle→join coupling: stage ``barrier`` vs partition-``pipelined``
    consumption vs the ``fused`` partition+probe kernel.

    A control-plane choice, not a data-plane flag: it binds from the
    *observed* post-scan volume (bucket size = both sides over the join
    fan-out) and the controller's free-slot view. Small buckets take the
    fused single-dispatch kernel (its build side must fit VMEM); otherwise
    free slots make partition-granularity pipelining worthwhile (consumers
    can launch while producers still hold slots); a saturated cluster keeps
    the stage barrier — pipelining would only queue behind producers. The
    ``scale`` is the per-side prefetch depth (double buffering)."""
    join = ctx.decisions["join"]
    dist_a = ctx.data_dist.get("A_scanned", ctx.data_dist["A"])
    dist_b = ctx.data_dist["B"]
    n_join = join_fanout(join)
    bucket = (dist_a.size + dist_b.size) / max(1, n_join)
    if bucket <= FUSED_BUCKET_BYTES:
        return Decision("fused", PREFETCH_DEPTH, join.schedule,
                        extras=(("bucket_bytes", int(bucket)),))
    if ctx.node_status.free() > 0:
        return Decision("pipelined", PREFETCH_DEPTH, join.schedule,
                        extras=(("bucket_bytes", int(bucket)),))
    return Decision("barrier", 1, join.schedule,
                    extras=(("bucket_bytes", int(bucket)),))


def build_query_workflow(strategy, name: str | None = None,
                         consolidate_threshold: int = 2 << 30,
                         elastic_max_workers: int = 16,
                         ) -> DecisionWorkflow:
    """The query's decision workflow (paper Fig. 5): seven per-phase nodes.

    ``join`` is late-bound on the scan stage's feedback; ``exchange``,
    ``aggregate`` and ``pipeline`` follow the join *decision* (their
    physical effect brackets the join stage) but await only the scan
    feedback. ``elastic`` sizes the worker pool for the join fan-out about
    to queue, and ``tiering`` chooses spill-vs-evict per ephemeral stage
    of the chosen plan — both decided from plan-derived inputs planted in
    the profile by the planner, so the simulator and the runtime bind
    identical sequences.
    """
    wf = DecisionWorkflow(name or f"query[{strategy.name}]")
    wf.add(DecisionNode("scan", scan_decision,
                        candidates=("scan_filter",)))
    wf.add(DecisionNode("join",
                        strategy_join_fn(strategy, consolidate_threshold),
                        candidates=("hash_join", "merge_join")),
           depends_on=("scan",))
    wf.add(DecisionNode("exchange", exchange_decision,
                        candidates=("shuffle", "broadcast")),
           depends_on=("join",), await_feedback=("scan",))
    wf.add(DecisionNode("aggregate", aggregate_decision,
                        candidates=("two_phase",)),
           depends_on=("exchange",), await_feedback=("scan",))
    wf.add(DecisionNode("pipeline", pipeline_decision,
                        candidates=("barrier", "pipelined", "fused")),
           depends_on=("exchange",), await_feedback=("scan",))
    wf.add(elasticity_node(max_workers=elastic_max_workers),
           depends_on=("join",), await_feedback=("scan",))
    wf.add(tiering_node(),
           depends_on=("exchange",), await_feedback=("scan",))
    return wf


def resolve_query_workflow(workflow: DecisionWorkflow | None, strategy,
                           consolidate_threshold: int | None,
                           ) -> DecisionWorkflow:
    """Reuse a caller-supplied workflow or build one. The consolidation
    threshold is baked into a workflow's join node at build time, so
    passing both is a contradiction, not a merge."""
    if workflow is not None:
        if consolidate_threshold is not None:
            raise ValueError(
                "consolidate_threshold is fixed when the workflow is built; "
                "pass it to build_query_workflow, not alongside an existing "
                "workflow")
        return workflow
    return build_query_workflow(
        strategy,
        consolidate_threshold=2 << 30 if consolidate_threshold is None
        else consolidate_threshold)


# ---------------------------------------------------------------------------
# Scan feedback estimation (simulator stand-in for measured store state)
# ---------------------------------------------------------------------------


def estimate_scan_output(fact, name: str = "A_scanned",
                         filter_col: str = "v0", filter_gt: float = 0.0,
                         selectivity: float | None = None) -> DataDist:
    """Simulated scan feedback: the post-filter output distribution.

    For materialized ``DistTable``s the filter is evaluated per partition —
    exact, byte-for-byte what the runtime's scan stage writes to the store —
    so a shared workflow binds identical decisions on either plane. For
    ``PhantomTable``s (GB-scale, size-only) a selectivity factor scales the
    input distribution; the default 1.0 preserves the planner's historical
    sizing.
    """
    parts = getattr(fact, "partitions", None)
    if parts is not None and selectivity is None:
        per_node: dict[int, int] = {}
        rows_per_part: list[int] = []
        total_rows = 0
        for node, t in sorted(parts.items()):
            rows = t.num_rows
            kept = rows
            if rows and filter_col in t.columns:
                kept = int((np.asarray(t[filter_col]) > filter_gt).sum())
            row_bytes = (t.nbytes // rows) if rows else 0
            per_node[node] = per_node.get(node, 0) + kept * row_bytes
            rows_per_part.append(kept)
            total_rows += kept
        return DataDist(name, per_node, rows=total_rows,
                        skew=partition_skew(rows_per_part))
    dist = fact.data_dist()
    s = 1.0 if selectivity is None else float(selectivity)
    per = {n: int(b * s) for n, b in dist.bytes_per_node.items()}
    return DataDist(name, per, rows=int(dist.rows * s), skew=dist.skew)


# ---------------------------------------------------------------------------
# Runtime materialization: decisions -> RuntimeStages
# ---------------------------------------------------------------------------


def _inv(app: str, stage: str, i: int, fn: str, node: int, params: dict,
         priority: int, batchable: bool = False, needs: tuple = ()):
    from repro.runtime.invoker import Invocation
    return Invocation(f"{app}/{stage}/{i}", app, stage, i, fn, node,
                      priority=priority, params=params, batchable=batchable,
                      needs=needs)


def scan_stages(app: str, fact_layout: Sequence[tuple[int, int]],
                dim_layout: Sequence[tuple[int, int]],
                priority: int = 0) -> list:
    """Data-local scan stages; independent, so the dependency-driven
    executor runs them concurrently under a parallel invoker. Scans are
    map-shaped (one partition in, one out): ``batchable`` lets the invoker
    coalesce co-located instances into one slot claim."""
    from repro.runtime.executor import RuntimeStage
    return [
        RuntimeStage("scan_fact", [
            _inv(app, "scan_fact", i, "scan_filter", node,
                 {"src": "input/fact", "dst": "scan_fact", "partition": i,
                  "filter_col": "v0", "filter_gt": 0.0}, priority,
                 batchable=True)
            for i, node in fact_layout], decision="scan"),
        RuntimeStage("scan_dim", [
            _inv(app, "scan_dim", j, "scan_filter", node,
                 {"src": "input/dim", "dst": "scan_dim", "partition": j},
                 priority, batchable=True)
            for j, node in dim_layout], decision="scan"),
    ]


def tail_stages(app: str, fact_layout: Sequence[tuple[int, int]],
                dim_layout: Sequence[tuple[int, int]], decision: Decision,
                dist_f: DataDist, consolidated: bool = False,
                num_groups: int = 64, priority: int = 0,
                exchange: Decision | None = None,
                aggregate: Decision | None = None,
                pipeline: Decision | None = None) -> list:
    """Materialize the post-scan plan from the bound decisions: the
    ``exchange`` decision picks the pattern (``shuffle`` both sides into the
    join's bucket space vs ``broadcast`` the dim side), the join decision's
    ``scale``/``schedule`` set the join fan-out and placement, and the
    ``aggregate`` decision places the two-phase aggregation. When only the
    join decision is given (legacy up-front path) the exchange pattern is
    derived from its ``func`` and aggregation co-locates with the join;
    ``consolidated`` then packs the whole tail onto the data-heaviest node
    (workflow-built consolidated decisions already carry that placement).

    The ``pipeline`` decision (barrier / pipelined / fused) rides along as
    a ``plan`` parameter on every join invocation, and every invocation
    carries ``needs`` — the producer invocations whose commits complete its
    inputs — so a pipelining executor can launch it at partition
    granularity. Both are *always* materialized from the bound decision:
    whether the executor honors them is its own flag, so the emitted plan
    (and the decision audit) is byte-identical with pipelining on or off.
    """
    from repro.runtime.executor import RuntimeStage

    all_nodes = tuple(sorted({n for _, n in fact_layout} |
                             {n for _, n in dim_layout}))
    plan_mode = pipeline.func if pipeline is not None else "barrier"
    n_join = join_fanout(decision)
    join_nodes = decision.schedule.place(n_join) or \
        tuple(all_nodes[i % len(all_nodes)] for i in range(n_join))
    func = decision.func
    if consolidated:
        target = max(dist_f.bytes_per_node, key=dist_f.bytes_per_node.get) \
            if dist_f.bytes_per_node else all_nodes[0]
        join_nodes = (target,) * n_join
        func = "hash_join"
    pattern = exchange.func if exchange is not None else \
        ("shuffle" if func == "merge_join" else "broadcast")
    agg_nodes = (aggregate.schedule.place(n_join) or join_nodes) \
        if aggregate is not None and not consolidated else join_nodes

    stages = []
    if pattern == "shuffle":
        # hash distribution is all-to-all: every join bucket may hold rows
        # from every writer, so a join's inputs are complete only once ALL
        # shuffle writers committed
        writers = tuple([f"{app}/shuffle_fact/{i}" for i, _ in fact_layout] +
                        [f"{app}/shuffle_dim/{j}" for j, _ in dim_layout])
        stages += [
            RuntimeStage("shuffle_fact", [
                _inv(app, "shuffle_fact", i, "shuffle_write", node,
                     {"src": "scan_fact", "dst": "fact_buckets",
                      "partition": i, "num_buckets": n_join}, priority,
                     batchable=True, needs=(f"{app}/scan_fact/{i}",))
                for i, node in fact_layout], deps=("scan_fact",),
                decision="exchange"),
            RuntimeStage("shuffle_dim", [
                _inv(app, "shuffle_dim", j, "shuffle_write", node,
                     {"src": "scan_dim", "dst": "dim_buckets",
                      "partition": j, "num_buckets": n_join}, priority,
                     batchable=True, needs=(f"{app}/scan_dim/{j}",))
                for j, node in dim_layout], deps=("scan_dim",),
                decision="exchange"),
            RuntimeStage("join", [
                _inv(app, "join", r, "merge_join_partition", join_nodes[r],
                     {"fact_stage": "fact_buckets", "fact_partitions": [r],
                      "dim_stage": "dim_buckets", "dim_partitions": [r],
                      "dst": "joined", "partition": r,
                      "num_groups": num_groups, "plan": plan_mode},
                     priority, needs=writers)
                for r in range(n_join)],
                deps=("shuffle_fact", "shuffle_dim"),
                ephemeral_inputs=("fact_buckets", "dim_buckets"),
                decision="join"),
        ]
    else:
        bcast = tuple(f"{app}/broadcast_dim/{j}" for j, _ in dim_layout)
        stages += [
            RuntimeStage("broadcast_dim", [
                _inv(app, "broadcast_dim", j, "broadcast_write", node,
                     {"src": "scan_dim", "dst": "dim_bcast", "partition": j},
                     priority, batchable=True,
                     needs=(f"{app}/scan_dim/{j}",))
                for j, node in dim_layout], deps=("scan_dim",),
                decision="exchange"),
            RuntimeStage("join", [
                _inv(app, "join", k, "hash_join_partition", join_nodes[k],
                     {"fact_stage": "scan_fact",
                      "fact_partitions": [i for i, _ in fact_layout
                                          if i % n_join == k],
                      "dim_stage": "dim_bcast", "dim_partitions": "all",
                      "dst": "joined", "partition": k,
                      "num_groups": num_groups, "plan": plan_mode},
                     priority,
                     needs=bcast + tuple(
                         f"{app}/scan_fact/{i}" for i, _ in fact_layout
                         if i % n_join == k))
                for k in range(n_join)],
                deps=("scan_fact", "broadcast_dim"), decision="join"),
        ]

    stages += [
        RuntimeStage("partial_agg", [
            _inv(app, "partial_agg", k, "partial_aggregate", agg_nodes[k],
                 {"src": "joined", "dst": "partials", "partition": k,
                  "num_groups": num_groups}, priority, batchable=True,
                 needs=(f"{app}/join/{k}",))
            for k in range(n_join)], deps=("join",),
            ephemeral_inputs=("joined",), decision="aggregate"),
        RuntimeStage("final_agg", [
            _inv(app, "final_agg", 0, "final_aggregate", agg_nodes[0],
                 {"src": "partials", "dst": "result",
                  "num_groups": num_groups}, priority,
                 needs=tuple(f"{app}/partial_agg/{k}"
                             for k in range(n_join)))],
            deps=("partial_agg",), ephemeral_inputs=("partials",),
            decision="aggregate"),
    ]
    return stages


class AdaptiveQueryPlan:
    """Stage planner driving one ``WorkflowRun`` against the runtime.

    The DAG executor calls ``on_stage_complete`` as physical stages finish.
    Once both scan stages are done, the measured stage metrics and the
    observed post-filter distribution are folded into the workflow context,
    the join/exchange/aggregate decisions bind (late), and the tail of the
    physical plan is emitted — the paper's decide→execute→re-decide loop.
    """

    def __init__(self, run: WorkflowRun, app: str,
                 fact_layout: Sequence[tuple[int, int]],
                 dim_layout: Sequence[tuple[int, int]],
                 num_groups: int = 64, priority: int = 0):
        self.run = run
        self.app = app
        self.fact_layout = list(fact_layout)
        self.dim_layout = list(dim_layout)
        self.num_groups = num_groups
        self.priority = priority
        self._completed: set[str] = set()
        self._tail_planned = False

    def initial_stages(self) -> list:
        self.run.decide("scan")
        return scan_stages(self.app, self.fact_layout, self.dim_layout,
                           self.priority)

    def on_stage_complete(self, stage: str, runtime, pc=None) -> list:
        self._completed.add(stage)
        # The join decision needs only the *fact* side's observed post-filter
        # output (the dim side has no filter, its input dist is app
        # knowledge) — so the tail binds as soon as scan_fact lands, and
        # e.g. shuffle_fact overlaps a still-running scan_dim.
        if self._tail_planned or "scan_fact" not in self._completed:
            return []
        self._tail_planned = True
        # Fig. 5 step 4: fold observed output + metrics, then decide late.
        scanned = runtime.store.data_dist(self.app, "scan_fact",
                                          name="A_scanned")
        if pc is not None:
            pc.observe_data(scanned)
        self.run.observe(scanned)
        self.run.refresh_status(runtime.gc.node_status())
        self.run.feedback("scan",
                          runtime.metrics.profile_feedback(self.app))
        join_d = self.run.decide("join")
        exchange_d = self.run.decide("exchange")
        aggregate_d = self.run.decide("aggregate")
        pipeline_d = self.run.decide("pipeline")
        # elasticity: size the worker pool for the join fan-out about to
        # queue; on backends without a pool (threads, inline) the decision
        # still binds and is audited, it just has nothing to resize
        pool_size = getattr(runtime.invoker, "pool_size", None)
        elastic_d = decide_elastic(
            self.run, join_fanout(join_d),
            int(pool_size()) if callable(pool_size) else 0)
        resize = getattr(runtime.invoker, "resize", None)
        if callable(resize) and elastic_d.func != "hold":
            resize(int(elastic_d.scale))
        # tiering: price spill-vs-evict for the plan's ephemeral stages
        # against the store's cold tiers; the bound plan becomes the spill
        # policy reclaim/eviction consults. Stores without spill backends
        # (or apps without quotas) bind "keep" — today's behavior
        store = runtime.store
        tier_d = decide_tiering(
            self.run,
            ephemeral_stage_profile(scanned, self.run.ctx.data_dist["B"],
                                    join_d, exchange_d, self.num_groups),
            store.quota(self.app), store.storage_spec())
        if tier_d.func != "keep":
            store.set_spill_policy(self.app, dict(tier_d.extra("plan", ())))
        # consolidated join decisions already carry their packed placement,
        # so the materialization is exactly what the sequence records
        return tail_stages(
            self.app, self.fact_layout, self.dim_layout, join_d,
            self.run.ctx.data_dist["A"], num_groups=self.num_groups,
            priority=self.priority, exchange=exchange_d,
            aggregate=aggregate_d, pipeline=pipeline_d)


def stages_for_run(run: WorkflowRun, app: str,
                   fact_layout: Sequence[tuple[int, int]],
                   dim_layout: Sequence[tuple[int, int]],
                   num_groups: int = 64, priority: int = 0) -> list:
    """Materialize the full physical stage list from an already-bound
    ``WorkflowRun`` — the *static* twin of ``AdaptiveQueryPlan``'s
    incremental emission, used by the simulator-side fault model to predict
    recovery stage sets (``repro.runtime.lineage.expected_recovery``) for
    the exact plan the decisions imply."""
    return scan_stages(app, fact_layout, dim_layout, priority) + tail_stages(
        app, fact_layout, dim_layout, run.decisions["join"],
        run.ctx.data_dist["A"], num_groups=num_groups, priority=priority,
        exchange=run.decisions.get("exchange"),
        aggregate=run.decisions.get("aggregate"),
        pipeline=run.decisions.get("pipeline"))


# ---------------------------------------------------------------------------
# Simulator materialization: the same workflow -> SimTasks
# ---------------------------------------------------------------------------


def plan_query_with_workflow(sim, pc, fact, dim, strategy,
                             app: str = "query",
                             workflow: DecisionWorkflow | None = None,
                             consolidate_threshold: int | None = None,
                             scan_selectivity: float | None = None,
                             num_groups: int = 64,
                             storage_spec=None,
                             store_quota: int | None = None,
                             ) -> WorkflowRun:
    """Plan the TPC-DS-like sub-query into ``sim`` through the decision
    workflow; the scan stage's feedback is *estimated* (exactly, for
    materialized tables) instead of measured. ``storage_spec`` /
    ``store_quota`` mirror the runtime store's cold-tier specs and app
    quota into the tiering decision (default: the sim's own
    ``storage_spec``/``store_quotas`` attributes when set, else no tiers —
    matching a store without spill backends). Returns the ``WorkflowRun``
    whose decision sequence the submitted tasks materialize."""
    from repro.analytics.simulator import calibrated_rates

    rates = calibrated_rates()
    gc = pc.gc
    status = gc.node_status()
    nodes = sorted(status.total_slots)
    slots = max(status.total_slots.values())

    dist_f, dist_d = fact.data_dist(), dim.data_dist()
    pc.observe_data(dist_f)
    pc.observe_data(dist_d)
    wf = resolve_query_workflow(workflow, strategy, consolidate_threshold)
    ctx = DecisionContext(data_dist={"A": dist_f, "B": dist_d},
                          node_status=status, profile=dict(pc.profile))
    run = wf.start(ctx)
    run.app = app
    run.decide("scan")

    # simulate the scan stage: the estimated post-filter output distribution
    # is the feedback the late-bound join decision consumes
    scanned = estimate_scan_output(fact, selectivity=scan_selectivity)
    run.observe(scanned)
    run.feedback("scan", {"scan_fact.bytes_out": scanned.size,
                          "scan_fact.estimated": True})
    decision = run.decide("join")
    exchange_d = run.decide("exchange")
    run.decide("aggregate")
    run.decide("pipeline")
    # elasticity, through the same helper as the runtime plane: the sim's
    # cold-start model (when enabled) pre-warms on "grow" exactly where the
    # runtime resizes its process pool
    elastic_d = decide_elastic(run, join_fanout(decision), sim.pool_size()
                               if hasattr(sim, "pool_size") else 0)
    if elastic_d.func == "grow" and hasattr(sim, "prewarm"):
        sim.prewarm(int(elastic_d.scale), app)
    # tiering, through the same helper and the same plan-derived estimates
    # as the runtime plane (estimate_scan_output is exact for materialized
    # tables, so both planes price identical stage profiles)
    if storage_spec is None:
        storage_spec = getattr(sim, "storage_spec", None)
    if store_quota is None:
        store_quota = (getattr(sim, "store_quotas", None) or {}).get(app)
    decide_tiering(run,
                   ephemeral_stage_profile(scanned, dist_d, decision,
                                           exchange_d, num_groups),
                   store_quota, storage_spec)
    consolidated = bool(decision.extra("consolidate", False))

    _submit_sim_tasks(sim, app, dist_f, dist_d, scanned, decision,
                      consolidated, nodes, slots, rates)
    return run


def _submit_sim_tasks(sim, app, dist_f, dist_d, scanned, decision,
                      consolidated, nodes, slots, rates) -> None:
    from repro.analytics.simulator import SimTask

    # ---- scan phase 1: map over fact partitions (scan+filter+project) -----
    map1 = []
    if consolidated:
        # paper Fig. 7 (2 GB case): pack everything onto one node; the only
        # transfers are the initial partition pulls.
        target = max(dist_f.bytes_per_node, key=dist_f.bytes_per_node.get)
        n_tasks = min(slots, max(1, int(dist_f.size / ALPHA)))
        per = dist_f.size / n_tasks
        for i in range(n_tasks):
            src = nodes[i % len(nodes)]
            sim.submit(SimTask(
                f"{app}/map1/{i}", app, per / rates["scan"], node=target,
                priority=10,
                transfers={src: int(per)} if src != target else {}))
            map1.append(f"{app}/map1/{i}")
    else:
        n_tasks = max(1, int(dist_f.size / ALPHA))
        placement = Schedule("round-robin", tuple(nodes)).place(n_tasks)
        per = dist_f.size / n_tasks
        for i, node in enumerate(placement):
            data_node = nodes[i % len(nodes)]
            sim.submit(SimTask(
                f"{app}/map1/{i}", app, per / rates["scan"], node=node,
                priority=10,
                transfers={data_node: int(per)} if data_node != node else {}))
            map1.append(f"{app}/map1/{i}")

    # ---- scan phase 2: map over dim partitions ----------------------------
    map2 = []
    n_tasks2 = max(1, int(dist_d.size / ALPHA))
    place2 = Schedule("round-robin", tuple(sorted(dist_d.loc))).place(n_tasks2)
    per2 = dist_d.size / n_tasks2
    for i, node in enumerate(place2):
        sim.submit(SimTask(f"{app}/map2/{i}", app, per2 / rates["scan"],
                           node=node, priority=10))
        map2.append(f"{app}/map2/{i}")

    # ---- join phase: sized by the *post-scan* volume ----------------------
    join_nodes = decision.schedule.place(decision.scale) or tuple(nodes)
    n_join = len(join_nodes)
    per_join = scanned.size / n_join

    if consolidated:
        target = max(dist_f.bytes_per_node, key=dist_f.bytes_per_node.get)
        for i in range(min(slots, n_join)):
            sim.submit(SimTask(
                f"{app}/join/{i}", app,
                per_join / rates["hash_probe"]
                + dist_d.size / max(1, n_join) / rates["hash_build"],
                node=target, priority=10, deps=tuple(map1 + map2)))
    elif decision.func == "merge_join":
        # shuffle both sides by key: every join task pulls its hash range
        # from every map task's node (all-to-all), then sort-merges.
        for i, node in enumerate(join_nodes):
            pulls = {n: int((per_join + dist_d.size / n_join)
                            / max(1, len(nodes)))
                     for n in nodes if n != node}
            sim.submit(SimTask(
                f"{app}/join/{i}", app,
                (per_join + dist_d.size / n_join) / rates["merge_join"],
                node=node, priority=10, deps=tuple(map1 + map2),
                transfers=pulls))
    else:
        # hash join: broadcast the whole dim table once per *node* (senders =
        # dim's home nodes, serialized — the Fig. 4c effect); the first task
        # on a node builds the table, co-located tasks share it and probe.
        dim_homes = sorted(dist_d.loc) or nodes
        seen_nodes: set[int] = set()
        for i, node in enumerate(join_nodes):
            first_on_node = node not in seen_nodes
            seen_nodes.add(node)
            src = dim_homes[i % len(dim_homes)]
            pulls = {src: int(dist_d.size)} \
                if (first_on_node and src != node) else {}
            dur = per_join / rates["hash_probe"]
            if first_on_node:
                dur += dist_d.size / rates["hash_build"]
            sim.submit(SimTask(
                f"{app}/join/{i}", app, dur, node=node, priority=10,
                deps=tuple(map1 + map2), transfers=pulls))

    # ---- final aggregation ------------------------------------------------
    join_names = [t for t in sim.tasks if t.startswith(f"{app}/join/")]
    agg_node = join_nodes[0] if join_nodes else nodes[0]
    pulls = {n: int(scanned.size / max(1, n_join) / 16)
             for n in set(join_nodes) if n != agg_node}
    sim.submit(SimTask(f"{app}/agg", app,
                       scanned.size / 16 / rates["agg"], node=agg_node,
                       priority=10, deps=tuple(join_names),
                       transfers=pulls))
