"""TPC-DS-like sub-query (paper §6): two MapReduce phases + a Join phase.

    Q: SELECT d.cat, SUM(f.v0 * f.v1)
       FROM fact f JOIN dim d ON f.key = d.key
       WHERE f.v0 > 0
       GROUP BY d.cat

Execution under Proteus: one decision workflow per query (scan → join →
exchange → aggregate decision nodes, see ``repro.analytics.planner``) drives
both data planes. Decisions are **late-bound**: the join node is evaluated
only after the scan stage's runtime feedback — including the observed
post-filter fact distribution — has been folded into the context, so a
selective filter can flip the join variant mid-query. On the serverless
runtime the dependency-driven DAG executor interleaves decision evaluation
with stage completion through ``AdaptiveQueryPlan``; on the cluster
simulator the same workflow binds the same decision sequence against an
estimated scan output. ``execute_query_runtime`` and ``plan_query_tasks``
are thin wrappers over that shared machinery; ``execute_query_jax`` runs
the logical plan in-process for correctness tests against a numpy oracle.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.analytics import operators as ops
from repro.analytics.decisions import ALPHA
from repro.analytics.planner import (
    AdaptiveQueryPlan,
    plan_query_with_workflow,
    resolve_query_workflow as _resolve_workflow,
    scan_stages,
    tail_stages,
)
from repro.analytics.simulator import ClusterSim
from repro.analytics.table import DistTable, Table, distribute, synth_table
from repro.core.controllers import GlobalController, PrivateController
from repro.core.decisions import (
    DataDist,
    Decision,
    DecisionContext,
    DecisionWorkflow,
    Schedule,
)

def synth_query_tables(rows: int = 4096, dim_rows: int = 512,
                       keyspace: int | None = None, seed: int = 1,
                       fact_nodes=4, dim_nodes=2, num_groups: int = 64,
                       zipf: float = 0.0, heavy_hitters: int = 0,
                       ) -> tuple[DistTable, DistTable, np.ndarray]:
    """Synthetic fact/dim pair + numpy oracle for the TPC-DS-like sub-query.

    The one workload builder shared by benchmarks, examples and tests (the
    ``cat`` cardinality must match ``num_groups`` — keeping it here stops
    the copies drifting). ``fact_nodes``/``dim_nodes`` take a node count
    (placed on ``0..n-1``) or an explicit node iterable; the dim table uses
    ``seed + 1``. Returns ``(fact, dim, reference_sums)``.

    ``zipf=s`` draws fact keys from a Zipf(s) law over the keyspace (key
    ``r`` carries mass ``(r+1)^-s``); ``heavy_hitters=H`` routes ~half the
    rows to ``H`` seeded hot keys on top of whatever base law is active.
    Both are seeded and leave the default (``zipf=0, heavy_hitters=0``)
    fact table byte-identical to the uniform workload.
    """
    ks = keyspace if keyspace is not None else 2 * max(rows, dim_rows)
    if zipf or heavy_hitters:
        fact = _synth_skewed_fact(rows, ks, seed, zipf, heavy_hitters)
    else:
        fact = synth_table("f", rows, ks, seed=seed)
    dimc = synth_table("d", dim_rows, ks, seed=seed + 1, unique_keys=True)
    dim = Table({**dimc.columns,
                 "cat": jnp.arange(dim_rows, dtype=jnp.int32) % num_groups})
    ref = reference_query_numpy(fact, dim, num_groups=num_groups)
    fact_nodes = range(fact_nodes) if isinstance(fact_nodes, int) \
        else fact_nodes
    dim_nodes = range(dim_nodes) if isinstance(dim_nodes, int) else dim_nodes
    return (distribute(fact, fact_nodes, "A"),
            distribute(dim, dim_nodes, "B"), ref)


def zipf_weights(key_space: int, s: float) -> np.ndarray:
    """Normalized Zipf(s) mass over keys ``0..key_space-1`` (key ``r`` gets
    mass ``(r+1)^-s``). Shared by the generator and the tests that check
    the realized histogram against the requested law."""
    w = np.arange(1, int(key_space) + 1, dtype=np.float64) ** -float(s)
    return w / w.sum()


def _synth_skewed_fact(rows: int, key_space: int, seed: int,
                       zipf: float, heavy_hitters: int) -> Table:
    """Skewed twin of ``synth_table('f', ...)`` — same column recipe
    (int32 ``key``, float32 ``v0``/``v1``), different key law."""
    rng = np.random.default_rng(seed)
    if zipf:
        keys = rng.choice(int(key_space), size=rows,
                          p=zipf_weights(key_space, zipf))
    else:
        keys = rng.integers(0, key_space, size=rows)
    if heavy_hitters:
        h = int(heavy_hitters)
        hot = rng.permutation(int(key_space))[:h]
        mask = rng.random(rows) < 0.5
        keys = np.where(mask, hot[rng.integers(0, h, size=rows)], keys)
    cols = {"key": jnp.asarray(keys, jnp.int32)}
    for i in range(2):
        cols[f"v{i}"] = jnp.asarray(
            rng.standard_normal(rows, dtype=np.float32))
    return Table(cols)


@dataclass
class QueryStrategy:
    """S-M = static merge, S-H = static hash, DYN = decision workflow.

    "dynamic" is the refined cost-model decision node (paper Fig. 5 step 4);
    "dynamic_fig6" is the literal T1/T2 threshold node of Fig. 6. The
    strategy supplies the join node's decision function; everything else
    (late binding, per-phase nodes, materialization) is shared.
    """

    name: str   # static_merge | static_hash | dynamic | dynamic_fig6

    def join_method(self, ctx: DecisionContext) -> Decision:
        if self.name == "dynamic":
            from repro.analytics.decisions import cost_model_join_node
            return cost_model_join_node().decide(ctx)
        if self.name == "dynamic_fig6":
            from repro.analytics.decisions import join_decision
            return join_decision(ctx)
        func = "merge_join" if self.name == "static_merge" else "hash_join"
        dist_a, dist_b = ctx.data_dist["A"], ctx.data_dist["B"]
        nodes = tuple(sorted(dist_a.loc | dist_b.loc))
        scale = max(1, int((dist_a.size + dist_b.size) / ALPHA))
        return Decision(func, scale, Schedule("round-robin", nodes))


def resolve_join_decision(strategy: QueryStrategy, ctx: DecisionContext,
                          consolidate_threshold: int = 2 << 30,
                          ) -> tuple[Decision, bool]:
    """Compatibility shim: run the strategy's join choice once, up front.

    New code should build a workflow (``build_query_workflow``) so the join
    decision late-binds on observed scan output; this path exists for
    callers that make a single a-priori decision.
    """
    from repro.analytics.planner import consolidation_applies

    decision = strategy.join_method(ctx)
    total_bytes = sum(d.size for d in ctx.data_dist.values())
    return decision, consolidation_applies(
        strategy.name, decision, total_bytes, consolidate_threshold)


def plan_query_tasks(sim: ClusterSim, pc: PrivateController,
                     fact: DistTable, dim: DistTable,
                     strategy: QueryStrategy, app: str = "query",
                     consolidate_threshold: int | None = None,
                     workflow: DecisionWorkflow | None = None) -> None:
    """Emit the task DAG for the sub-query — thin wrapper over the
    workflow-driven planner (``plan_query_with_workflow``)."""
    plan_query_with_workflow(
        sim, pc, fact, dim, strategy, app=app, workflow=workflow,
        consolidate_threshold=consolidate_threshold)


# -- runtime execution: decisions -> real partitioned invocations ----------------


def plan_runtime_stages(app: str, fact_layout: Sequence[tuple[int, int]],
                        dim_layout: Sequence[tuple[int, int]],
                        decision: Decision, dist_f: DataDist,
                        consolidated: bool = False, num_groups: int = 64,
                        priority: int = 0) -> list:
    """Compatibility shim: materialize a single up-front join decision into
    the full physical stage list (scans + exchange + join + aggregation).
    The adaptive path builds the same stages incrementally via
    ``AdaptiveQueryPlan``."""
    return scan_stages(app, fact_layout, dim_layout, priority) + tail_stages(
        app, fact_layout, dim_layout, decision, dist_f,
        consolidated=consolidated, num_groups=num_groups, priority=priority)


def split_partitions(partitions, split: int) -> list:
    """Split each home node's partition into ``split`` row-range slices —
    the fine-grained ``[(node, table), ...]`` layout where a node hosts
    several map partitions (so the invoker's batch coalescing has same-node
    siblings to merge). Slices are ``TableSlice`` views: no copies until a
    scan reads them. The per-node byte totals — everything the decision
    nodes consume — are unchanged."""
    out = []
    for node, t in sorted(partitions.items()):
        k = max(1, min(int(split), t.num_rows or 1))
        bounds = np.linspace(0, t.num_rows, k + 1).astype(int)
        out.extend((node, t.slice(lo, hi))
                   for lo, hi in zip(bounds[:-1], bounds[1:]))
    return out


def prepare_query_plan(runtime, fact: DistTable, dim: DistTable,
                       strategy: QueryStrategy, app: str = "query",
                       priority: int = 10, num_groups: int = 64,
                       pc: PrivateController | None = None,
                       consolidate_threshold: int | None = None,
                       workflow: DecisionWorkflow | None = None,
                       map_split: int = 1, seed_tier: str | None = None,
                       reuse_inputs: bool = False,
                       ) -> tuple[AdaptiveQueryPlan, PrivateController]:
    """Planner entry point for a *named* application on a shared runtime.

    Observes the input distributions, opens the query's own late-bound
    ``WorkflowRun``, seeds the inputs into the shared store under ``app``'s
    namespace, and returns the ``AdaptiveQueryPlan`` (plus the private
    controller) ready for ``runtime.execute``. Several apps prepared against
    one runtime can then be driven concurrently — this is what
    ``repro.runtime.scheduler.QueryScheduler`` admits per query.

    ``map_split`` seeds each node's input as that many sub-partitions
    (``split_partitions``): map stages then run ``map_split`` invocations
    per node, which the invoker's batching coalesces back into one claim
    per node — the vectorized-data-plane benchmark knob.

    ``seed_tier`` ingests the inputs into a cold storage backend (e.g.
    ``"object"``) instead of memory — the Lambada cold-data scenario:
    first-touch scans read (and promote) through the emulated object
    store. ``reuse_inputs=True`` skips seeding when the store already
    holds the input stages (a warm re-query on the same runtime reads
    whatever tier the previous run left them in).
    """
    if pc is None:
        pc = PrivateController(app, runtime.gc, priority=priority)

    dist_f, dist_d = fact.data_dist(), dim.data_dist()
    pc.observe_data(dist_f)
    pc.observe_data(dist_d)
    wf = _resolve_workflow(workflow, strategy, consolidate_threshold)
    ctx = DecisionContext(
        data_dist={"A": dist_f, "B": dist_d},
        node_status=runtime.gc.node_status(), profile=dict(pc.profile))
    run = wf.start(ctx)
    run.app = app

    fact_parts = fact.partitions if map_split <= 1 \
        else split_partitions(fact.partitions, map_split)
    dim_parts = dim.partitions if map_split <= 1 \
        else split_partitions(dim.partitions, map_split)
    if reuse_inputs and runtime.store.stage_layout(app, "input/fact"):
        fact_layout = runtime.store.stage_layout(app, "input/fact")
        dim_layout = runtime.store.stage_layout(app, "input/dim")
    else:
        fact_layout = runtime.seed(app, "input/fact", fact_parts,
                                   tier=seed_tier)
        dim_layout = runtime.seed(app, "input/dim", dim_parts,
                                  tier=seed_tier)
    plan = AdaptiveQueryPlan(run, app, fact_layout, dim_layout,
                             num_groups=num_groups, priority=pc.priority)
    return plan, pc


def execute_query_runtime(fact: DistTable, dim: DistTable,
                          strategy: QueryStrategy, runtime=None,
                          gc: GlobalController | None = None,
                          pc: PrivateController | None = None,
                          app: str = "query", priority: int = 10,
                          num_groups: int = 64, invoker: str = "inline",
                          consolidate_threshold: int | None = None,
                          workflow: DecisionWorkflow | None = None,
                          barrier: bool = False, recovery="lineage",
                          max_recoveries: int = 8, batching: bool = True,
                          map_split: int = 1, pipeline: bool = False,
                          seed_tier: str | None = None,
                          reuse_inputs: bool = False):
    """Run the TPC-DS-like sub-query end-to-end on the serverless runtime.

    One decision workflow drives the whole query: the scan decision binds
    up front, the executor launches the (independent) scan stages, and when
    they complete the planner folds the observed post-filter distribution
    plus stage metrics back into the context and binds the join/exchange/
    aggregate decisions — the paper's interleaved decide→execute→re-decide
    loop. Pass ``workflow`` to share one workflow object across planners
    (e.g. with the simulator) and ``barrier=True`` to force the legacy
    stage-at-a-time executor. ``recovery``/``max_recoveries`` pick the
    failure-handling policy for lost shuffle stages (see ``DAGExecutor``).
    ``batching`` (only consulted when the runtime is built here) toggles
    the invoker's coalescing of batchable map invocations — the control
    plane sees identical decisions and metrics either way (tested).
    ``pipeline=True`` lets the executor honor the workflow's bound
    ``pipeline`` decision (partition-granularity launch + prefetch + fused
    probe); off, the same decision is still bound and audited but the
    stage barrier runs — decisions, record counts and results are
    identical either way (tested). Returns ``(group_sums, runtime)``.
    """
    from repro.runtime.executor import Runtime

    if runtime is None:
        if gc is None:
            nodes = sorted(set(fact.partitions) | set(dim.partitions))
            gc = GlobalController({n: 8 for n in nodes})
        runtime = Runtime(gc, invoker=invoker, batching=batching)
    plan, pc = prepare_query_plan(
        runtime, fact, dim, strategy, app=app, priority=priority,
        num_groups=num_groups, pc=pc,
        consolidate_threshold=consolidate_threshold, workflow=workflow,
        map_split=map_split, seed_tier=seed_tier, reuse_inputs=reuse_inputs)
    runtime.execute(plan.initial_stages(), pc=pc, planner=plan,
                    barrier=barrier, recovery=recovery,
                    max_recoveries=max_recoveries, pipeline=pipeline)
    return runtime.result(app), runtime


# -- real-data-plane execution (correctness path) --------------------------------


def execute_query_jax(fact: Table, dim: Table, method: str = "hash",
                      num_groups: int = 64) -> jnp.ndarray:
    """Run the logical query on the JAX data plane; returns per-group sums."""
    keep = fact["v0"] > 0
    filtered = ops.filter_table(fact, keep)
    joined = ops.join(filtered, dim, method=method)
    weights = jnp.where(joined["found"] & (joined["valid"] != 0),
                        joined["v0"] * joined["v1"], 0.0)
    group = joined["cat"].astype(jnp.int32) % num_groups
    return ops.groupby_sum(group, weights, num_groups)


def reference_query_numpy(fact: Table, dim: Table,
                          num_groups: int = 64) -> np.ndarray:
    """Pure-numpy oracle for tests."""
    fk = np.asarray(fact["key"])
    v0 = np.asarray(fact["v0"]).astype(np.float64)
    v1 = np.asarray(fact["v1"]).astype(np.float64)
    dk = np.asarray(dim["key"])
    cat = np.asarray(dim["cat"])
    lookup = {int(k): int(c) for k, c in zip(dk, cat)}
    out = np.zeros(num_groups)
    for k, a, b in zip(fk, v0, v1):
        if a > 0 and int(k) in lookup:
            out[lookup[int(k)] % num_groups] += a * b
    return out
