"""TPC-DS-like sub-query (paper §6): two MapReduce phases + a Join phase.

    Q: SELECT d.cat, SUM(f.v0 * f.v1)
       FROM fact f JOIN dim d ON f.key = d.key
       WHERE f.v0 > 0
       GROUP BY d.cat

Execution under Proteus: every phase is a decision node; the decision tuple
(func, scale, schedule) is turned into SimTasks for the cluster simulator,
with task durations taken from calibrated real-operator rates and shuffle
volumes from the actual table sizes. The ``dynamic`` strategy additionally
runs the paper's packing consolidation when the whole input fits one node.

``execute_query_jax`` runs the same logical plan for real on the in-process
JAX data plane (used by correctness tests against a numpy oracle), and
``execute_query_runtime`` runs it on the serverless function runtime
(``repro.runtime``): the decision tuple is materialized into real
partitioned function invocations — scan, shuffle-by-hash or broadcast,
per-partition hash/merge join, partial + final aggregation — over the
ephemeral shuffle store, with slot claims through the global controller.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.analytics import operators as ops
from repro.analytics.decisions import ALPHA, join_decision_node
from repro.analytics.simulator import ClusterSim, SimTask, calibrated_rates
from repro.analytics.table import DistTable, Table
from repro.core.controllers import GlobalController, PrivateController
from repro.core.decisions import DataDist, Decision, DecisionContext, Schedule

ROW_BYTES = 8  # key(4) + packed values, matching calibration units


@dataclass
class QueryStrategy:
    """S-M = static merge, S-H = static hash, DYN = decision workflow.

    "dynamic" is the refined cost-model decision node (paper Fig. 5 step 4);
    "dynamic_fig6" is the literal T1/T2 threshold node of Fig. 6.
    """

    name: str   # static_merge | static_hash | dynamic | dynamic_fig6

    def join_method(self, ctx: DecisionContext) -> Decision:
        if self.name == "dynamic":
            from repro.analytics.decisions import cost_model_join_node
            return cost_model_join_node().decide(ctx)
        if self.name == "dynamic_fig6":
            return join_decision_node().decide(ctx)
        func = "merge_join" if self.name == "static_merge" else "hash_join"
        dist_a, dist_b = ctx.data_dist["A"], ctx.data_dist["B"]
        nodes = tuple(sorted(dist_a.loc | dist_b.loc))
        scale = max(1, int((dist_a.size + dist_b.size) / ALPHA))
        return Decision(func, scale, Schedule("round-robin", nodes))


def resolve_join_decision(strategy: QueryStrategy, ctx: DecisionContext,
                          consolidate_threshold: int = 2 << 30,
                          ) -> tuple[Decision, bool]:
    """Run the strategy's decision node; returns (decision, consolidated).

    Shared by the simulator planner and the runtime planner so both data
    planes materialize the *same* decision tuple.
    """
    decision = strategy.join_method(ctx)
    total_bytes = sum(d.size for d in ctx.data_dist.values())
    consolidated = bool(decision.extra("consolidate", False)) or (
        strategy.name == "dynamic_fig6"
        and total_bytes <= consolidate_threshold)
    return decision, consolidated


def plan_query_tasks(sim: ClusterSim, pc: PrivateController,
                     fact: DistTable, dim: DistTable,
                     strategy: QueryStrategy, app: str = "query",
                     consolidate_threshold: int = 2 << 30) -> None:
    """Emit the task DAG for the sub-query under a strategy."""
    rates = calibrated_rates()
    gc = pc.gc
    status = gc.node_status()
    nodes = sorted(status.total_slots)
    slots = max(status.total_slots.values())

    dist_f, dist_d = fact.data_dist(), dim.data_dist()
    pc.observe_data(dist_f)
    pc.observe_data(dist_d)
    ctx = DecisionContext(
        data_dist={"A": dist_f, "B": dist_d},
        node_status=status)

    decision, consolidated = resolve_join_decision(
        strategy, ctx, consolidate_threshold)

    # ---- Phase 1: map over fact partitions (scan+filter+project) ----------
    map1 = []
    if consolidated:
        # paper Fig. 7 (2 GB case): pack everything onto one node; the only
        # transfers are the initial partition pulls.
        target = max(dist_f.bytes_per_node, key=dist_f.bytes_per_node.get)
        n_tasks = min(slots, max(1, int(dist_f.size / ALPHA)))
        per = dist_f.size / n_tasks
        for i in range(n_tasks):
            src = nodes[i % len(nodes)]
            sim.submit(SimTask(
                f"{app}/map1/{i}", app, per / rates["scan"], node=target,
                priority=10,
                transfers={src: int(per)} if src != target else {}))
            map1.append(f"{app}/map1/{i}")
    else:
        n_tasks = max(1, int(dist_f.size / ALPHA))
        placement = Schedule("round-robin", tuple(nodes)).place(n_tasks)
        per = dist_f.size / n_tasks
        for i, node in enumerate(placement):
            data_node = nodes[i % len(nodes)]
            sim.submit(SimTask(
                f"{app}/map1/{i}", app, per / rates["scan"], node=node,
                priority=10,
                transfers={data_node: int(per)} if data_node != node else {}))
            map1.append(f"{app}/map1/{i}")

    # ---- Phase 2: map over dim partitions ---------------------------------
    map2 = []
    n_tasks2 = max(1, int(dist_d.size / ALPHA))
    place2 = Schedule("round-robin", tuple(sorted(dist_d.loc))).place(n_tasks2)
    per2 = dist_d.size / n_tasks2
    for i, node in enumerate(place2):
        sim.submit(SimTask(f"{app}/map2/{i}", app, per2 / rates["scan"],
                           node=node, priority=10))
        map2.append(f"{app}/map2/{i}")

    # ---- Join phase: the Fig. 6 decision node ------------------------------
    join_nodes = decision.schedule.place(decision.scale) or tuple(nodes)
    n_join = len(join_nodes)
    per_join = dist_f.size / n_join

    if consolidated:
        target = max(dist_f.bytes_per_node, key=dist_f.bytes_per_node.get)
        for i in range(min(slots, n_join)):
            sim.submit(SimTask(
                f"{app}/join/{i}", app,
                per_join / rates["hash_probe"]
                + dist_d.size / max(1, n_join) / rates["hash_build"],
                node=target, priority=10, deps=tuple(map1 + map2)))
    elif decision.func == "merge_join":
        # shuffle both sides by key: every join task pulls its hash range
        # from every map task's node (all-to-all), then sort-merges.
        for i, node in enumerate(join_nodes):
            pulls = {n: int((per_join + dist_d.size / n_join)
                            / max(1, len(nodes)))
                     for n in nodes if n != node}
            sim.submit(SimTask(
                f"{app}/join/{i}", app,
                (per_join + dist_d.size / n_join) / rates["merge_join"],
                node=node, priority=10, deps=tuple(map1 + map2),
                transfers=pulls))
    else:
        # hash join: broadcast the whole dim table once per *node* (senders =
        # dim's home nodes, serialized — the Fig. 4c effect); the first task
        # on a node builds the table, co-located tasks share it and probe.
        dim_homes = sorted(dist_d.loc) or nodes
        seen_nodes: set[int] = set()
        for i, node in enumerate(join_nodes):
            first_on_node = node not in seen_nodes
            seen_nodes.add(node)
            src = dim_homes[i % len(dim_homes)]
            pulls = {src: int(dist_d.size)} \
                if (first_on_node and src != node) else {}
            dur = per_join / rates["hash_probe"]
            if first_on_node:
                dur += dist_d.size / rates["hash_build"]
            sim.submit(SimTask(
                f"{app}/join/{i}", app, dur, node=node, priority=10,
                deps=tuple(map1 + map2), transfers=pulls))

    # ---- Final aggregation --------------------------------------------------
    join_names = [t for t in sim.tasks if t.startswith(f"{app}/join/")]
    agg_node = join_nodes[0] if join_nodes else nodes[0]
    pulls = {n: int(dist_f.size / max(1, n_join) / 16)
             for n in set(join_nodes) if n != agg_node}
    sim.submit(SimTask(f"{app}/agg", app,
                       dist_f.size / 16 / rates["agg"], node=agg_node,
                       priority=10, deps=tuple(join_names),
                       transfers=pulls))


# -- runtime execution: decisions -> real partitioned invocations ----------------


def plan_runtime_stages(app: str, fact_layout: Sequence[tuple[int, int]],
                        dim_layout: Sequence[tuple[int, int]],
                        decision: Decision, dist_f: DataDist,
                        consolidated: bool = False, num_groups: int = 64,
                        priority: int = 0) -> "list[RuntimeStage]":
    """Materialize a decision tuple into the physical stage DAG.

    The layouts are ``[(partition, home_node), ...]`` as returned by
    ``Runtime.seed``. The decision's ``func`` picks the exchange pattern
    (merge_join => hash-shuffle both sides; hash_join => broadcast the dim
    side), its ``scale`` sets the join fan-out and its ``schedule`` places
    the join instances — scans stay data-local regardless (the decision
    workflow governs the *join* group, as in the paper's Fig. 6).
    """
    from repro.runtime.executor import RuntimeStage
    from repro.runtime.invoker import Invocation

    all_nodes = tuple(sorted({n for _, n in fact_layout} |
                             {n for _, n in dim_layout}))
    n_join = max(1, min(int(decision.scale), 64))
    join_nodes = decision.schedule.place(n_join) or \
        tuple(all_nodes[i % len(all_nodes)] for i in range(n_join))
    func = decision.func
    if consolidated:
        # pack the whole pipeline onto the data-heaviest node: the only
        # cross-node traffic left is the initial partition pulls
        target = max(dist_f.bytes_per_node, key=dist_f.bytes_per_node.get) \
            if dist_f.bytes_per_node else all_nodes[0]
        join_nodes = (target,) * n_join
        func = "hash_join"

    def inv(stage, i, fn, node, params):
        return Invocation(f"{app}/{stage}/{i}", app, stage, i, fn, node,
                          priority=priority, params=params)

    stages = [
        RuntimeStage("scan_fact", [
            inv("scan_fact", i, "scan_filter", node,
                {"src": "input/fact", "dst": "scan_fact", "partition": i,
                 "filter_col": "v0", "filter_gt": 0.0})
            for i, node in fact_layout]),
        RuntimeStage("scan_dim", [
            inv("scan_dim", j, "scan_filter", node,
                {"src": "input/dim", "dst": "scan_dim", "partition": j})
            for j, node in dim_layout]),
    ]

    if func == "merge_join":
        stages += [
            RuntimeStage("shuffle_fact", [
                inv("shuffle_fact", i, "shuffle_write", node,
                    {"src": "scan_fact", "dst": "fact_buckets",
                     "partition": i, "num_buckets": n_join})
                for i, node in fact_layout], deps=("scan_fact",)),
            RuntimeStage("shuffle_dim", [
                inv("shuffle_dim", j, "shuffle_write", node,
                    {"src": "scan_dim", "dst": "dim_buckets",
                     "partition": j, "num_buckets": n_join})
                for j, node in dim_layout], deps=("scan_dim",)),
            RuntimeStage("join", [
                inv("join", r, "merge_join_partition", join_nodes[r],
                    {"fact_stage": "fact_buckets", "fact_partitions": [r],
                     "dim_stage": "dim_buckets", "dim_partitions": [r],
                     "dst": "joined", "partition": r,
                     "num_groups": num_groups})
                for r in range(n_join)],
                deps=("shuffle_fact", "shuffle_dim"),
                ephemeral_inputs=("fact_buckets", "dim_buckets")),
        ]
    else:
        stages += [
            RuntimeStage("broadcast_dim", [
                inv("broadcast_dim", j, "broadcast_write", node,
                    {"src": "scan_dim", "dst": "dim_bcast", "partition": j})
                for j, node in dim_layout], deps=("scan_dim",)),
            RuntimeStage("join", [
                inv("join", k, "hash_join_partition", join_nodes[k],
                    {"fact_stage": "scan_fact",
                     "fact_partitions": [i for i, _ in fact_layout
                                         if i % n_join == k],
                     "dim_stage": "dim_bcast", "dim_partitions": "all",
                     "dst": "joined", "partition": k,
                     "num_groups": num_groups})
                for k in range(n_join)],
                deps=("scan_fact", "broadcast_dim")),
        ]

    stages += [
        RuntimeStage("partial_agg", [
            inv("partial_agg", k, "partial_aggregate", join_nodes[k],
                {"src": "joined", "dst": "partials", "partition": k,
                 "num_groups": num_groups})
            for k in range(n_join)], deps=("join",),
            ephemeral_inputs=("joined",)),
        RuntimeStage("final_agg", [
            inv("final_agg", 0, "final_aggregate", join_nodes[0],
                {"src": "partials", "dst": "result",
                 "num_groups": num_groups})],
            deps=("partial_agg",), ephemeral_inputs=("partials",)),
    ]
    return stages


def execute_query_runtime(fact: DistTable, dim: DistTable,
                          strategy: QueryStrategy, runtime=None,
                          gc: GlobalController | None = None,
                          pc: PrivateController | None = None,
                          app: str = "query", priority: int = 10,
                          num_groups: int = 64, invoker: str = "inline",
                          consolidate_threshold: int = 2 << 30):
    """Run the TPC-DS-like sub-query end-to-end on the serverless runtime.

    Decisions come from the same strategy nodes the simulator planner uses;
    here they drive *real* partitioned invocations through the store +
    invoker. Returns ``(group_sums, runtime)`` — the runtime keeps the
    metrics/trace for inspection or simulator replay.
    """
    from repro.runtime.executor import Runtime

    if runtime is None:
        if gc is None:
            nodes = sorted(set(fact.partitions) | set(dim.partitions))
            gc = GlobalController({n: 8 for n in nodes})
        runtime = Runtime(gc, invoker=invoker)
    if pc is None:
        pc = PrivateController(app, runtime.gc, priority=priority)

    dist_f, dist_d = fact.data_dist(), dim.data_dist()
    pc.observe_data(dist_f)
    pc.observe_data(dist_d)
    ctx = DecisionContext(
        data_dist={"A": dist_f, "B": dist_d},
        node_status=runtime.gc.node_status(), profile=dict(pc.profile))
    decision, consolidated = resolve_join_decision(
        strategy, ctx, consolidate_threshold)

    fact_layout = runtime.seed(app, "input/fact", fact.partitions)
    dim_layout = runtime.seed(app, "input/dim", dim.partitions)
    stages = plan_runtime_stages(app, fact_layout, dim_layout, decision,
                                 dist_f, consolidated=consolidated,
                                 num_groups=num_groups, priority=pc.priority)
    runtime.execute(stages, pc=pc)
    # feed the observed scan output distribution back into app knowledge so
    # the next decision sees post-filter sizes, not raw input sizes
    pc.observe_data(runtime.store.data_dist(app, "scan_fact",
                                            name="A_scanned"))
    return runtime.result(app), runtime


# -- real-data-plane execution (correctness path) --------------------------------


def execute_query_jax(fact: Table, dim: Table, method: str = "hash",
                      num_groups: int = 64) -> jnp.ndarray:
    """Run the logical query on the JAX data plane; returns per-group sums."""
    keep = fact["v0"] > 0
    filtered = ops.filter_table(fact, keep)
    joined = ops.join(filtered, dim, method=method)
    weights = jnp.where(joined["found"] & (joined["valid"] != 0),
                        joined["v0"] * joined["v1"], 0.0)
    group = joined["cat"].astype(jnp.int32) % num_groups
    return ops.groupby_sum(group, weights, num_groups)


def reference_query_numpy(fact: Table, dim: Table,
                          num_groups: int = 64) -> np.ndarray:
    """Pure-numpy oracle for tests."""
    fk = np.asarray(fact["key"])
    v0 = np.asarray(fact["v0"]).astype(np.float64)
    v1 = np.asarray(fact["v1"]).astype(np.float64)
    dk = np.asarray(dim["key"])
    cat = np.asarray(dim["cat"])
    lookup = {int(k): int(c) for k, c in zip(dk, cat)}
    out = np.zeros(num_groups)
    for k, a, b in zip(fk, v0, v1):
        if a > 0 and int(k) in lookup:
            out[lookup[int(k)] % num_groups] += a * b
    return out
