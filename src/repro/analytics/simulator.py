"""Discrete-event cluster simulator for the serverless control plane.

Reproduces the paper's evaluation environment (6–20 node clusters of
c5.2xlarge-like machines: 8 function slots/node, ~1.25 GB/s NIC) without the
EC2 cluster: *compute* rates are calibrated from real timings of the JAX
operators in ``repro.analytics.operators``; *network* transfers occupy source
and destination NICs (so hash-join broadcast saturates senders as the cluster
grows — Fig. 4c — and mis-placed functions pay remote-read costs — Fig. 4e).

Slot accounting goes through the real ``GlobalController`` (Omega-style
commits + priority preemption), so Fig. 8's fine-grained sharing runs the
actual control plane, not a model of it. Task DAGs for the paper's query
come from the same decision workflow that drives the serverless runtime
(``repro.analytics.planner``), so simulated and real plans materialize
identical decision sequences.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping, Sequence

from repro.core.controllers import Claim, ConflictError, GlobalController

DEFAULT_NET_BW = 1.25e9        # bytes/s per node NIC (10 Gbps)
DEFAULT_SLOTS = 8              # vCPUs per c5.2xlarge


@dataclass
class SimTask:
    name: str
    app: str
    duration: float                         # compute seconds (one slot)
    node: int | None = None                 # None = any node (flexible)
    deps: tuple[str, ...] = ()
    priority: int = 0
    # bytes to pull from each source node before compute starts
    transfers: Mapping[int, int] = field(default_factory=dict)
    started: float = -1.0
    finished: float = -1.0


@dataclass
class Timeline:
    samples: list = field(default_factory=list)   # (t, used, total)

    def record(self, t: float, used: int, total: int):
        self.samples.append((t, used, total))

    def allocation_rate(self, t0: float = 0.0, t1: float | None = None):
        """Time-weighted mean used/total over [t0, t1]."""
        if not self.samples:
            return 0.0
        pts = sorted(self.samples)
        t1 = t1 if t1 is not None else pts[-1][0]
        area = 0.0
        for (ta, ua, tot), (tb, _, _) in zip(pts, pts[1:] + [(t1, 0, 1)]):
            lo, hi = max(ta, t0), min(tb, t1)
            if hi > lo and tot:
                area += (hi - lo) * ua / tot
        return area / max(t1 - t0, 1e-9)


class ClusterSim:
    """Event-driven simulator; one slot per task, NICs serialize transfers.

    Failure models (mirroring ``repro.runtime.faults``): ``straggle`` adds
    per-node latency to tasks started there — either ``{node: delay}``
    (every task on the node, unbounded) or scoped entries ``(node, delay,
    task_family | None, times | None)`` matching the runtime injector's
    stage filter and firing bound; overlapping entries combine by max, as
    in ``FaultInjector.before_body``. ``crash_plan`` maps task names to a
    number of failures — a crashed task occupies its slot for the full
    duration, then releases it and re-enters the ready set (the runtime
    invoker's crash-retry, priced in sim time). ``reexecutions`` counts the
    extra runs.

    Cold-start economics (twin of the ``repro.runtime.workers`` pool,
    active when ``provision_s > 0``): each task start consumes a warm
    worker — LIFO, reaped after ``idle_reap_s`` idle — or pays a
    ``provision_s`` cold start before compute begins. ``prewarm`` (the
    elasticity decision's grow path) provisions workers up front and bills
    their cold starts immediately. ``fn_seconds`` is the per-app
    function-seconds cost proxy matching ``WorkerPool.
    cost_function_seconds``: busy compute + provision charges, with NIC
    transfer time excluded (the store bills that separately).
    """

    def __init__(self, gc: GlobalController, net_bw: float = DEFAULT_NET_BW,
                 straggle=None, crash_plan: Mapping[str, int] | None = None,
                 provision_s: float = 0.0, warm_pool: int = 0,
                 idle_reap_s: float | None = None,
                 storage_spec: Mapping[str, Mapping] | None = None,
                 store_quotas: Mapping[str, int] | None = None):
        self.gc = gc
        self.net_bw = net_bw
        # storage-tier twin: mirrors ShuffleStore.storage_spec() and the
        # per-app quotas so the tiering decision binds identically to the
        # runtime plane (empty = a store without spill backends)
        self.storage_spec = dict(storage_spec or {})
        self.store_quotas = dict(store_quotas or {})
        if isinstance(straggle, Mapping):
            entries = [(n, d, None, None) for n, d in straggle.items()]
        else:
            entries = [tuple(e) for e in (straggle or ())]
        # mutable: the last slot counts remaining firings (None = unbounded)
        self._stragglers = [[n, d, fam, times]
                            for n, d, fam, times in entries]
        self.crash_plan = dict(crash_plan or {})
        self.reexecutions = 0
        self.tasks: dict[str, SimTask] = {}
        self.done: set[str] = set()
        self.now = 0.0
        self.nic_free_send = {n: 0.0 for n in gc.total}
        self.nic_free_recv = {n: 0.0 for n in gc.total}
        self.timeline = Timeline()
        self.app_finish: dict[str, float] = {}
        self.app_cost: dict[str, float] = {}
        self._events: list = []
        self._counter = itertools.count()
        self._running: dict[str, Claim] = {}
        # -- cold-start / warm-pool model (inert when provision_s == 0) ----
        self.provision_s = float(provision_s)
        self.idle_reap_s = idle_reap_s
        self._warm: list[float] = [0.0] * int(warm_pool)   # idle-since times
        self.pool = int(warm_pool)        # provisioned workers (warm + busy)
        self.cold_starts = 0
        self.warm_hits = 0
        self.reaped = 0
        self.fn_seconds: dict[str, float] = {}

    # -- submission ----------------------------------------------------------

    def submit(self, task: SimTask):
        assert task.name not in self.tasks
        self.tasks[task.name] = task

    def submit_all(self, tasks: Iterable[SimTask]):
        for t in tasks:
            self.submit(t)

    # -- cold-start / warm-pool model ------------------------------------------

    def pool_size(self) -> int:
        """Provisioned workers (warm + busy) — the elastic node's input."""
        return self.pool

    def prewarm(self, target: int, app: str = "query"):
        """Grow the pool to ``target`` ahead of demand (elastic "grow"):
        each new worker's provision charge is billed to ``app`` now, so the
        fan-out that follows leases warm. Shrinking just lowers the idle
        floor — the reaper retires the surplus as it expires. Inert when
        cold starts aren't modeled (``provision_s<=0``): the pool must then
        stay at 0 so ``pool_size()`` matches a pool-less runtime invoker
        and shared-workflow decision sequences agree across planes."""
        if self.provision_s <= 0:
            return
        grow = int(target) - self.pool
        for _ in range(max(0, grow)):
            self.pool += 1
            self.cold_starts += 1
            self._warm.append(self.now)
            if self.provision_s > 0:
                self.fn_seconds[app] = \
                    self.fn_seconds.get(app, 0.0) + self.provision_s

    def _reap_idle(self):
        if self.idle_reap_s is None:
            return
        while self._warm and self.now - self._warm[0] > self.idle_reap_s:
            self._warm.pop(0)
            self.pool -= 1
            self.reaped += 1

    def _lease_worker(self, app: str) -> float:
        """Lease a warm worker (0 extra latency) or cold-start one
        (``provision_s`` latency, billed to ``app``). Inert when the model
        is disabled."""
        if self.provision_s <= 0:
            return 0.0
        self._reap_idle()
        if self._warm:
            self._warm.pop()          # LIFO: most-recently-idle first
            self.warm_hits += 1
            return 0.0
        self.pool += 1
        self.cold_starts += 1
        self.fn_seconds[app] = \
            self.fn_seconds.get(app, 0.0) + self.provision_s
        return self.provision_s

    def _return_worker(self):
        if self.provision_s <= 0:
            return
        self._warm.append(self.now)
        self._reap_idle()

    # -- engine ----------------------------------------------------------------

    def _ready(self, task: SimTask) -> bool:
        return task.started < 0 and all(d in self.done for d in task.deps)

    def _transfer_time(self, task: SimTask, dst: int) -> float:
        """Serialize on src-send and dst-recv NICs; returns completion time."""
        start = self.now
        end = start
        for src, nbytes in sorted(task.transfers.items()):
            if src == dst or nbytes <= 0:
                continue
            t0 = max(self.nic_free_send[src], self.nic_free_recv[dst], start)
            dt = nbytes / self.net_bw
            self.nic_free_send[src] = t0 + dt
            self.nic_free_recv[dst] = t0 + dt
            end = max(end, t0 + dt)
        return end

    def _try_start(self):
        # priority-ordered ready tasks (the global controller arbitrates)
        ready = sorted(
            (t for t in self.tasks.values() if self._ready(t)),
            key=lambda t: (-t.priority, t.name))
        for task in ready:
            status = self.gc.node_status()
            if task.node is not None:
                candidates = [task.node]
            else:  # flexible: most-free node first (backfill)
                candidates = sorted(
                    status.free_slots, key=lambda n: -status.free_slots[n])
            for node in candidates:
                if status.free_slots.get(node, 0) <= 0:
                    continue
                try:
                    claim = self.gc.commit(task.app, task.priority, [node],
                                           tag=task.name)
                except ConflictError:
                    continue
                ready_at = self._transfer_time(task, node)
                ready_at += self._lease_worker(task.app)
                task.started = self.now
                finish = ready_at + task.duration + \
                    self._straggle_delay(task.name, node)
                self._running[task.name] = claim
                heapq.heappush(self._events,
                               (finish, next(self._counter), task.name))
                self.app_cost[task.app] = self.app_cost.get(task.app, 0.0) \
                    + (finish - self.now)
                self.fn_seconds[task.app] = \
                    self.fn_seconds.get(task.app, 0.0) + (finish - ready_at)
                break
        self._sample()

    def _straggle_delay(self, name: str, node: int) -> float:
        """Injected latency for one task start: scoped entries match the
        task's family (``app/<family>/i``), decrement their firing budget,
        and combine by max — the runtime injector's semantics."""
        family = name.split("/")[1] if name.count("/") >= 2 else None
        delay = 0.0
        for entry in self._stragglers:
            s_node, s_delay, s_fam, s_times = entry
            if s_node != node:
                continue
            if s_fam is not None and s_fam != family:
                continue
            if s_times is not None:
                if s_times <= 0:
                    continue
                entry[3] = s_times - 1
            delay = max(delay, s_delay)
        return delay

    def _sample(self):
        used = sum(self.gc.used.values())
        total = sum(self.gc.total.values())
        self.timeline.record(self.now, used, total)

    def run(self, until: float | None = None) -> dict:
        self._try_start()
        while self._events:
            t, _, name = heapq.heappop(self._events)
            if until is not None and t > until:
                self.now = until
                break
            self.now = t
            task = self.tasks[name]
            if self.crash_plan.get(name, 0) > 0:
                # injected crash: the run burned its slot-time but commits
                # nothing; the task re-enters the ready set (crash-retry)
                self.crash_plan[name] -= 1
                self.reexecutions += 1
                task.started = -1.0
                self.gc.release(self._running.pop(name))
                if self.provision_s > 0:
                    self.pool -= 1    # crashed worker died with its task
                self._try_start()
                continue
            task.finished = t
            self.done.add(name)
            self.gc.release(self._running.pop(name))
            self._return_worker()
            self.app_finish[task.app] = max(
                self.app_finish.get(task.app, 0.0), t)
            self._try_start()
        self._sample()
        return {
            "completion": dict(self.app_finish),
            "cost_slot_seconds": dict(self.app_cost),
            "cost_function_seconds": dict(self.fn_seconds),
            "allocation": self.timeline,
        }


def make_cluster(num_nodes: int, slots: int = DEFAULT_SLOTS,
                 net_bw: float = DEFAULT_NET_BW, straggle=None,
                 crash_plan: Mapping[str, int] | None = None,
                 provision_s: float = 0.0, warm_pool: int = 0,
                 idle_reap_s: float | None = None,
                 ) -> tuple[GlobalController, ClusterSim]:
    gc = GlobalController({n: slots for n in range(num_nodes)})
    return gc, ClusterSim(gc, net_bw, straggle=straggle,
                          crash_plan=crash_plan, provision_s=provision_s,
                          warm_pool=warm_pool, idle_reap_s=idle_reap_s)


# Runtime physical stage -> simulator task family (the sim plans the query
# as map/join/agg phases; exchange stages have no separate sim task).
_SIM_STAGE_MAP = {"scan_fact": "map1", "scan_dim": "map2", "join": "join",
                  "final_agg": "agg"}


def sim_fault_models(plan, app: str = "query") -> tuple[list, dict]:
    """Map a ``repro.runtime.faults.FaultPlan`` onto the simulator's
    failure models: ``(straggle_entries, crash_plan)`` for ``ClusterSim``.

    Straggler entries keep the plan's stage scope (mapped to the sim task
    family) and firing bound; stage-scoped stragglers and crashes naming a
    runtime stage without a simulator task family (the exchange writes,
    ``partial_agg``) are dropped — the sim folds those phases into its
    join/agg tasks. A crash with ``index=None`` (any instance) pins to
    instance 0 — the sim replays a *specific* schedule, not a matcher.
    Stage *loss* is not a timing model at all: its simulator-side twin is
    the static recovery prediction (``repro.runtime.lineage.
    expected_recovery``), which the differential test checks against the
    runtime's actual recovery events.
    """
    straggle = [(s.node, s.delay,
                 _SIM_STAGE_MAP.get(s.stage) if s.stage else None, s.times)
                for s in plan.stragglers
                if s.stage is None or s.stage in _SIM_STAGE_MAP]
    crash: dict[str, int] = {}
    for c in plan.crashes:
        fam = _SIM_STAGE_MAP.get(c.stage)
        if fam is None:
            continue
        idx = c.index if c.index is not None else 0
        name = f"{app}/{fam}/{idx}" if fam != "agg" else f"{app}/agg"
        crash[name] = crash.get(name, 0) + c.times
    return straggle, crash


# -- calibration ------------------------------------------------------------------


_RATE_CACHE: dict[str, float] = {}


def calibrated_rates(sample_rows: int = 1 << 18, force: bool = False) -> dict:
    """Measure real bytes/s of the JAX operators on this host (used as the
    simulator's per-slot compute rates). Cached per process."""
    if _RATE_CACHE and not force:
        return dict(_RATE_CACHE)
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analytics import operators as ops

    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, sample_rows, sample_rows), jnp.int32)
    bkeys = jnp.asarray(rng.permutation(sample_rows)[: sample_rows // 4],
                        jnp.int32)
    nbytes = sample_rows * 8.0

    def timeit(fn, *args):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 3

    slots_tbl = ops.build_hash_table(bkeys)
    _RATE_CACHE.update({
        "scan": nbytes / timeit(
            lambda k: jnp.sum(jnp.where(k % 3 == 0, k, 0)), keys),
        "sort": nbytes / timeit(lambda k: jnp.sort(k), keys),
        "hash_build": (bkeys.shape[0] * 8.0) / timeit(
            ops.build_hash_table, bkeys),
        "hash_probe": nbytes / timeit(
            ops.hash_join_indices, keys, bkeys, slots_tbl),
        "merge_join": nbytes / timeit(
            ops.sort_merge_join_indices, keys, bkeys),
        "agg": nbytes / timeit(
            lambda k: ops.groupby_sum(k % 1024,
                                      jnp.ones_like(k, jnp.float32), 1024),
            keys),
    })
    return dict(_RATE_CACHE)
