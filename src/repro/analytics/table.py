"""Distributed tables for the serverless-analytics case study.

A ``Table`` is a dict of equal-length columns (jnp arrays). A
``DistTable`` is a table partitioned across cluster nodes (the paper's
per-node data distribution), carrying the per-node byte counts that decision
nodes consume as ``data_dist`` (Fig. 6 input).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decisions import DataDist, partition_skew


@dataclass
class Table:
    columns: dict

    def __post_init__(self):
        lens = {k: v.shape[0] for k, v in self.columns.items()}
        assert len(set(lens.values())) <= 1, lens

    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).shape[0] \
            if self.columns else 0

    @property
    def nbytes(self) -> int:
        return sum(int(np.prod(v.shape)) * v.dtype.itemsize
                   for v in self.columns.values())

    def select(self, *names: str) -> "Table":
        return Table({n: self.columns[n] for n in names})

    def __getitem__(self, name: str):
        return self.columns[name]

    def take(self, idx) -> "Table":
        return Table({k: jnp.take(v, idx, axis=0)
                      for k, v in self.columns.items()})

    def mask(self, keep) -> "Table":
        idx = jnp.nonzero(keep, size=int(np.sum(np.asarray(keep))))[0]
        return self.take(idx)

    def concat(self, other: "Table") -> "Table":
        return Table.concat_all([self, other])

    @staticmethod
    def concat_all(parts: Sequence) -> "Table":
        """Multi-way concatenation: ONE ``jnp.concatenate`` per column.

        The pairwise ``a.concat(b).concat(c)...`` chain is O(P²) in copied
        bytes across P parts; this is the single-pass replacement — the one
        concat helper — used by ``DistTable.gather``, the shuffle store's
        multi-writer reads, ``FnContext.get_all`` and the join functions'
        ``_read_side``. Accepts ``TableSlice`` views (materialized here,
        where the copy is amortized into the final buffer anyway) and falls
        back to the pairwise ``concat`` protocol for duck-typed stand-ins
        without ``columns`` (test fakes).
        """
        parts = [p for p in parts]
        if not parts:
            raise ValueError("concat_all of no parts")
        if len(parts) == 1:
            p = parts[0]
            mat = getattr(p, "materialize", None)
            return mat() if mat is not None else p
        if all(hasattr(p, "columns") for p in parts):
            names = list(parts[0].columns)
            cols = {}
            for k in names:
                vals = [p.columns[k] for p in parts]
                if all(isinstance(v, np.ndarray) for v in vals):
                    # host-resident parts (the shuffle store's bucket views)
                    # concatenate as one memcpy — no XLA program per distinct
                    # (part-count, shapes) combination
                    cols[k] = np.concatenate(vals)
                else:
                    cols[k] = jnp.concatenate(vals)
            return Table(cols)
        out = parts[0]
        for p in parts[1:]:
            out = out.concat(p)
        return out

    def slice(self, lo: int, hi: int) -> "TableSlice":
        """A row-range view sharing this table's column buffers."""
        return TableSlice(self.columns, int(lo), int(hi))


class TableSlice:
    """A lazy row-range view of a parent table's columns.

    The single-pass shuffle writes every bucket of a partition from one
    device-side permutation: each bucket is a ``TableSlice`` over the
    permuted parent columns, so publishing P buckets costs zero copies at
    write time — the parent buffer is shared, and a column is materialized
    (one device slice) only when a reader first touches it. ``nbytes`` and
    ``num_rows`` are computed from the range alone, so store byte
    accounting, quotas and tombstones see exactly the numbers a
    materialized copy would produce.
    """

    def __init__(self, parent_columns: Mapping, lo: int, hi: int):
        assert 0 <= lo <= hi
        # (columns, lo, hi) lives in ONE tuple so concurrent readers (e.g.
        # a speculation backup and its original reading the same blob)
        # always see a consistent snapshot — materialization republishes
        # the tuple with a single atomic rebind, never mutates it
        self._src: tuple = (dict(parent_columns), lo, hi)
        self.num_rows = hi - lo
        self._row_nbytes = sum(int(np.prod(v.shape[1:])) * v.dtype.itemsize
                               for v in parent_columns.values())
        self._cache: dict | None = None

    @property
    def parent_columns(self) -> dict:
        return self._src[0]

    @property
    def lo(self) -> int:
        return self._src[1]

    @property
    def hi(self) -> int:
        return self._src[2]

    @property
    def nbytes(self) -> int:
        return self._row_nbytes * self.num_rows

    @property
    def columns(self) -> dict:
        cache = self._cache
        if cache is None:
            parent, lo, hi = self._src      # one consistent snapshot
            cache = {k: v[lo:hi] for k, v in parent.items()}
            self._cache = cache
            # materialized: drop the pin on the (full-size) parent buffer so
            # the slice's real device footprint matches the ``nbytes`` the
            # store accounts — once every sibling slice materializes, the
            # parent is collectable (racing readers built identical caches
            # from their own snapshots; last writer wins harmlessly)
            self._src = (cache, 0, self.num_rows)
        return cache

    def materialize(self) -> Table:
        return Table(dict(self.columns))

    def select(self, *names: str) -> "Table":
        return self.materialize().select(*names)

    def __getitem__(self, name: str):
        return self.columns[name]

    def take(self, idx) -> "Table":
        return self.materialize().take(idx)

    def mask(self, keep) -> "Table":
        return self.materialize().mask(keep)

    def concat(self, other) -> "Table":
        return Table.concat_all([self, other])


@dataclass
class DistTable:
    """A table partitioned over cluster nodes."""

    name: str
    partitions: dict[int, Table] = field(default_factory=dict)  # node -> part

    @property
    def num_rows(self) -> int:
        return sum(p.num_rows for p in self.partitions.values())

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes for p in self.partitions.values())

    def data_dist(self) -> DataDist:
        per_node = {n: p.nbytes for n, p in self.partitions.items()}
        skew = partition_skew(p.num_rows for p in self.partitions.values())
        return DataDist(self.name, per_node, rows=self.num_rows, skew=skew)

    def gather(self) -> Table:
        """All partitions as one table — a single multi-way concatenation
        per column (was O(P²) pairwise)."""
        return Table.concat_all(
            [p for _, p in sorted(self.partitions.items())])


def synth_table(name: str, rows: int, key_space: int, seed: int = 0,
                distribution: str = "uniform", pareto_a: float = 1.2,
                value_cols: int = 2, unique_keys: bool = False) -> Table:
    """Synthetic table generator (uniform or Pareto-skewed keys)."""
    rng = np.random.default_rng(seed)
    if unique_keys:
        assert rows <= key_space
        keys = rng.permutation(key_space)[:rows]
    elif distribution == "uniform":
        keys = rng.integers(0, key_space, size=rows)
    elif distribution == "pareto":
        raw = rng.pareto(pareto_a, size=rows)
        keys = np.minimum((raw / (raw.max() + 1e-9) * key_space),
                          key_space - 1).astype(np.int64)
    else:
        raise ValueError(distribution)
    cols = {"key": jnp.asarray(keys, jnp.int32)}
    for i in range(value_cols):
        cols[f"v{i}"] = jnp.asarray(
            rng.standard_normal(rows, dtype=np.float32))
    return Table(cols)


@dataclass
class PhantomTable:
    """Size-only stand-in for GB-scale simulator experiments (the paper's
    400 MB–6 GB tables): carries the data distribution without materializing
    arrays. Quacks like DistTable for planning purposes."""

    name: str
    bytes_per_node: Mapping[int, int]
    skew: float = 1.0

    @property
    def nbytes(self) -> int:
        return sum(self.bytes_per_node.values())

    def data_dist(self) -> DataDist:
        return DataDist(self.name, dict(self.bytes_per_node),
                        rows=self.nbytes // 8, skew=self.skew)


def phantom(name: str, total_bytes: int, nodes: Sequence[int],
            distribution: str = "uniform", pareto_a: float = 1.2,
            seed: int = 0) -> PhantomTable:
    nodes = list(nodes)
    if distribution == "uniform":
        share = np.full(len(nodes), 1.0 / len(nodes))
    elif distribution == "pareto":
        rng = np.random.default_rng(seed)
        raw = rng.pareto(pareto_a, size=len(nodes)) + 0.05
        share = raw / raw.sum()
    else:
        raise ValueError(distribution)
    per = {n: int(total_bytes * s) for n, s in zip(nodes, share)}
    skew = float(max(share) / (sum(share) / len(share)))
    return PhantomTable(name, per, skew)


def distribute(table: Table, nodes: Sequence[int], name: str,
               by: str = "round-robin", seed: int = 0) -> DistTable:
    n = table.num_rows
    order = np.arange(n)
    if by == "random":
        order = np.random.default_rng(seed).permutation(n)
    chunks = np.array_split(order, len(nodes))
    parts = {node: table.take(jnp.asarray(c))
             for node, c in zip(nodes, chunks)}
    return DistTable(name, parts)
