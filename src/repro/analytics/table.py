"""Distributed tables for the serverless-analytics case study.

A ``Table`` is a dict of equal-length columns (jnp arrays). A
``DistTable`` is a table partitioned across cluster nodes (the paper's
per-node data distribution), carrying the per-node byte counts that decision
nodes consume as ``data_dist`` (Fig. 6 input).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decisions import DataDist, partition_skew


@dataclass
class Table:
    columns: dict

    def __post_init__(self):
        lens = {k: v.shape[0] for k, v in self.columns.items()}
        assert len(set(lens.values())) <= 1, lens

    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).shape[0] \
            if self.columns else 0

    @property
    def nbytes(self) -> int:
        return sum(int(np.prod(v.shape)) * v.dtype.itemsize
                   for v in self.columns.values())

    def select(self, *names: str) -> "Table":
        return Table({n: self.columns[n] for n in names})

    def __getitem__(self, name: str):
        return self.columns[name]

    def take(self, idx) -> "Table":
        return Table({k: jnp.take(v, idx, axis=0)
                      for k, v in self.columns.items()})

    def mask(self, keep) -> "Table":
        idx = jnp.nonzero(keep, size=int(np.sum(np.asarray(keep))))[0]
        return self.take(idx)

    def concat(self, other: "Table") -> "Table":
        return Table({k: jnp.concatenate([v, other.columns[k]])
                      for k, v in self.columns.items()})


@dataclass
class DistTable:
    """A table partitioned over cluster nodes."""

    name: str
    partitions: dict[int, Table] = field(default_factory=dict)  # node -> part

    @property
    def num_rows(self) -> int:
        return sum(p.num_rows for p in self.partitions.values())

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes for p in self.partitions.values())

    def data_dist(self) -> DataDist:
        per_node = {n: p.nbytes for n, p in self.partitions.items()}
        skew = partition_skew(p.num_rows for p in self.partitions.values())
        return DataDist(self.name, per_node, rows=self.num_rows, skew=skew)

    def gather(self) -> Table:
        parts = [p for _, p in sorted(self.partitions.items())]
        out = parts[0]
        for p in parts[1:]:
            out = out.concat(p)
        return out


def synth_table(name: str, rows: int, key_space: int, seed: int = 0,
                distribution: str = "uniform", pareto_a: float = 1.2,
                value_cols: int = 2, unique_keys: bool = False) -> Table:
    """Synthetic table generator (uniform or Pareto-skewed keys)."""
    rng = np.random.default_rng(seed)
    if unique_keys:
        assert rows <= key_space
        keys = rng.permutation(key_space)[:rows]
    elif distribution == "uniform":
        keys = rng.integers(0, key_space, size=rows)
    elif distribution == "pareto":
        raw = rng.pareto(pareto_a, size=rows)
        keys = np.minimum((raw / (raw.max() + 1e-9) * key_space),
                          key_space - 1).astype(np.int64)
    else:
        raise ValueError(distribution)
    cols = {"key": jnp.asarray(keys, jnp.int32)}
    for i in range(value_cols):
        cols[f"v{i}"] = jnp.asarray(
            rng.standard_normal(rows, dtype=np.float32))
    return Table(cols)


@dataclass
class PhantomTable:
    """Size-only stand-in for GB-scale simulator experiments (the paper's
    400 MB–6 GB tables): carries the data distribution without materializing
    arrays. Quacks like DistTable for planning purposes."""

    name: str
    bytes_per_node: Mapping[int, int]
    skew: float = 1.0

    @property
    def nbytes(self) -> int:
        return sum(self.bytes_per_node.values())

    def data_dist(self) -> DataDist:
        return DataDist(self.name, dict(self.bytes_per_node),
                        rows=self.nbytes // 8, skew=self.skew)


def phantom(name: str, total_bytes: int, nodes: Sequence[int],
            distribution: str = "uniform", pareto_a: float = 1.2,
            seed: int = 0) -> PhantomTable:
    nodes = list(nodes)
    if distribution == "uniform":
        share = np.full(len(nodes), 1.0 / len(nodes))
    elif distribution == "pareto":
        rng = np.random.default_rng(seed)
        raw = rng.pareto(pareto_a, size=len(nodes)) + 0.05
        share = raw / raw.sum()
    else:
        raise ValueError(distribution)
    per = {n: int(total_bytes * s) for n, s in zip(nodes, share)}
    skew = float(max(share) / (sum(share) / len(share)))
    return PhantomTable(name, per, skew)


def distribute(table: Table, nodes: Sequence[int], name: str,
               by: str = "round-robin", seed: int = 0) -> DistTable:
    n = table.num_rows
    order = np.arange(n)
    if by == "random":
        order = np.random.default_rng(seed).permutation(n)
    chunks = np.array_split(order, len(nodes))
    parts = {node: table.take(jnp.asarray(c))
             for node, c in zip(nodes, chunks)}
    return DistTable(name, parts)
