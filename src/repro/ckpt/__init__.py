"""Checkpointing, restart supervision, elastic rescaling."""

from repro.ckpt.checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.ckpt.supervisor import StragglerEvent, Supervisor  # noqa: F401
