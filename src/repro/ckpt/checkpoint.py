"""Fault-tolerant checkpointing: atomic, keep-K, async, mesh-agnostic.

Layout per step::

    <dir>/step_000123.tmp/     (written)    -> atomic rename ->
    <dir>/step_000123/
        manifest.json          tree structure, shapes, dtypes, logical axes
        leaf_00000.npy ...     one file per pytree leaf (full, unsharded)

Checkpoints store *unsharded* arrays plus the logical-axis tree, so a restore
may target a different mesh shape than the save (elastic rescaling: the
restore path re-applies the current ShardingRules). An async writer thread
keeps the train loop off the I/O path; ``wait()`` drains it (called before
exit and by tests).
"""

from __future__ import annotations

import json
import queue
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

_SENTINEL = object()


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str | Path, step: int, state: Any,
                    keep: int = 3, extra: dict | None = None) -> Path:
    """Synchronous atomic save."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step:09d}.tmp"
    final = directory / f"step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat, treedef = _flatten_with_paths(state)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(flat),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomicity point
    _cleanup(directory, keep)
    return final


def _cleanup(directory: Path, keep: int):
    steps = sorted(p for p in directory.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for old in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(old, ignore_errors=True)
    for stale in directory.glob("step_*.tmp"):
        shutil.rmtree(stale, ignore_errors=True)


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    steps = sorted(p.name for p in directory.glob("step_*")
                   if p.is_dir() and not p.name.endswith(".tmp"))
    return int(steps[-1].split("_")[1]) if steps else None


def load_checkpoint(directory: str | Path, step: int | None = None,
                    like: Any = None, shardings: Any = None) -> tuple[Any,
                                                                      dict]:
    """Restore (state, extra). If ``like`` (a pytree) is given, the restored
    arrays are unflattened into its structure; ``shardings`` (same structure,
    NamedSharding leaves or None) re-shards onto the *current* mesh — this is
    the elastic-rescale path."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = directory / f"step_{step:09d}"
    manifest = json.loads((path / "manifest.json").read_text())
    leaves = [np.load(path / f"leaf_{i:05d}.npy")
              for i in range(manifest["num_leaves"])]
    if like is None:
        return leaves, manifest["extra"]
    _, treedef = jax.tree.flatten(like)
    state = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        flat_s, _ = jax.tree.flatten(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "spec"))
        flat_v, treedef = jax.tree.flatten(state)
        placed = [
            jax.device_put(v, s) if s is not None else jax.numpy.asarray(v)
            for v, s in zip(flat_v, flat_s)
        ]
        state = jax.tree.unflatten(treedef, placed)
    return state, manifest["extra"]


class AsyncCheckpointer:
    """Background writer thread; the train loop enqueues host copies."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._errors: list[Exception] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                return
            step, state, extra = item
            try:
                save_checkpoint(self.directory, step, state, self.keep,
                                extra)
            except Exception as e:  # noqa: BLE001 - surfaced via .errors
                self._errors.append(e)
            finally:
                self._q.task_done()

    def save(self, step: int, state: Any, extra: dict | None = None):
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self._q.put((step, host_state, extra))

    def wait(self):
        self._q.join()
        if self._errors:
            raise self._errors[-1]

    def close(self):
        self.wait()
        self._q.put(_SENTINEL)
        self._thread.join(timeout=10)
