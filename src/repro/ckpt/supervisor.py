"""Training supervisor: restart-on-failure, straggler watchdog, elastic hooks.

The supervisor owns the outer loop of a production run:

  * checkpoint every K steps (async), restore-from-latest on any step
    failure (simulating node loss — tests inject faults),
  * per-step wall-time watchdog: steps slower than ``straggler_factor`` x the
    trailing median are recorded as straggler events and surfaced to a
    re-layout decision node (the control-plane hook: at scale the decision
    is typically "checkpoint + restart without the slow host"),
  * elastic rescale: because checkpoints are mesh-agnostic (full arrays +
    logical axes), ``resume(new_mesh_rules)`` re-shards onto a different
    mesh — the restart-smaller/-larger path for node failures/additions.
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    load_checkpoint,
)
from repro.core.decisions import Decision, DecisionContext, DecisionNode, \
    Schedule


@dataclass
class StragglerEvent:
    step: int
    seconds: float
    median: float


def relayout_decision(ctx: DecisionContext) -> Decision:
    """Default straggler response: if slowdowns persist, restart from the
    last checkpoint excluding the slow node (scale-down by one)."""
    events = ctx.profile.get("straggler_events", 0)
    nodes = tuple(ctx.node_status.total_slots)
    if events >= 3:
        return Decision("restart_excluding_stragglers", max(1, len(nodes) - 1),
                        Schedule("round-robin", nodes[:-1] or nodes))
    return Decision("continue", len(nodes), Schedule("round-robin", nodes))


@dataclass
class Supervisor:
    step_fn: Callable[[Any, Any], tuple[Any, dict]]
    batch_fn: Callable[[int], Any]
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    max_restarts: int = 5

    step_times: list[float] = field(default_factory=list)
    stragglers: list[StragglerEvent] = field(default_factory=list)
    restarts: int = 0
    relayout_node: DecisionNode = field(
        default_factory=lambda: DecisionNode("relayout", relayout_decision))

    def run(self, state: Any, num_steps: int, start_step: int = 0,
            fault_hook: Callable[[int], None] | None = None) -> tuple[Any,
                                                                      int]:
        """Run ``num_steps`` with checkpoint/restart. Returns (state, step).

        ``fault_hook(step)`` may raise to simulate node failure; the
        supervisor restores the latest checkpoint and continues.
        """
        ckpt = AsyncCheckpointer(self.ckpt_dir, keep=self.keep)
        step = start_step
        like = state
        try:
            while step < num_steps:
                try:
                    if fault_hook is not None:
                        fault_hook(step)
                    t0 = time.perf_counter()
                    batch = self.batch_fn(step)
                    state, metrics = self.step_fn(state, batch)
                    dt = time.perf_counter() - t0
                    self._watch(step, dt)
                    step += 1
                    if step % self.ckpt_every == 0:
                        ckpt.save(step, state, {"step": step})
                except KeyboardInterrupt:
                    raise
                except Exception:  # noqa: BLE001 - node-failure path
                    self.restarts += 1
                    if self.restarts > self.max_restarts:
                        raise
                    ckpt.wait()
                    restored = latest_step(self.ckpt_dir)
                    if restored is None:
                        # no checkpoint yet: restart from the initial state
                        step = start_step
                        continue
                    state, extra = load_checkpoint(self.ckpt_dir, like=like)
                    step = extra.get("step", restored)
            ckpt.save(step, state, {"step": step})
            ckpt.wait()
        finally:
            ckpt.close()
        return state, step

    def _watch(self, step: int, dt: float):
        self.step_times.append(dt)
        window = self.step_times[-21:-1]
        if len(window) >= 5:
            med = statistics.median(window)
            # ignore sub-50ms jitter: straggler detection targets real steps
            if dt > self.straggler_factor * med and dt > 0.05:
                self.stragglers.append(StragglerEvent(step, dt, med))
