"""Version-tolerant JAX API shims.

The codebase targets the current ``jax.shard_map`` surface
(``axis_names=...``, ``check_vma=...``); older installs only ship
``jax.experimental.shard_map.shard_map`` with the pre-rename kwargs
(``auto=...``, ``check_rep=...``). Route every call through here so modules
never probe jax versions themselves.
"""

from __future__ import annotations

import jax

#: True when only the experimental pre-rename shard_map is available. Its
#: ``auto=`` partial-manual mode is incomplete there (PartitionId lowering
#: is unimplemented under SPMD), so pipeline-parallel paths gate on this.
LEGACY_SHARD_MAP = not hasattr(jax, "shard_map")
if LEGACY_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _shard_map
else:
    _shard_map = jax.shard_map


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict (older jax returns a
    one-element list of per-computation dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def set_mesh(mesh):
    """Context manager pinning the global mesh: ``jax.set_mesh`` on new jax;
    on older releases ``Mesh`` itself is the context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(axis_name):
    """``jax.lax.axis_size`` (static int on new jax; a unit-psum — still
    correct in any arithmetic use — where the API predates it)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=None):
    """``jax.shard_map`` with old/new kwarg spellings papered over."""
    kw = {}
    if LEGACY_SHARD_MAP:
        if check_vma is not None:
            kw["check_rep"] = check_vma
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            if auto:
                kw["auto"] = auto
    else:
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
