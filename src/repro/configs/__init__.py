"""Architecture registry: the 10 assigned archs (+ the paper's analytics
workload config lives in repro/analytics)."""

from __future__ import annotations

import importlib

from repro.core.config import ModelConfig

ARCH_IDS = (
    "qwen1.5-4b",
    "mistral-nemo-12b",
    "llama3.2-3b",
    "qwen2-72b",
    "internvl2-1b",
    "xlstm-1.3b",
    "moonshot-v1-16b-a3b",
    "granite-moe-1b-a400m",
    "musicgen-medium",
    "jamba-v0.1-52b",
)

_MODULES = {
    "qwen1.5-4b": "qwen1_5_4b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen2-72b": "qwen2_72b",
    "internvl2-1b": "internvl2_1b",
    "xlstm-1.3b": "xlstm_1_3b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "musicgen-medium": "musicgen_medium",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    mod = _module(arch_id)
    return mod.smoke_config() if smoke else mod.full_config()


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
