"""Shared helpers for architecture configs: input specs per shape cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, **no device allocation**) for every model input of a given
(arch x shape) cell — the dry-run lowers against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import Frontend, ModelConfig, ShapeConfig
from repro.models.lm import AUDIO_FRAME_DIM


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for train/prefill inputs or the decode token batch."""
    b = shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32

    if shape.mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}

    specs: dict = {}
    if cfg.frontend == Frontend.VISION_STUB.value:
        n_text = s - cfg.stub_patches
        assert n_text > 0
        specs["tokens"] = jax.ShapeDtypeStruct((b, n_text), i32)
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.stub_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    elif cfg.frontend == Frontend.AUDIO_STUB.value:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["frame_embeds"] = jax.ShapeDtypeStruct(
            (b, s, AUDIO_FRAME_DIM), jnp.dtype(cfg.dtype))
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)

    if shape.mode == "train":
        # labels align with text positions (== tokens shape; for the VLM
        # stub the patch positions carry no loss)
        specs["labels"] = jax.ShapeDtypeStruct(specs["tokens"].shape, i32)
    return specs


def concrete_inputs(cfg: ModelConfig, shape: ShapeConfig, key=None) -> dict:
    """Small real arrays matching input_specs (for smoke tests)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    out = {}
    for name, spec in input_specs(cfg, shape).items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(spec.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, spec.shape, 0,
                                           max(2, cfg.vocab_size - 1),
                                           spec.dtype)
        else:
            out[name] = jax.random.normal(sub, spec.shape, spec.dtype)
    return out


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Shape cells that apply to this arch (long_500k: sub-quadratic only)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names
