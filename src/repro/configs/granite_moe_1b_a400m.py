"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8)
d_ff(expert)=512, MoE 32 experts top-8, vocab=49155 (padded for TP).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Tiny experts: the MoE dispatch decision node tends to pick the *gather*
(hash-join/broadcast) strategy here — the broadcast side is cheap.
"""

from repro.core.config import FFNKind, ModelConfig, MoEConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        ffn=FFNKind.MOE,
        moe=MoEConfig(num_experts=32, top_k=8, d_expert=512),
        rope_theta=1e4,
        family="moe",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=64,
        vocab_size=512,
        ffn=FFNKind.MOE,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64),
        family="moe",
    )
