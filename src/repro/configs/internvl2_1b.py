"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 (padded to 151680 for TP divisibility; logged), InternViT
frontend stubbed as precomputed patch embeddings. [arXiv:2404.16821; hf]
"""

from repro.core.config import Frontend, ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        rope_theta=1e6,
        max_position=32768,
        frontend=Frontend.VISION_STUB.value,
        stub_patches=256,
        family="vlm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        rope_theta=1e6,
        frontend=Frontend.VISION_STUB.value,
        stub_patches=8,   # reduced stub for CPU smoke shapes
        family="vlm",
    )
