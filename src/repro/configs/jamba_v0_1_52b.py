"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba+attention 1:7 interleave, MoE 16 experts top-2 on every
second layer. [arXiv:2403.19887; hf]

Hybrid: eligible for long_500k (Mamba states are O(1)/token; the 1:7
attention layers decode linearly against a mesh-sharded KV cache).
Note: the published Jamba uses no explicit positional encoding; we keep RoPE
on the attention layers (recorded deviation, does not change shapes/FLOPs).
"""

from repro.core.config import FFNKind, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        ffn=FFNKind.MOE,
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336,
                      every_k_layers=2),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        block_pattern=("mamba", "mamba", "mamba", "attention",
                       "mamba", "mamba", "mamba", "mamba"),
        rope_theta=1e6,
        family="hybrid",
        sub_quadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        ffn=FFNKind.MOE,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128,
                      every_k_layers=2),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
        block_pattern=("mamba", "attention"),
        rope_theta=1e6,
        family="hybrid",
        sub_quadratic=True,
    )
