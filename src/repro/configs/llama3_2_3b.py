"""llama3.2-3b [dense] — 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256, small llama3. [hf:meta-llama/Llama-3.2-1B family; unverified]

24 heads do not divide the model axis (16) — seq_tp attention strategy.
"""

from repro.core.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        num_layers=28,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=5e5,
        max_position=131072,
        tie_embeddings=True,
        family="dense",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke",
        num_layers=2,
        d_model=48,
        num_heads=6,
        num_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab_size=512,
        rope_theta=5e5,
        tie_embeddings=True,
        family="dense",
    )
