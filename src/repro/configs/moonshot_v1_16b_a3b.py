"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H d_ff(expert)=1408
vocab=163840, MoE 64 experts top-6 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""

from repro.core.config import FFNKind, ModelConfig, MoEConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=163840,
        ffn=FFNKind.MOE,
        moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408),
        rope_theta=5e6,
        family="moe",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=96,
        vocab_size=512,
        ffn=FFNKind.MOE,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=96),
        family="moe",
    )
