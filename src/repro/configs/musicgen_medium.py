"""musicgen-medium [audio] — 48L d_model=1536 24H d_ff=6144 vocab=2048,
decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only: the EnCodec frontend is a stub providing precomputed frame
embeddings via input_specs(); 24 heads -> seq_tp attention strategy.
"""

from repro.core.config import Frontend, ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        rope_theta=1e4,
        frontend=Frontend.AUDIO_STUB.value,
        family="audio",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        rope_theta=1e4,
        frontend=Frontend.AUDIO_STUB.value,
        family="audio",
    )
