"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-0.5B family; hf]

20 heads do not divide the model axis (16) — the attention-strategy decision
node selects seq_tp (sequence-sharded residual + KV broadcast).
"""

from repro.core.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        num_layers=40,
        d_model=2560,
        num_heads=20,
        num_kv_heads=20,
        d_ff=6912,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=5e6,
        max_position=32768,
        family="dense",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=160,
        vocab_size=512,
        qkv_bias=True,
        rope_theta=5e6,
        family="dense",
    )
