"""qwen2-72b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, QKV bias. [arXiv:2407.10671; hf]

The largest assigned arch: the scale decision node raises microbatch
accumulation so the train_4k cell fits HBM.
"""

from repro.core.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
        max_position=131072,
        family="dense",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        head_dim=8,
        d_ff=192,
        vocab_size=512,
        qkv_bias=True,
        rope_theta=1e6,
        family="dense",
    )
