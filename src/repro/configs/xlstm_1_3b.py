"""xlstm-1.3b [ssm] — 48L d_model=2048 4H, sLSTM + mLSTM blocks (7:1),
no separate FFN (d_ff=0), vocab=50304. [arXiv:2405.04517; unverified]

Attention-free: eligible for the long_500k decode cell (O(1)/token state).
"""

from repro.core.config import FFNKind, ModelConfig, XLSTMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        num_layers=48,
        d_model=2048,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        ffn=FFNKind.NONE,
        xlstm=XLSTMConfig(slstm_every=8),
        block_pattern=("mlstm",) * 7 + ("slstm",),
        family="ssm",
        sub_quadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        ffn=FFNKind.NONE,
        xlstm=XLSTMConfig(slstm_every=2),
        block_pattern=("mlstm", "slstm"),
        family="ssm",
        sub_quadratic=True,
    )
