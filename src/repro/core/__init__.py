"""Proteus-JAX core: decision workflows + decentralized control plane.

The paper's primary contribution — an extensible serverless control plane —
is implemented here as: decision nodes/workflows (config-time and run-time
control decisions), and a decentralized controller pair (global resource view
+ per-application private controllers with Omega-style priority commits).
"""

from .config import (  # noqa: F401
    BlockKind,
    CheckpointConfig,
    FFNKind,
    Frontend,
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    ParallelConfig,
    RunConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
    fingerprint,
    override,
    replace,
)
from .decisions import (  # noqa: F401
    DataDist,
    Decision,
    DecisionContext,
    DecisionNode,
    DecisionWorkflow,
    LateBindingError,
    NodeStatus,
    Schedule,
    Stage,
    WorkflowRun,
    default_node,
)
from .controllers import (  # noqa: F401
    Claim,
    ConflictError,
    GlobalController,
    PrivateController,
)
