"""Typed configuration system for Proteus-JAX.

Configs are frozen dataclasses so they can be used as static arguments to
``jax.jit`` and as keys of the executable cache (the warm-container analogue
of the paper). ``ModelConfig`` carries the architecture definition;
``ShapeConfig`` carries one of the assigned input-shape cells; ``RunConfig``
bundles everything a launcher needs.
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence


class BlockKind(str, enum.Enum):
    """Kinds of residual blocks the unified LM stack can interleave."""

    ATTENTION = "attention"
    MAMBA = "mamba"
    MLSTM = "mlstm"
    SLSTM = "slstm"


class FFNKind(str, enum.Enum):
    DENSE = "dense"          # SwiGLU MLP
    MOE = "moe"              # top-k routed experts
    NONE = "none"            # block has no separate FFN (e.g. xLSTM)


class Frontend(str, enum.Enum):
    TOKENS = "tokens"        # plain token ids
    VISION_STUB = "vision"   # precomputed patch embeddings + token ids
    AUDIO_STUB = "audio"     # precomputed EnCodec frame embeddings / codec tokens


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                    # per-expert FFN hidden size
    every_k_layers: int = 1          # MoE applied every k-th block (Jamba: 2)
    router_jitter: float = 0.0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                  # d_inner = expand * d_model
    dt_rank: int = 0                 # 0 => ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8             # every k-th block is sLSTM, rest mLSTM
    conv_kernel: int = 4
    qk_dim_factor: float = 0.5
    v_dim_factor: float = 1.0
    proj_factor: float = 2.0         # pre-up-projection factor for mLSTM


@dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads
    ffn: FFNKind = FFNKind.DENSE
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None
    # layout: pattern of block kinds tiled over num_layers, e.g.
    # ("attention",) for dense, ("mamba",)*7 + ("attention",) for Jamba 1:7.
    block_pattern: tuple[str, ...] = (BlockKind.ATTENTION.value,)
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    frontend: str = Frontend.TOKENS.value
    stub_patches: int = 256          # VLM stub frontend patch count
    max_position: int = 131072
    dtype: str = "bfloat16"
    # Families: "dense" | "moe" | "ssm" | "hybrid" | "vlm" | "audio"
    family: str = "dense"
    sub_quadratic: bool = False      # eligible for long_500k decode

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def block_kind(self, layer: int) -> BlockKind:
        return BlockKind(self.block_pattern[layer % len(self.block_pattern)])

    def layer_is_moe(self, layer: int) -> bool:
        if self.ffn != FFNKind.MOE or self.moe is None:
            return False
        return layer % self.moe.every_k_layers == (self.moe.every_k_layers - 1)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        hd = self.resolved_head_dim
        for layer in range(self.num_layers):
            kind = self.block_kind(layer)
            if kind == BlockKind.ATTENTION:
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
                if self.qkv_bias:
                    total += (self.num_heads + 2 * self.num_kv_heads) * hd
            elif kind == BlockKind.MAMBA:
                ssm = self.ssm or SSMConfig()
                d_in = ssm.expand * d
                dt_rank = ssm.dt_rank or -(-d // 16)
                total += d * 2 * d_in            # in_proj
                total += d_in * ssm.d_conv + d_in  # conv w + b
                total += d_in * (dt_rank + 2 * ssm.d_state)  # x_proj
                total += dt_rank * d_in + d_in   # dt_proj
                total += d_in * ssm.d_state      # A_log
                total += d_in                    # D
                total += d_in * d                # out_proj
            elif kind == BlockKind.MLSTM:
                x = self.xlstm or XLSTMConfig()
                d_in = int(x.proj_factor * d)
                qk = int(x.qk_dim_factor * d_in)
                h = self.num_heads
                total += 2 * d * d_in            # up proj (2 branches)
                total += d_in * x.conv_kernel + d_in
                total += 2 * d_in * qk           # wq, wk
                total += d_in * d_in             # wv
                total += d_in * 2 * h + 2 * h    # i/f gates
                total += d_in                    # head norm
                total += d_in * d                # down proj
            elif kind == BlockKind.SLSTM:
                x = self.xlstm or XLSTMConfig()
                d_in = int(x.proj_factor * d)
                h = self.num_heads
                dv = d_in // h
                total += 2 * d * d_in            # up proj
                total += d_in * x.conv_kernel + d_in
                total += d_in * 4 * d_in + 4 * d_in  # w_gates + b
                total += 4 * h * dv * dv         # block-diag recurrence
                total += d_in                    # head norm
                total += d_in * d                # down proj
            # FFN
            if self.layer_is_moe(layer):
                assert self.moe is not None
                total += d * self.moe.num_experts * 3 * self.moe.d_expert
                total += d * self.moe.num_experts  # router
            elif self.ffn != FFNKind.NONE:
                total += 3 * d * self.d_ff       # SwiGLU gate/up/down
            if self.ffn != FFNKind.NONE:
                total += 2 * d                   # 2 RMSNorm scales
            else:
                total += d                       # single pre-norm
        total += d                               # final norm
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if self.ffn != FFNKind.MOE or self.moe is None:
            return self.param_count()
        dense_like = self.param_count()
        m = self.moe
        n_moe_layers = sum(
            1 for layer in range(self.num_layers) if self.layer_is_moe(layer)
        )
        inactive = (m.num_experts - m.top_k) * 3 * self.d_model * m.d_expert
        return dense_like - n_moe_layers * inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str                        # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.mode == "decode":
            return self.global_batch          # one new token per sequence
        return self.global_batch * self.seq_len


SHAPES: Mapping[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # Gradient compression for cross-pod all-reduce: "none"|"bf16"|"int8"
    grad_compression: str = "none"


@dataclass(frozen=True)
class ParallelConfig:
    """Resolved control-plane decisions for one (arch x shape x mesh) cell.

    Produced by decision nodes in ``repro.parallel.strategies`` — this is the
    JAX analogue of the paper's decision tuple (func, scale, schedule).
    """

    # func: which implementation variant
    attn_strategy: str = "auto"      # "head_tp" | "seq_tp" | "replicated" | "auto"
    moe_strategy: str = "auto"       # "all_to_all" | "gather" |
                                     # "shard_map_a2a" | "auto"
    layout: str = "auto"             # "tp" | "pure_dp" | "auto": pure_dp
                                     # maps batch over the WHOLE mesh (no
                                     # tensor parallelism) — optimal for
                                     # small models on a fixed mesh
    # scale: how much parallelism / accumulation
    microbatches: int = 1
    remat: str = "block"             # "none" | "block" | "full"
    # schedule: placement of work over the mesh
    pod_axis_role: str = "data"      # "data" (round-robin) | "pipeline" (packing)
    sequence_sharded_residual: bool = False
    fsdp: str = "auto"               # "on" | "off" | "auto": shard weights
                                     # over the data axis (ZeRO-3) when the
                                     # optimizer state would not fit HBM
    zero2: bool = False              # gather FSDP weights ONCE per step
                                     # (before the microbatch scan) instead
                                     # of per-microbatch; grads reduce-
                                     # scatter once at the step boundary
    # data-plane knobs
    use_pallas_attention: bool = False
    kv_compress: bool = False        # int8-wire the seq_tp KV broadcast
    causal_skip: bool = False        # skip upper-triangle attention chunks
    mlp_mode: str = "tp"             # "tp" (Megatron column/row) | "seq"
                                     # (weights replicated over model,
                                     # activations stay sequence-sharded) |
                                     # "auto" (cheaper-wire side wins)
    dtype: str = "bfloat16"


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str = "/tmp/repro_ckpt"
    keep: int = 3
    every_steps: int = 50
    async_write: bool = True


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    optimizer: OptimizerConfig = OptimizerConfig()
    parallel: ParallelConfig = ParallelConfig()
    checkpoint: CheckpointConfig = CheckpointConfig()
    steps: int = 100
    seed: int = 0
    priority: int = 0                # controller priority (higher wins)


def asdict(cfg: Any) -> dict:
    return dataclasses.asdict(cfg)


def fingerprint(*cfgs: Any) -> str:
    """Stable content hash of configs — the executable-cache key."""
    blob = json.dumps([dataclasses.asdict(c) for c in cfgs], sort_keys=True,
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)


def override(cfg, dotted: Mapping[str, Any]):
    """Apply {"optimizer.lr": 1e-4}-style overrides to a nested dataclass."""
    for key, value in dotted.items():
        parts = key.split(".")
        cfg = _override_one(cfg, parts, value)
    return cfg


def _override_one(cfg, parts: Sequence[str], value):
    if len(parts) == 1:
        return dataclasses.replace(cfg, **{parts[0]: value})
    child = getattr(cfg, parts[0])
    return dataclasses.replace(
        cfg, **{parts[0]: _override_one(child, parts[1:], value)}
    )
