"""Decentralized, extensible control plane (paper §5.2).

``GlobalController`` owns the full resource view (device/function slots per
node, grouped into pods) and offers it to per-application
``PrivateController``s. Private controllers make application-level decisions
(via their decision workflows) against an *optimistic* shared-state view and
then try to **commit** slot claims — the Omega model [Schwarzkopf EuroSys'13]
the paper adopts. On conflict, the global controller resolves by priority:
higher-priority claims evict lower-priority, delay-tolerant ones (XFaaS-style
background functions).

These controllers are deliberately runtime-agnostic: the analytics simulator,
the serving engine and the training supervisor all drive them.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping, Sequence

from .decisions import (
    DataDist,
    Decision,
    DecisionContext,
    DecisionWorkflow,
    NodeStatus,
)


@dataclass(frozen=True)
class Claim:
    """A committed (or pending) slot reservation."""

    claim_id: int
    app: str
    priority: int
    placement: tuple[int, ...]            # node id per instance
    tag: str = ""                         # e.g. stage name

    def slots_per_node(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for node in self.placement:
            out[node] = out.get(node, 0) + 1
        return out


class ConflictError(RuntimeError):
    def __init__(self, msg: str, shortfall: Mapping[int, int]):
        super().__init__(msg)
        self.shortfall = dict(shortfall)


@dataclass
class Preemption:
    victim: Claim
    by: str


class GlobalController:
    """Coordinates resource allocation across applications (paper §5.2).

    Maintains the comprehensive resource view and commits claims with
    priority-based conflict resolution. Thread-safe: serving/training/
    background drivers may commit concurrently.
    """

    def __init__(self, slots_per_node: Mapping[int, int],
                 pods: Mapping[int, Sequence[int]] | None = None,
                 link_bw: float = 50e9, intra_bw: float = 819e9):
        self._lock = threading.RLock()
        self.total = dict(slots_per_node)
        self.used: dict[int, int] = {n: 0 for n in self.total}
        self.pods = {k: tuple(v) for k, v in (pods or {0: tuple(self.total)}).items()}
        self.link_bw = link_bw
        self.intra_bw = intra_bw
        self.claims: dict[int, Claim] = {}
        self.preemptions: list[Preemption] = []
        self._ids = itertools.count(1)
        self._listeners: list[Callable[[str, Claim], None]] = []
        # Release-event machinery for starved claimants: every slot release
        # bumps the released nodes' epochs (and a global one) and wakes
        # waiters, so a failed try_commit can block until capacity may have
        # freed on *its* node instead of busy-spinning — and without burning
        # retry attempts on unrelated nodes' churn.
        self._release_cond = threading.Condition(self._lock)
        self._release_epoch = 0
        self._node_release_epoch: dict[int, int] = {n: 0 for n in self.total}

    # -- resource view offered to private controllers (all or parts) --------

    def node_status(self, visible_nodes: Iterable[int] | None = None) -> NodeStatus:
        with self._lock:
            nodes = list(visible_nodes) if visible_nodes is not None \
                else list(self.total)
            return NodeStatus(
                total_slots={n: self.total[n] for n in nodes},
                free_slots={n: self.total[n] - self.used[n] for n in nodes},
                link_bw=self.link_bw,
                intra_bw=self.intra_bw,
                pods=self.pods,
            )

    def utilization(self) -> float:
        with self._lock:
            total = sum(self.total.values())
            return (sum(self.used.values()) / total) if total else 0.0

    def subscribe(self, fn: Callable[[str, Claim], None]) -> None:
        with self._lock:
            self._listeners.append(fn)

    def _notify(self, event: str, claim: Claim) -> None:
        # Called with the controller lock *released* (listeners may block or
        # re-enter the controller); snapshot under the lock so a listener
        # subscribing mid-notify can't mutate the list being iterated.
        with self._lock:
            listeners = tuple(self._listeners)
        for fn in listeners:
            fn(event, claim)

    # -- Omega-style optimistic commit --------------------------------------

    def commit(self, app: str, priority: int, placement: Sequence[int],
               tag: str = "") -> Claim:
        """Atomically commit a claim; may preempt lower-priority claims.

        Raises ConflictError when demand cannot be satisfied even after
        preempting every lower-priority claim on the contended nodes.
        """
        evicted: list[Claim] = []
        claim: Claim | None = None
        try:
            with self._lock:
                demand: dict[int, int] = {}
                for node in placement:
                    if node not in self.total:
                        raise KeyError(f"unknown node {node}")
                    demand[node] = demand.get(node, 0) + 1

                shortfall = {
                    n: need - (self.total[n] - self.used[n])
                    for n, need in demand.items()
                    if need > self.total[n] - self.used[n]
                }
                if shortfall:
                    evicted = self._preempt_for(shortfall, priority, app)
                    shortfall = {
                        n: need - (self.total[n] - self.used[n])
                        for n, need in demand.items()
                        if need > self.total[n] - self.used[n]
                    }
                    if shortfall:
                        raise ConflictError(
                            f"claim by {app} (prio {priority}) unsatisfiable",
                            shortfall,
                        )

                claim = Claim(next(self._ids), app, priority,
                              tuple(placement), tag)
                for node, need in demand.items():
                    self.used[node] += need
                self.claims[claim.claim_id] = claim
        finally:
            # Notifications fire outside the lock: a blocking or re-entrant
            # listener must not stall every other thread's slot traffic. A
            # *raising* listener must not leak the booked claim either — the
            # caller gets the exception instead of the claim handle, so the
            # booking is rolled back before propagating.
            try:
                for victim in evicted:
                    self._notify("release", victim)
                if claim is not None:
                    self._notify("commit", claim)
            except BaseException:
                if claim is not None:
                    with self._lock:
                        self._release_locked(claim)
                raise
        return claim

    # -- invoker-facing claim path ------------------------------------------
    #
    # Function runtimes hold a claim only for the lifetime of one stateless
    # invocation and must detect losing it mid-flight: ``try_commit`` is the
    # non-raising commit, ``finish`` the release-or-report-preempted exit.

    def try_commit(self, app: str, priority: int, placement: Sequence[int],
                   tag: str = "") -> Claim | None:
        """Commit a claim, or return None when it cannot be satisfied."""
        try:
            return self.commit(app, priority, placement, tag=tag)
        except ConflictError:
            return None

    def is_active(self, claim: Claim) -> bool:
        with self._lock:
            return claim.claim_id in self.claims

    def finish(self, claim: Claim) -> bool:
        """Release a claim at invocation exit. Returns False if the claim had
        already been preempted (the invocation's work must be discarded and
        retried — safe for stateless functions)."""
        with self._lock:
            active = self._release_locked(claim)
        if active:
            self._notify("release", claim)
        return active

    def release(self, claim: Claim) -> None:
        with self._lock:
            active = self._release_locked(claim)
        if active:
            self._notify("release", claim)

    def _release_locked(self, claim: Claim) -> bool:
        """Bookkeeping half of a release; caller holds the lock and emits
        the notification after dropping it."""
        if claim.claim_id not in self.claims:
            return False
        del self.claims[claim.claim_id]
        for node, count in claim.slots_per_node().items():
            self.used[node] -= count
            self._node_release_epoch[node] = \
                self._node_release_epoch.get(node, 0) + 1
        self._release_epoch += 1
        self._release_cond.notify_all()
        return True

    # -- release-event wait (starved claimants block, not spin) --------------

    def release_epoch(self, node: int | None = None) -> int:
        """Current release epoch — per ``node`` when given, global otherwise.
        Read *before* a try_commit attempt: if the attempt fails,
        ``wait_for_release(epoch, ...)`` returns immediately when a matching
        slot was freed since — the lost-wakeup-free handshake."""
        with self._lock:
            if node is None:
                return self._release_epoch
            return self._node_release_epoch.get(node, 0)

    def wait_for_release(self, epoch: int, timeout: float | None = None,
                         node: int | None = None) -> bool:
        """Block until the release epoch advances past ``epoch`` — a claim
        was released or preempted since the caller sampled it, on ``node``
        when given (unrelated nodes' churn does not wake-and-burn a
        node-pinned claimant's retry budget) — or ``timeout`` elapses.
        Returns True if a matching release happened."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._release_cond:
            while True:
                current = self._release_epoch if node is None \
                    else self._node_release_epoch.get(node, 0)
                if current != epoch:
                    return True
                if deadline is None:
                    self._release_cond.wait()
                    continue
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._release_cond.wait(remaining)

    def _preempt_for(self, shortfall: Mapping[int, int], priority: int,
                     app: str) -> list[Claim]:
        """Evict lowest-priority claims on contended nodes (paper: priority
        arbitration; effective because low-priority work is delay-tolerant).
        Returns the victims; the caller notifies listeners after unlocking."""
        victims = sorted(
            (c for c in self.claims.values() if c.priority < priority),
            key=lambda c: c.priority,
        )
        need = dict(shortfall)
        evicted: list[Claim] = []
        for victim in victims:
            if not any(n in need and need[n] > 0 for n in victim.placement):
                continue
            self._release_locked(victim)
            evicted.append(victim)
            self.preemptions.append(Preemption(victim, app))
            for node, count in victim.slots_per_node().items():
                if node in need:
                    need[node] -= count
            if all(v <= 0 for v in need.values()):
                break
        return evicted


class PrivateController:
    """Application-level controller: tracks app data distribution, runs the
    app's decision workflow against the global resource view, and converts
    decisions into committed claims."""

    def __init__(self, app: str, gc: GlobalController, priority: int = 0,
                 workflow: DecisionWorkflow | None = None):
        self.app = app
        self.gc = gc
        self.priority = priority
        self.workflow = workflow or DecisionWorkflow(app)
        self.data_dist: dict[str, DataDist] = {}
        self.profile: dict[str, object] = {}
        self.active_claims: list[Claim] = []

    # -- app-level knowledge -------------------------------------------------

    def observe_data(self, dist: DataDist) -> None:
        self.data_dist[dist.name] = dist

    def record_profile(self, **kv) -> None:
        self.profile.update(kv)

    def context(self, app_info: Mapping | None = None) -> DecisionContext:
        return DecisionContext(
            data_dist=dict(self.data_dist),
            node_status=self.gc.node_status(),
            app=dict(app_info or {}),
            profile=dict(self.profile),
        )

    # -- decision -> claim ---------------------------------------------------

    def enact(self, decision: Decision, tag: str = "") -> Claim:
        placement = decision.schedule.place(decision.scale)
        claim = self.gc.commit(self.app, self.priority, placement, tag=tag)
        self.active_claims.append(claim)
        return claim

    def release_all(self) -> None:
        for claim in self.active_claims:
            self.gc.release(claim)
        self.active_claims.clear()

    def run_workflow(self, executor, app_info: Mapping | None = None):
        ctx = self.context(app_info)
        return self.workflow.run(ctx, executor)

    def start_run(self, app_info: Mapping | None = None):
        """Open a late-bound ``WorkflowRun`` over this app's knowledge; the
        executor interleaves ``decide``/``feedback`` with its stages."""
        return self.workflow.start(self.context(app_info))
