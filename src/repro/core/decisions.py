"""Decision workflows — the paper's core abstraction (§5.1), adapted to TPU.

A *decision node* receives system knowledge (``DecisionContext``: data
distribution + node/mesh status) and emits a decision tuple
``Decision(func, scale, schedule)``:

  * ``func``     — which implementation variant to run (paper: hash_join vs
                   merge_join; here e.g. "head_tp" vs "seq_tp" attention, or
                   "all_to_all" vs "gather" MoE dispatch),
  * ``scale``    — how many instances / how much parallelism (paper: function
                   count ∝ data size; here microbatch count, DP width, batch
                   size),
  * ``schedule`` — a placement policy over a node set (paper: round-robin vs
                   packing; here pod-spread vs pod-packing, slot selection).

A *decision workflow* is a DAG of decision nodes evaluated at runtime, between
the stages of an application (query phases, training steps, serving batches).
Applications that need no customization fall back to ``default_node`` —
mirroring the paper's fallback to plain function workflows.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping, Sequence


# ---------------------------------------------------------------------------
# System knowledge exposed to decision nodes (paper Fig. 5, step 2)
# ---------------------------------------------------------------------------


@dataclass
class DataDist:
    """Distribution of one named datum across the cluster/mesh.

    For analytics: per-node byte counts of a table. For LM workloads: tensor
    sizes, token-per-expert histograms, KV-cache occupancy.
    """

    name: str
    bytes_per_node: Mapping[int, int] = field(default_factory=dict)
    rows: int = 0
    skew: float = 0.0                     # max/mean per-node load

    @property
    def size(self) -> int:
        return sum(self.bytes_per_node.values())

    @property
    def loc(self) -> frozenset[int]:
        return frozenset(n for n, b in self.bytes_per_node.items() if b > 0)


@dataclass
class NodeStatus:
    """Cluster/mesh resource view offered by the global controller."""

    total_slots: Mapping[int, int] = field(default_factory=dict)
    free_slots: Mapping[int, int] = field(default_factory=dict)
    link_bw: float = 50e9                 # bytes/s per link (ICI)
    intra_bw: float = 819e9               # bytes/s local (HBM)
    pods: Mapping[int, Sequence[int]] = field(default_factory=dict)

    @property
    def num_nodes(self) -> int:
        return len(self.total_slots)

    def free(self, nodes: Iterable[int] | None = None) -> int:
        nodes = list(nodes) if nodes is not None else list(self.free_slots)
        return sum(self.free_slots.get(n, 0) for n in nodes)


@dataclass
class DecisionContext:
    """Everything a decision node may look at (system + app knowledge)."""

    data_dist: Mapping[str, DataDist] = field(default_factory=dict)
    node_status: NodeStatus = field(default_factory=NodeStatus)
    app: Mapping[str, Any] = field(default_factory=dict)      # app semantics
    profile: Mapping[str, Any] = field(default_factory=dict)  # runtime feedback
    # Feedback from previous runs (paper Fig. 5, step 4) is merged into
    # ``profile`` by the private controller between executions.


# ---------------------------------------------------------------------------
# Decision output (paper Fig. 6, "output decision tuple")
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Schedule:
    policy: str                           # "round-robin" | "packing" | custom
    nodes: tuple[int, ...]                # candidate node set
    slots_per_node: int = 8               # capacity used by the packing policy

    def place(self, n_instances: int) -> tuple[int, ...]:
        """Materialize instance -> node placement under this policy."""
        nodes = list(self.nodes)
        if not nodes:
            return ()
        if self.policy == "packing":
            # Fill each node to capacity before opening the next one
            # (the paper's consolidation strategy for skewed data).
            cap = max(1, self.slots_per_node)
            return tuple(
                nodes[min(i // cap, len(nodes) - 1)] for i in range(n_instances)
            )
        # round-robin: spread instances across the node set.
        return tuple(nodes[i % len(nodes)] for i in range(n_instances))


@dataclass(frozen=True)
class Decision:
    func: str
    scale: int
    schedule: Schedule
    extras: tuple[tuple[str, Any], ...] = ()

    def extra(self, key: str, default: Any = None) -> Any:
        return dict(self.extras).get(key, default)


DecisionFn = Callable[[DecisionContext], Decision]


# ---------------------------------------------------------------------------
# Decision nodes and workflows
# ---------------------------------------------------------------------------


class DecisionNode:
    """A named, user-supplied control-plane decision point."""

    def __init__(self, name: str, fn: DecisionFn,
                 fallback: DecisionFn | None = None):
        self.name = name
        self.fn = fn
        self.fallback = fallback
        self.history: list[tuple[float, Decision]] = []

    def decide(self, ctx: DecisionContext) -> Decision:
        try:
            decision = self.fn(ctx)
        except Exception:
            if self.fallback is None:
                raise
            decision = self.fallback(ctx)
        self.history.append((time.monotonic(), decision))
        return decision

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"DecisionNode({self.name!r})"


def default_node(name: str, func: str = "default") -> DecisionNode:
    """The paper's fallback: scale = all free slots, round-robin placement."""

    def fn(ctx: DecisionContext) -> Decision:
        nodes = tuple(sorted(ctx.node_status.free_slots))
        scale = max(1, ctx.node_status.free(nodes))
        return Decision(func, scale, Schedule("round-robin", nodes))

    return DecisionNode(name, fn)


@dataclass
class Stage:
    """One stage of a decision workflow: a decision node plus downstream
    function group it controls (the paper: "the scheduling of a group of
    functions as a decision node")."""

    node: DecisionNode
    depends_on: tuple[str, ...] = ()


class DecisionWorkflow:
    """A DAG of decision stages evaluated at runtime.

    ``run`` walks stages in topological order, calling a user ``executor``
    for each resolved decision; executors return runtime feedback that is
    folded into the context for downstream stages (paper Fig. 5, step 4).
    """

    def __init__(self, name: str):
        self.name = name
        self.stages: dict[str, Stage] = {}
        self.order: list[str] = []

    def add(self, node: DecisionNode,
            depends_on: Sequence[str] = ()) -> "DecisionWorkflow":
        missing = [d for d in depends_on if d not in self.stages]
        if missing:
            raise ValueError(f"unknown dependencies {missing} for {node.name}")
        if node.name in self.stages:
            raise ValueError(f"duplicate stage {node.name}")
        self.stages[node.name] = Stage(node, tuple(depends_on))
        self.order.append(node.name)
        return self

    def toposorted(self) -> list[str]:
        # insertion order is already valid because add() checks deps exist
        return list(self.order)

    def run(self, ctx: DecisionContext,
            executor: Callable[[str, Decision, DecisionContext], Mapping | None],
            ) -> dict[str, Decision]:
        decisions: dict[str, Decision] = {}
        for name in self.toposorted():
            stage = self.stages[name]
            decision = stage.node.decide(ctx)
            decisions[name] = decision
            feedback = executor(name, decision, ctx)
            if feedback:
                merged = dict(ctx.profile)
                merged.update({f"{name}.{k}": v for k, v in feedback.items()})
                ctx.profile = merged
        return decisions

    def explain(self) -> str:
        lines = [f"DecisionWorkflow({self.name})"]
        for name in self.order:
            deps = self.stages[name].depends_on
            lines.append(f"  {name} <- {list(deps) or '[]'}")
        return "\n".join(lines)
