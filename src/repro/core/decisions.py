"""Decision workflows — the paper's core abstraction (§5.1), adapted to TPU.

A *decision node* receives system knowledge (``DecisionContext``: data
distribution + node/mesh status) and emits a decision tuple
``Decision(func, scale, schedule)``:

  * ``func``     — which implementation variant to run (paper: hash_join vs
                   merge_join; here e.g. "head_tp" vs "seq_tp" attention, or
                   "all_to_all" vs "gather" MoE dispatch),
  * ``scale``    — how many instances / how much parallelism (paper: function
                   count ∝ data size; here microbatch count, DP width, batch
                   size),
  * ``schedule`` — a placement policy over a node set (paper: round-robin vs
                   packing; here pod-spread vs pod-packing, slot selection).

A *decision workflow* is a DAG of decision nodes evaluated at runtime, between
the stages of an application (query phases, training steps, serving batches).
Decisions are **late-bound**: a stage's node is evaluated only once its
upstream stages have decided and the runtime feedback it awaits has been
folded into the context (paper Fig. 5 step 4) — so a decision made between
two application stages sees what the earlier stages actually produced, not
what the planner guessed up front. ``WorkflowRun`` is the incremental
evaluation handle executors drive; ``DecisionWorkflow.run`` remains the
one-shot convenience loop. Applications that need no customization fall back
to ``default_node`` — mirroring the paper's fallback to plain function
workflows.
"""

from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping, Sequence


# ---------------------------------------------------------------------------
# System knowledge exposed to decision nodes (paper Fig. 5, step 2)
# ---------------------------------------------------------------------------


@dataclass
class DataDist:
    """Distribution of one named datum across the cluster/mesh.

    For analytics: per-node byte counts of a table. For LM workloads: tensor
    sizes, token-per-expert histograms, KV-cache occupancy.
    """

    name: str
    bytes_per_node: Mapping[int, int] = field(default_factory=dict)
    rows: int = 0
    skew: float = 0.0                     # max/mean per-node load

    @property
    def size(self) -> int:
        return sum(self.bytes_per_node.values())

    @property
    def loc(self) -> frozenset[int]:
        return frozenset(n for n, b in self.bytes_per_node.items() if b > 0)


def partition_skew(counts: Iterable[int]) -> float:
    """max/mean per-partition load — the skew figure every ``DataDist``
    producer (tables, shuffle store, scan estimates) must agree on."""
    counts = list(counts)
    if not counts:
        return 0.0
    mean = sum(counts) / len(counts)
    return float(max(counts) / max(mean, 1e-9))


def merge_hot_keys(sketches: Iterable[Iterable[tuple[int, int]]],
                   k: int = 8) -> tuple[tuple[int, int], ...]:
    """Merge per-partition heavy-hitter sketches (``((key, count), ...)``)
    into one global top-k, ordered by (-count, key). Summation by key is
    order-independent, so the runtime (merging observed per-invocation
    sketches) and the simulator (merging recomputed per-partition sketches)
    produce bit-identical results from the same inputs."""
    counts: dict[int, int] = {}
    for sketch in sketches:
        for key, c in sketch:
            key = int(key)
            counts[key] = counts.get(key, 0) + int(c)
    top = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))
    return tuple((k_, c) for k_, c in top[:max(1, int(k))])


@dataclass
class NodeStatus:
    """Cluster/mesh resource view offered by the global controller."""

    total_slots: Mapping[int, int] = field(default_factory=dict)
    free_slots: Mapping[int, int] = field(default_factory=dict)
    link_bw: float = 50e9                 # bytes/s per link (ICI)
    intra_bw: float = 819e9               # bytes/s local (HBM)
    pods: Mapping[int, Sequence[int]] = field(default_factory=dict)

    @property
    def num_nodes(self) -> int:
        return len(self.total_slots)

    def free(self, nodes: Iterable[int] | None = None) -> int:
        nodes = list(nodes) if nodes is not None else list(self.free_slots)
        return sum(self.free_slots.get(n, 0) for n in nodes)


@dataclass
class DecisionContext:
    """Everything a decision node may look at (system + app knowledge)."""

    data_dist: Mapping[str, DataDist] = field(default_factory=dict)
    node_status: NodeStatus = field(default_factory=NodeStatus)
    app: Mapping[str, Any] = field(default_factory=dict)      # app semantics
    profile: Mapping[str, Any] = field(default_factory=dict)  # runtime feedback
    # Decisions already bound earlier in the same workflow run; downstream
    # nodes may condition on them (e.g. the exchange pattern follows the
    # join variant). Populated by ``WorkflowRun.decide``.
    decisions: Mapping[str, "Decision"] = field(default_factory=dict)
    # Feedback from previous runs (paper Fig. 5, step 4) is merged into
    # ``profile`` by the private controller between executions.


# ---------------------------------------------------------------------------
# Decision output (paper Fig. 6, "output decision tuple")
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Schedule:
    policy: str                           # "round-robin" | "packing" | custom
    nodes: tuple[int, ...]                # candidate node set
    slots_per_node: int = 8               # capacity used by the packing policy

    def place(self, n_instances: int) -> tuple[int, ...]:
        """Materialize instance -> node placement under this policy."""
        nodes = list(self.nodes)
        if not nodes:
            return ()
        if self.policy == "packing":
            # Fill each node to capacity before opening the next one
            # (the paper's consolidation strategy for skewed data).
            cap = max(1, self.slots_per_node)
            return tuple(
                nodes[min(i // cap, len(nodes) - 1)] for i in range(n_instances)
            )
        # round-robin: spread instances across the node set.
        return tuple(nodes[i % len(nodes)] for i in range(n_instances))


@dataclass(frozen=True)
class Decision:
    func: str
    scale: int
    schedule: Schedule
    extras: tuple[tuple[str, Any], ...] = ()

    def extra(self, key: str, default: Any = None) -> Any:
        return dict(self.extras).get(key, default)


DecisionFn = Callable[[DecisionContext], Decision]


# ---------------------------------------------------------------------------
# Decision nodes and workflows
# ---------------------------------------------------------------------------


class DecisionNode:
    """A named, user-supplied control-plane decision point.

    ``history`` keeps the last ``max_history`` decisions (bounded so
    long-lived nodes shared across many queries don't grow without limit);
    it is what profiling dashboards and the re-plan tests inspect.
    ``candidates`` names the implementation variants the node chooses among
    (purely declarative — recorded in the decision audit log so a binding
    shows what it picked *against*).

    Every binding is reported to the global ``DecisionAuditLog``
    (``repro.obs.audit``) together with the context snapshot it saw —
    profile feedback, data distributions, free slots, upstream decisions —
    attributed to the query the calling scope bound via ``bound_app``.
    """

    def __init__(self, name: str, fn: DecisionFn,
                 fallback: DecisionFn | None = None, max_history: int = 64,
                 candidates: Sequence[str] = ()):
        self.name = name
        self.fn = fn
        self.fallback = fallback
        self.candidates = tuple(candidates)
        self.history: deque[tuple[float, Decision]] = deque(maxlen=max_history)

    def decide(self, ctx: DecisionContext) -> Decision:
        from repro.obs.audit import get_audit_log
        try:
            decision = self.fn(ctx)
        except Exception:
            if self.fallback is None:
                raise
            decision = self.fallback(ctx)
        self.history.append((time.monotonic(), decision))
        get_audit_log().record(self, ctx, decision)
        return decision

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"DecisionNode({self.name!r})"


def default_node(name: str, func: str = "default") -> DecisionNode:
    """The paper's fallback: scale = all free slots, round-robin placement."""

    def fn(ctx: DecisionContext) -> Decision:
        nodes = tuple(sorted(ctx.node_status.free_slots))
        scale = max(1, ctx.node_status.free(nodes))
        return Decision(func, scale, Schedule("round-robin", nodes))

    return DecisionNode(name, fn, candidates=(func,))


# ---------------------------------------------------------------------------
# Failure-feedback nodes: failure handling as a decision-workflow concern.
# The runtime feeds observed failure metrics (per-invocation elapsed times,
# recovery plan sizes) into these nodes exactly like any other profile
# feedback; the decision tuple picks the mitigation — speculate vs wait,
# lineage recompute vs whole-query rerun.
# ---------------------------------------------------------------------------


def should_speculate(done_seconds: Iterable[float], elapsed: float,
                     multiple: float = 2.0, min_done: int = 2,
                     floor: float = 0.05) -> bool:
    """Pure straggler predicate shared by the runtime invoker and the
    cluster simulator: an in-flight invocation is a straggler once its
    elapsed time exceeds ``multiple`` × the p50 of its completed siblings
    (needs ``min_done`` completions; ``floor`` suppresses speculation on
    microsecond-scale stages where a backup costs more than it saves)."""
    done = sorted(done_seconds)
    if len(done) < min_done:
        return False
    p50 = done[len(done) // 2]
    return elapsed > max(multiple * p50, floor)


def speculation_node(multiple: float = 2.0, min_done: int = 2,
                     floor: float = 0.05) -> DecisionNode:
    """Failure-feedback node: launch a backup for a straggling invocation?

    Context contract (fed by the invoker per straggler candidate):
    ``profile["speculation.done_s"]`` — completed siblings' durations,
    ``profile["speculation.elapsed_s"]`` — the candidate's elapsed time,
    ``profile["speculation.node"]`` — the node it is stuck on. Decides
    ``Decision("speculate", 1, schedule)`` with the schedule ranging over
    every *other* node (the straggler's node is presumed slow), or
    ``Decision("wait", 0, ...)``.
    """

    def fn(ctx: DecisionContext) -> Decision:
        done = ctx.profile.get("speculation.done_s", ())
        elapsed = float(ctx.profile.get("speculation.elapsed_s", 0.0))
        avoid = ctx.profile.get("speculation.node")
        nodes = tuple(n for n in sorted(ctx.node_status.total_slots)
                      if n != avoid) or \
            tuple(sorted(ctx.node_status.total_slots))
        if should_speculate(done, elapsed, multiple, min_done, floor):
            return Decision("speculate", 1, Schedule("round-robin", nodes))
        return Decision("wait", 0, Schedule("round-robin", nodes))

    return DecisionNode("speculation", fn,
                        candidates=("speculate", "wait"))


def recovery_node(max_reexec_frac: float = 0.5) -> DecisionNode:
    """Failure-feedback node: heal a lost stage by lineage recompute or give
    up and rerun the whole query?

    Context contract (fed by the executor on ``StageLostError``):
    ``profile["recovery.reexec_invocations"]`` — invocations the lineage
    plan would re-execute, ``profile["recovery.total_invocations"]`` — the
    query's total. Recompute while the plan re-executes at most
    ``max_reexec_frac`` of the query; otherwise decide ``"rerun"`` (the
    executor then surfaces ``RecoveryError`` for the caller to rerun).
    """

    def fn(ctx: DecisionContext) -> Decision:
        n_re = int(ctx.profile.get("recovery.reexec_invocations", 0))
        total = max(1, int(ctx.profile.get("recovery.total_invocations", 0)))
        nodes = tuple(sorted(ctx.node_status.total_slots))
        func = "recompute" if n_re <= max_reexec_frac * total else "rerun"
        return Decision(func, n_re, Schedule("round-robin", nodes))

    return DecisionNode("recovery", fn,
                        candidates=("recompute", "rerun"))


def worker_pool_target(fanout: int, pool: int, min_workers: int = 1,
                       max_workers: int = 16,
                       tasks_per_worker: int = 4) -> int:
    """Pure pool-sizing rule shared by the runtime invoker and the cluster
    simulator (the sharing is what makes elastic decision sequences
    identical across planes): enough warm workers that the upcoming
    fan-out queues at most ``tasks_per_worker`` deep per worker, clamped
    to ``[min_workers, max_workers]``. With no upcoming work the pool
    shrinks to ``min_workers`` (the warm floor the idle reaper leaves)."""
    if fanout <= 0:
        return max(min_workers, 0)
    want = -(-int(fanout) // max(1, int(tasks_per_worker)))   # ceil div
    return max(min_workers, min(int(max_workers), want))


def elasticity_node(min_workers: int = 1, max_workers: int = 16,
                    tasks_per_worker: int = 4,
                    name: str = "elastic") -> DecisionNode:
    """Elasticity as a decision node: grow or shrink the worker pool from
    queue pressure — the control-plane half of the process worker plane
    (``repro.runtime.workers``), in the spirit of Lambada's burst fan-out.

    Context contract (fed by the planner on either plane before the node
    binds): ``profile["elastic.fanout"]`` — the upcoming stage fan-out
    (invocations about to queue), ``profile["elastic.pool"]`` — the
    current worker-pool size (0 on backends without a pool: the decision
    still binds and is audited, it just has nothing to resize — the same
    control-plane-invisibility convention as the pipeline node). Decides
    ``Decision("grow"|"shrink"|"hold", target_pool, schedule)`` where
    ``scale`` IS the target pool size; ``extras`` carry the sizing inputs
    so the audit log shows why.
    """

    def fn(ctx: DecisionContext) -> Decision:
        fanout = int(ctx.profile.get("elastic.fanout", 0))
        pool = int(ctx.profile.get("elastic.pool", 0))
        target = worker_pool_target(fanout, pool, min_workers=min_workers,
                                    max_workers=max_workers,
                                    tasks_per_worker=tasks_per_worker)
        func = "grow" if target > pool else \
            "shrink" if target < pool else "hold"
        nodes = tuple(sorted(ctx.node_status.total_slots))
        return Decision(func, target, Schedule("round-robin", nodes),
                        extras=(("fanout", fanout), ("pool", pool),
                                ("tasks_per_worker", tasks_per_worker)))

    return DecisionNode(name, fn, candidates=("grow", "shrink", "hold"))


# spill costs are seconds + dollars; one exchange rate folds them into a
# single objective ($1 ≈ one cpu-hour of makespan — the serverless duality
# of paying for time)
SPILL_DOLLARS_TO_SECONDS = 3600.0


def tiering_choice(nbytes: int, reread_p: float, recompute_s: float,
                   tiers: Mapping[str, Mapping]) -> tuple[str, str | None]:
    """Pure per-stage tiering rule shared by the runtime planner and the
    cluster simulator (the sharing is what makes tiering decision
    sequences identical across planes): for one reclaimable stage of
    ``nbytes``, compare evict-and-recompute (``reread_p *
    recompute_s``) against spilling to each cold tier (write now, read
    back with probability ``reread_p``, request/GB dollars monetized at
    ``SPILL_DOLLARS_TO_SECONDS``). ``tiers`` maps tier name ->
    ``StorageBackend.spec()``. Returns ``("spill", tier)`` or
    ``("evict", None)``; ties break toward evicting (recompute needs no
    new machinery) then toward the warmer tier."""
    best = ("evict", None)
    best_cost = max(0.0, float(reread_p)) * max(0.0, float(recompute_s))
    for name in sorted(tiers, key=lambda n: (tiers[n].get("order", 99), n)):
        spec = tiers[name]
        lat = float(spec.get("latency_s") or 0.0)
        write_bw = spec.get("write_bw")
        read_bw = spec.get("read_bw")
        write_s = lat + (nbytes / write_bw if write_bw else 0.0)
        read_s = lat + (nbytes / read_bw if read_bw else 0.0)
        dollars = (float(spec.get("cost_per_request") or 0.0) * 2
                   + 2 * nbytes * float(spec.get("cost_per_gb") or 0.0)
                   / 1e9)
        cost = write_s + reread_p * read_s \
            + dollars * SPILL_DOLLARS_TO_SECONDS
        if cost < best_cost:
            best, best_cost = ("spill", name), cost
    return best


def tiering_node(loss_rate: float = 0.05, recompute_bw: float = 32e6,
                 name: str = "tiering") -> DecisionNode:
    """Storage tiering as a decision node: choose, per reclaimable shuffle
    stage, whether quota pressure should *spill* it to a colder backend or
    *evict* it and lean on lineage recompute — the graceful-degradation
    answer to ServerMix's ephemeral-storage tension.

    Context contract (fed by the planner on either plane before the node
    binds): ``profile["tiering.stages"]`` — tuple of ``(stage,
    est_bytes, lineage_depth, downstream_remaining)`` per ephemeral data
    stage of the chosen physical plan; ``profile["tiering.quota"]`` — the
    app's store quota (None = unlimited); ``profile["tiering.tiers"]`` —
    cold-tier specs (``ShuffleStore.storage_spec()``; empty on stores
    without spill backends). With no quota or no cold tiers the node
    decides ``keep`` — today's behavior, byte-identical on both planes.

    Per stage, re-read probability grows with the downstream stages still
    to run (``loss_rate`` per consumer — more future readers, more
    chances a fault or speculation replay re-pulls it) and recompute cost
    scales with lineage depth at an effective ``recompute_bw`` bytes/s
    (recomputing a deep stage replays its whole producer chain). Both
    inputs are plan-derived, never measured, so runtime and simulator
    price identically. Decides ``Decision("spill"|"evict"|"keep",
    n_spilled, schedule)``; ``extras["plan"]`` carries the per-stage
    choices (``tier`` name or ``"evict"``) the planner installs via
    ``ShuffleStore.set_spill_policy``.
    """

    def fn(ctx: DecisionContext) -> Decision:
        stages = tuple(ctx.profile.get("tiering.stages", ()))
        quota = ctx.profile.get("tiering.quota")
        tiers = dict(ctx.profile.get("tiering.tiers") or {})
        nodes = tuple(sorted(ctx.node_status.total_slots))
        sched = Schedule("round-robin", nodes)
        if quota is None or not tiers or not stages:
            return Decision("keep", 0, sched, extras=(("plan", ()),))
        plan = []
        spilled = 0
        for stage, nbytes, depth, remaining in stages:
            p = min(1.0, loss_rate * (1 + int(remaining)))
            recompute_s = max(1, int(depth)) * int(nbytes) / recompute_bw
            func, tier = tiering_choice(int(nbytes), p, recompute_s, tiers)
            if func == "spill":
                spilled += 1
                plan.append((stage, tier))
            else:
                plan.append((stage, "evict"))
        func = "spill" if spilled else "evict"
        return Decision(func, spilled, sched,
                        extras=(("plan", tuple(plan)),))

    return DecisionNode(name, fn, candidates=("spill", "evict", "keep"))


def skew_mitigation(rows_hist: Sequence[int],
                    hot_keys: Sequence[tuple[int, int]],
                    threshold: float = 2.0, min_rows: int = 4096,
                    salt_cap: int = 8, hot_frac: float = 0.08,
                    force: str | None = None,
                    ) -> tuple[str, tuple[tuple[int, int], ...], int,
                               tuple[int, ...]]:
    """Pure skew-mitigation rule shared by the runtime planner and the
    cluster simulator (the sharing is what makes skew decision sequences
    identical across planes). From an observed per-bucket row histogram
    and a merged heavy-hitter sketch, pick:

      * ``("none", (), 0, ())`` — balanced enough (max/mean below
        ``threshold``) or too small (< ``min_rows``) to be worth touching;
      * ``("broadcast", heavy, salt, hot)`` — a few keys dominate
        (any sketch key holding >= ``hot_frac`` of all rows): split them
        out of the shuffle and join them against a replicated build side,
        and shard what remains of the heavy buckets ``salt`` ways;
      * ``("salted", heavy, salt, ())`` — buckets are lopsided without a
        single dominating key: split each heavy bucket (>= ``threshold`` x
        mean rows) into ``salt`` writer-sharded sub-joins.

    ``heavy`` is ``((bucket, rows), ...)``; ``salt`` = ceil(max/mean)
    clamped to ``[2, salt_cap]``. ``force`` pins the mitigation for A/B
    benchmarking: a forced choice still needs a histogram to split on
    (empty input stays ``none``), and forced ``salted`` on balanced data
    splits the single largest bucket.
    """
    rows = [int(r) for r in rows_hist]
    total = sum(rows)
    if total <= 0 or len(rows) < 2:
        return ("none", (), 0, ())
    mean = total / len(rows)
    ratio = max(rows) / max(mean, 1e-9)
    heavy = tuple((b, r) for b, r in enumerate(rows)
                  if r >= threshold * mean and r > 0)
    hot = tuple(int(k) for k, c in hot_keys if c >= hot_frac * total)
    salt = max(2, min(int(salt_cap), math.ceil(ratio)))
    if force == "none":
        return ("none", (), 0, ())
    if force == "broadcast":
        if not hot:
            hot = tuple(int(k) for k, _ in list(hot_keys)[:2])
        return ("broadcast", heavy, salt, hot) if hot \
            else ("none", (), 0, ())
    if force == "salted":
        if not heavy:
            b = max(range(len(rows)), key=lambda i: rows[i])
            heavy = ((b, rows[b]),)
        return ("salted", heavy, salt, ())
    if total < min_rows or ratio < threshold:
        return ("none", (), 0, ())
    if hot:
        return ("broadcast", heavy, salt, hot)
    if heavy:
        return ("salted", heavy, salt, ())
    return ("none", (), 0, ())


def skew_node(threshold: float = 2.0, min_rows: int = 4096,
              salt_cap: int = 8, hot_frac: float = 0.08,
              force: str | None = None, name: str = "skew") -> DecisionNode:
    """Skew mitigation as a decision node: fire between exchange and join
    on the *observed* shuffle histogram — not a planner estimate — and
    rewrite the heavy part of the join fan-in (ROADMAP's skew half of the
    plan-language item; Lambada's exchange-balance concern).

    Context contract (fed by the planner on either plane before the node
    binds): ``profile["skew.partition_rows"]`` / ``["skew.partition_bytes"]``
    — per-join-bucket row/byte histograms summed over the shuffle writers
    (runtime: observed via ``InvocationRecord.stats``; simulator: exactly
    recomputed from the same partition contents), and
    ``profile["skew.hot_keys"]`` — the merged top-k heavy-hitter sketch
    ``((key, count), ...)``. Empty histograms (broadcast exchange, phantom
    tables) bind ``none`` — today's behavior, byte-identical on both
    planes. Decides ``Decision("none"|"salted"|"broadcast", n_extra_invs,
    schedule)`` reusing the join schedule's node set; ``extras`` carry
    everything stage materialization needs (``heavy`` buckets, ``salt``
    width, ``hot_keys``) plus the observed ``ratio`` so the audit log
    shows why.
    """

    def fn(ctx: DecisionContext) -> Decision:
        rows = tuple(ctx.profile.get("skew.partition_rows", ()))
        nbytes = tuple(ctx.profile.get("skew.partition_bytes", ()))
        sketch = tuple(ctx.profile.get("skew.hot_keys", ()))
        func, heavy, salt, hot = skew_mitigation(
            rows, sketch, threshold=threshold, min_rows=min_rows,
            salt_cap=salt_cap, hot_frac=hot_frac, force=force)
        join = ctx.decisions.get("join")
        sched = join.schedule if join is not None else Schedule(
            "round-robin", tuple(sorted(ctx.node_status.total_slots)))
        scale = len(heavy) * salt if func == "salted" else len(hot)
        return Decision(func, scale, sched,
                        extras=(("heavy", heavy), ("salt", salt),
                                ("hot_keys", hot),
                                ("ratio", round(partition_skew(rows), 4)),
                                ("max_bytes", max(nbytes, default=0)),
                                ("total_rows", sum(int(r) for r in rows))))

    return DecisionNode(name, fn, candidates=("none", "salted", "broadcast"))


@dataclass
class Stage:
    """One stage of a decision workflow: a decision node plus downstream
    function group it controls (the paper: "the scheduling of a group of
    functions as a decision node").

    ``depends_on`` orders decisions (upstream stages must have *decided*);
    ``await_feedback`` late-binds them (the named stages must also have had
    their runtime feedback folded into the context before this stage may
    decide). ``None`` means "same as depends_on" — the decision order and
    the feedback order coincide, which is the common linear case. Pass an
    explicit subset when a stage's physical work runs *after* a downstream
    decision (e.g. the exchange decision follows the join decision but both
    bind on the scan stage's feedback).
    """

    node: DecisionNode
    depends_on: tuple[str, ...] = ()
    await_feedback: tuple[str, ...] | None = None

    @property
    def awaits(self) -> tuple[str, ...]:
        return self.depends_on if self.await_feedback is None \
            else self.await_feedback


class LateBindingError(RuntimeError):
    """A decision was requested before its awaited feedback arrived."""


class WorkflowRun:
    """One incremental, late-bound evaluation of a workflow.

    Executors drive it between application stages:

        run = workflow.start(ctx)
        run.decide("scan")              # binds the scan decision
        ... execute the scan stage ...
        run.observe(post_scan_dist)     # fold observed data distribution
        run.feedback("scan", metrics)   # fold runtime feedback (Fig. 5 §4)
        run.decide("join")              # now sees what the scan produced

    ``decide`` refuses to run a stage whose upstream decisions or awaited
    feedback are missing — that is the late-binding contract.
    """

    def __init__(self, workflow: "DecisionWorkflow", ctx: DecisionContext):
        self.workflow = workflow
        self.ctx = ctx
        # the application this run plans for — set by the planner entry
        # points so decision audit entries attribute to the right query
        self.app: str | None = None
        self.decisions: dict[str, Decision] = {}
        self.fed: set[str] = set()

    def ready(self) -> list[str]:
        """Undecided stages whose deps have decided and feedback arrived."""
        out = []
        for name in self.workflow.order:
            if name in self.decisions:
                continue
            stage = self.workflow.stages[name]
            if all(d in self.decisions for d in stage.depends_on) and \
                    all(f in self.fed for f in stage.awaits):
                out.append(name)
        return out

    def decide(self, name: str) -> Decision:
        stage = self.workflow.stages[name]
        if name in self.decisions:
            raise LateBindingError(f"stage {name!r} already decided")
        undecided = [d for d in stage.depends_on if d not in self.decisions]
        unfed = [f for f in stage.awaits if f not in self.fed]
        if undecided or unfed:
            raise LateBindingError(
                f"stage {name!r} is not ready: undecided deps {undecided}, "
                f"awaiting feedback from {unfed}")
        from repro.obs.audit import bound_app
        with bound_app(self.app):
            decision = stage.node.decide(self.ctx)
        self.decisions[name] = decision
        self.ctx.decisions = dict(self.ctx.decisions, **{name: decision})
        return decision

    def feedback(self, name: str, feedback: Mapping | None = None) -> None:
        """Fold a completed stage's runtime feedback and unblock dependents.

        Keys are merged into ``ctx.profile`` verbatim — callers prefix them
        (``"scan.seconds"``) when they want namespacing.
        """
        if feedback:
            merged = dict(self.ctx.profile)
            merged.update(feedback)
            self.ctx.profile = merged
        self.fed.add(name)

    def observe(self, dist: DataDist) -> None:
        """Fold an observed data distribution (e.g. post-filter scan output)
        into the context so later decisions see actual, not planned, sizes."""
        merged = dict(self.ctx.data_dist)
        merged[dist.name] = dist
        self.ctx.data_dist = merged

    def refresh_status(self, status: NodeStatus) -> None:
        """Update the resource view so late decisions see current free slots."""
        self.ctx.node_status = status

    def complete(self) -> bool:
        return len(self.decisions) == len(self.workflow.stages)

    @property
    def sequence(self) -> list[tuple[str, Decision]]:
        """The materialized decision sequence, in binding order."""
        return list(self.decisions.items())


class DecisionWorkflow:
    """A DAG of decision stages evaluated at runtime.

    ``start`` hands out a ``WorkflowRun`` for incremental, late-bound
    evaluation interleaved with application stages. ``run`` is the one-shot
    loop: it walks ready stages in insertion order, calls a user
    ``executor`` for each resolved decision, and folds the feedback the
    executor returns into the context for downstream stages (paper Fig. 5,
    step 4). One workflow may be shared by several planners (simulator and
    runtime); each ``start`` opens an independent run while the nodes'
    bounded histories accumulate across runs.
    """

    def __init__(self, name: str):
        self.name = name
        self.stages: dict[str, Stage] = {}
        self.order: list[str] = []
        self.last_run: WorkflowRun | None = None

    def add(self, node: DecisionNode, depends_on: Sequence[str] = (),
            await_feedback: Sequence[str] | None = None) -> "DecisionWorkflow":
        missing = [d for d in depends_on if d not in self.stages]
        missing += [f for f in (await_feedback or ()) if f not in self.stages]
        if missing:
            raise ValueError(f"unknown dependencies {missing} for {node.name}")
        if node.name in self.stages:
            raise ValueError(f"duplicate stage {node.name}")
        self.stages[node.name] = Stage(
            node, tuple(depends_on),
            None if await_feedback is None else tuple(await_feedback))
        self.order.append(node.name)
        return self

    def toposorted(self) -> list[str]:
        # insertion order is already valid because add() checks deps exist
        return list(self.order)

    def start(self, ctx: DecisionContext) -> WorkflowRun:
        self.last_run = WorkflowRun(self, ctx)
        return self.last_run

    def run(self, ctx: DecisionContext,
            executor: Callable[[str, Decision, DecisionContext], Mapping | None],
            ) -> dict[str, Decision]:
        run = self.start(ctx)
        while not run.complete():
            ready = run.ready()
            if not ready:
                stuck = [n for n in self.order if n not in run.decisions]
                raise LateBindingError(
                    f"workflow {self.name}: stages {stuck} never became "
                    f"ready (missing feedback?)")
            for name in ready:
                decision = run.decide(name)
                feedback = executor(name, decision, ctx)
                run.feedback(name, {f"{name}.{k}": v
                                    for k, v in (feedback or {}).items()})
        return dict(run.decisions)

    def explain(self) -> str:
        lines = [f"DecisionWorkflow({self.name})"]
        for name in self.order:
            stage = self.stages[name]
            lines.append(f"  {name} <- {list(stage.depends_on) or '[]'}"
                         f" [awaits {list(stage.awaits) or '[]'}]")
        return "\n".join(lines)
