"""Data pipeline: synthetic + memmap sources, shard-aware, prefetching."""

from repro.data.pipeline import (  # noqa: F401
    MemmapSource,
    Prefetcher,
    SyntheticSource,
    write_token_file,
)
