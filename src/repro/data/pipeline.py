"""Shard-aware token data pipeline.

Sources: deterministic synthetic streams (seeded per (step, shard) so every
data-parallel shard sees a disjoint slice and a restart reproduces the exact
batch sequence — required for checkpoint/restart bit-exactness) and memmapped
token files. A background prefetch thread keeps ``depth`` batches ready so
host-side data work overlaps device compute.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator

import numpy as np

from repro.core.config import Frontend, ModelConfig, ShapeConfig
from repro.models.lm import AUDIO_FRAME_DIM


@dataclass
class SyntheticSource:
    """Deterministic infinite token stream: batch(step) is a pure function
    of (seed, step, shard), so restarts replay identically."""

    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    shard: int = 0
    num_shards: int = 1

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)
        b = self.shape.global_batch // self.num_shards
        s = self.shape.seq_len
        out: dict = {}
        if self.cfg.frontend == Frontend.VISION_STUB.value:
            n_text = s - self.cfg.stub_patches
            tokens = rng.integers(0, self.cfg.vocab_size, (b, n_text),
                                  dtype=np.int32)
            out["patch_embeds"] = rng.standard_normal(
                (b, self.cfg.stub_patches, self.cfg.d_model)).astype(
                np.float32)
        else:
            tokens = rng.integers(0, self.cfg.vocab_size, (b, s),
                                  dtype=np.int32)
            if self.cfg.frontend == Frontend.AUDIO_STUB.value:
                out["frame_embeds"] = rng.standard_normal(
                    (b, s, AUDIO_FRAME_DIM)).astype(np.float32)
        out["tokens"] = tokens
        out["labels"] = np.concatenate(
            [tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
        return out


@dataclass
class MemmapSource:
    """Token file source: flat int32 binary, sliced into (batch, seq) with a
    per-shard stride."""

    path: str
    cfg: ModelConfig
    shape: ShapeConfig
    shard: int = 0
    num_shards: int = 1

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")

    def batch(self, step: int) -> dict:
        b = self.shape.global_batch // self.num_shards
        s = self.shape.seq_len
        n = self._data.shape[0]
        per_step = b * (s + 1)
        offset = (step * self.num_shards + self.shard) * per_step % max(
            1, n - per_step)
        window = np.asarray(self._data[offset: offset + per_step])
        window = window.reshape(b, s + 1) % self.cfg.vocab_size
        return {"tokens": window[:, :-1].astype(np.int32),
                "labels": window[:, 1:].astype(np.int32)}


class Prefetcher:
    """Runs source.batch(step) ``depth`` steps ahead on a worker thread."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self.source.batch(step)), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self._q.get()

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)


def write_token_file(path: str | Path, num_tokens: int, vocab: int,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, vocab, num_tokens, dtype=np.int32)
    arr.tofile(path)
    return path
