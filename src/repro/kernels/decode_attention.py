"""Flash-decode attention — Pallas TPU kernel for the serve_step hot loop.

One new query token per sequence against a long KV cache
[FlashDecoding++, arXiv:2311.01282 adapted to TPU]. Grid =
(batch*kv_heads, kv_blocks); the G grouped query heads of each kv head are
processed together as a (G, hd) tile (MXU-friendly when G*hd >= 128). The
kv_blocks dimension is sequential on TPU, so the online-softmax state lives
in VMEM scratch, and blocks beyond the valid cache length short-circuit via
``pl.when`` (no work issued) — the kernel reads only ceil(len/bk) blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, block_k: int,
                   num_k_blocks: int):
    ki = pl.program_id(1)
    length = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * block_k < length)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # (G, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bk)
        k_idx = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(k_idx < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_cur
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); caches: (B, S, K, hd); length: (B,) valid prefix.

    Returns (B, H, hd). H = K * G (GQA); q heads are grouped per kv head.
    """
    b, h, hd = q.shape
    _, s, kh, _ = k_cache.shape
    g = h // kh
    block_k = min(block_k, s)
    assert s % block_k == 0
    nk = s // block_k

    qb = q.reshape(b, kh, g, hd).reshape(b * kh, g, hd)
    kb = k_cache.transpose(0, 2, 1, 3).reshape(b * kh, s, hd)
    vb = v_cache.transpose(0, 2, 1, 3).reshape(b * kh, s, hd)
    lens = jnp.repeat(length.astype(jnp.int32), kh)

    kernel = functools.partial(_decode_kernel, scale=hd ** -0.5,
                               block_k=block_k, num_k_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * kh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, kk: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, hd), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda i, kk: (i, kk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda i, kk: (i, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda i, kk: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qb, kb, vb)

    return out.reshape(b, kh, g, hd).reshape(b, h, hd)
