"""Causal flash attention — Pallas TPU kernel.

TPU adaptation of the FlashAttention tiling [arXiv:2205.14135]: the grid is
(batch*heads, q_blocks, k_blocks); TPU executes the minor-most grid dim
sequentially per core, so the online-softmax state (m, l, acc) lives in VMEM
scratch that persists across the k_block iterations of one q_block. Block
shapes are MXU-aligned (multiples of 128 in production configs; smaller in
tests). Causal masking is applied per-block; fully-masked upper-triangle
blocks are skipped with ``pl.when`` (no MXU work issued).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0].astype(jnp.float32)              # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            q_idx = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_idx = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_idx >= k_idx, s, NEG_INF)

        m_prev = m_scr[...]                           # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_cur
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q, k, v: (B, S, H, hd) with KV pre-expanded to H heads.

    Returns (B, S, H, hd). VMEM working set per grid step:
    bq*hd (q) + 2*bk*hd (kv) + bq*bk (scores) + bq*hd (acc), fp32.
    """
    b, s, h, hd = q.shape
    assert k.shape == v.shape == (b, s, h, hd)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    nq, nk = s // block_q, s // block_k

    def to_bh(t):  # (B,S,H,hd) -> (B*H, S, hd)
        return t.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    qb, kb, vb = to_bh(q), to_bh(k), to_bh(v)

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, block_q=block_q,
        block_k=block_k, num_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda i, j, kk: (i, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda i, j, kk: (i, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda i, j, kk: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),          # running max m
            pltpu.VMEM((block_q,), jnp.float32),          # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),       # accumulator
        ],
        interpret=interpret,
    )(qb, kb, vb)

    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
