"""Jit'd dispatch wrappers: Pallas kernel on TPU, interpret-mode kernel or
jnp reference elsewhere. These are the functions the model/data plane calls.
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.partition import (
    partition_histogram as _hist,
    partition_scatter as _scatter,
)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, force_kernel: bool = False):
    """(B,S,H,hd) attention; kernel on TPU, oracle elsewhere."""
    if on_tpu() or force_kernel:
        return _flash(q, k, v, causal=causal, block_q=block_q,
                      block_k=block_k, interpret=not on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal)


def decode_attention(q, k_cache, v_cache, length, block_k: int = 512,
                     force_kernel: bool = False):
    if on_tpu() or force_kernel:
        return _decode(q, k_cache, v_cache, length, block_k=block_k,
                       interpret=not on_tpu())
    return ref.decode_attention_ref(q, k_cache, v_cache, length)


def partition_histogram(part_ids, num_partitions: int, block: int = 1024,
                        force_kernel: bool = False):
    if on_tpu() or force_kernel:
        return _hist(part_ids, num_partitions, block=block,
                     interpret=not on_tpu())
    return ref.partition_histogram_ref(part_ids, num_partitions)


def partition_scatter(rows, part_ids, num_partitions: int, block: int = 1024,
                      force_kernel: bool = False):
    if on_tpu() or force_kernel:
        return _scatter(rows, part_ids, num_partitions, block=block,
                        interpret=not on_tpu())
    return ref.partition_scatter_ref(rows, part_ids, num_partitions)
