"""The data plane's single kernel-dispatch point.

Every primitive the analytics operators and the serverless function library
touch routes through here: attention for the model plane, and the
partition / join / aggregate primitives for the analytics plane. Each entry
dispatches to the fastest available implementation — a Pallas kernel on TPU
(``partition_histogram``/``partition_scatter``), a jitted single-pass jnp
computation elsewhere — so callers never carry their own ad-hoc ``jax.jit``
wrappers and every call site shares one compilation cache.

Shape classes: the partition-grouping entry point (``grouping_indices``)
pads its input to the next power of two before hitting the jitted body, so
32 map partitions with 32 different post-filter row counts compile a
handful of executables (one per power-of-two class), not 32 — the
no-per-partition-recompilation property the CI smoke benchmark asserts.
"""

from __future__ import annotations

import threading
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.partition import (
    fused_probe as _fused_probe,
    partition_histogram as _hist,
    partition_scatter as _scatter,
)

HASH_MULT = jnp.uint32(0x9E3779B1)   # Knuth multiplicative hash
EMPTY = jnp.int32(-1)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# -- attention -----------------------------------------------------------------


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, force_kernel: bool = False):
    """(B,S,H,hd) attention; kernel on TPU, oracle elsewhere."""
    if on_tpu() or force_kernel:
        return _flash(q, k, v, causal=causal, block_q=block_q,
                      block_k=block_k, interpret=not on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal)


def decode_attention(q, k_cache, v_cache, length, block_k: int = 512,
                     force_kernel: bool = False):
    if on_tpu() or force_kernel:
        return _decode(q, k_cache, v_cache, length, block_k=block_k,
                       interpret=not on_tpu())
    return ref.decode_attention_ref(q, k_cache, v_cache, length)


# -- partitioning (the shuffle primitive) --------------------------------------


def _hash(keys: jax.Array, bits: int) -> jax.Array:
    h = keys.astype(jnp.uint32) * HASH_MULT
    return (h >> (32 - bits)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("num_partitions",))
def partition_ids(keys: jax.Array, num_partitions: int) -> jax.Array:
    """Radix/hash partition id per row."""
    bits = max(1, int(np.ceil(np.log2(num_partitions))))
    return _hash(keys, bits) % num_partitions


@partial(jax.jit, static_argnames=("num_partitions",))
def partition_permutation(keys: jax.Array, num_partitions: int):
    """Stable permutation grouping rows by partition + per-partition counts.

    The jitted single-pass fallback the dispatch layer uses off-TPU; the
    Pallas histogram/scatter pair computes the same grouping on TPU.
    """
    pids = partition_ids(keys, num_partitions)
    order = jnp.argsort(pids, stable=True)
    counts = jnp.bincount(pids, length=num_partitions)
    return order, counts, pids


def partition_histogram(part_ids, num_partitions: int, block: int = 1024,
                        force_kernel: bool = False):
    """Per-partition row counts. Pallas per-block histograms on TPU (summed
    here), jnp bincount elsewhere. Handles the n == 0 and
    block-non-divisible edges the raw kernel asserts on."""
    n = int(part_ids.shape[0])
    if n == 0:
        return jnp.zeros((num_partitions,), jnp.int32)
    if (on_tpu() or force_kernel) and n % min(block, n) == 0:
        hist = _hist(part_ids, num_partitions, block=block,
                     interpret=not on_tpu())
        return jnp.sum(hist, axis=0).astype(jnp.int32)
    return ref.partition_histogram_ref(part_ids, num_partitions)


def partition_scatter(rows, part_ids, num_partitions: int, block: int = 1024,
                      force_kernel: bool = False):
    """Stable grouping of 2-D rows by partition id -> (grouped, offsets).

    Pallas kernel on TPU when the row count divides the block size; the
    jnp reference otherwise (including the empty input the kernel's grid
    cannot express)."""
    n = int(rows.shape[0])
    if n == 0:
        return rows, jnp.zeros((num_partitions,), jnp.int32)
    if (on_tpu() or force_kernel) and n % min(block, n) == 0:
        return _scatter(rows, part_ids, num_partitions, block=block,
                        interpret=not on_tpu())
    return ref.partition_scatter_ref(rows, part_ids, num_partitions)


def _pad_len(n: int) -> int:
    """Next power of two >= n (floor 8): the shape-class quantizer that
    keeps per-partition row-count jitter from recompiling the jitted
    grouping body."""
    return max(8, 1 << int(np.ceil(np.log2(max(1, n)))))


# (padded_len, num_partitions) pairs already dispatched — tells the kernel
# span whether this call paid a fresh trace/compile or hit the jit cache
_SHAPE_CLASSES: set[tuple[int, int]] = set()

# per-thread padded-vs-actual row tally for every shape-class dispatch; the
# invoker snapshots it around each function body so padding waste lands on
# the invocation record (-> profile_feedback "padding_overhead") instead of
# needing a re-profile to spot a probe-side blowup
_padding_tls = threading.local()


def _note_padding(rows: int, padded: int) -> None:
    c = getattr(_padding_tls, "counts", None)
    if c is None:
        c = _padding_tls.counts = [0, 0]
    c[0] += int(rows)
    c[1] += int(padded)


def padding_counters() -> tuple[int, int]:
    """``(actual_rows, padded_rows)`` dispatched through shape-class-padded
    kernel entry points by this thread since ``reset_padding_counters``."""
    c = getattr(_padding_tls, "counts", None)
    return (c[0], c[1]) if c else (0, 0)


def reset_padding_counters() -> None:
    _padding_tls.counts = [0, 0]


@partial(jax.jit, static_argnames=("num_partitions",))
def _grouping_padded(pids_padded: jax.Array, num_partitions: int):
    """Grouping permutation over a padded id vector.

    Padding rows carry the sentinel id ``num_partitions`` — larger than any
    real id, so the stable sort parks them at the end and the first
    ``offsets[-1]`` entries of ``order`` are exactly the real rows'
    grouping permutation. ``offsets`` has ``num_partitions + 1`` entries
    (exclusive prefix; the last is the total real-row count).
    """
    order = jnp.argsort(pids_padded, stable=True)
    counts = jnp.bincount(pids_padded, length=num_partitions + 1)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(counts[:num_partitions]).astype(jnp.int32)])
    return order, offsets


def grouping_indices(part_ids, num_partitions: int,
                     force_kernel: bool = False):
    """One-call shuffle grouping: ``(order, offsets)`` for a partition-id
    vector, where ``order[offsets[p]:offsets[p+1]]`` are partition ``p``'s
    row indices in stable (original) order.

    This is the single-pass replacement for the per-bucket
    ``np.nonzero``/``take`` loop: one device computation yields every
    bucket's membership at once. Inputs are padded to a power-of-two shape
    class before the jitted body (or the Pallas scatter on TPU) runs, so
    heterogeneous per-partition row counts share a handful of compiled
    executables.
    """
    from repro.obs.tracer import get_tracer

    n = int(part_ids.shape[0])
    if n == 0:
        return (jnp.zeros((0,), jnp.int32),
                jnp.zeros((num_partitions + 1,), jnp.int32))
    n_pad = _pad_len(n)
    _note_padding(n, n_pad)
    shape_class = (n_pad, num_partitions)
    fresh = shape_class not in _SHAPE_CLASSES
    _SHAPE_CLASSES.add(shape_class)
    with get_tracer().span("kernel/grouping", "kernel", rows=n,
                           shape_class=n_pad, buckets=num_partitions,
                           compile="fresh" if fresh else "cached"):
        pids = jnp.asarray(part_ids, jnp.int32)
        if n_pad != n:
            pids = jnp.concatenate(
                [pids, jnp.full((n_pad - n,), num_partitions, jnp.int32)])
        if on_tpu() or force_kernel:
            # Pallas path: scatter the index column through the kernel — the
            # grouped output *is* the permutation (sentinel rows land last),
            # and the kernel's per-partition bases over num_partitions + 1
            # buckets *are* the offsets vector ([0, c0, c0+c1, ..., n]).
            idx = jnp.arange(n_pad, dtype=jnp.int32)[:, None]
            grouped, part_base = _scatter(idx, pids, num_partitions + 1,
                                          interpret=not on_tpu())
            return grouped[:, 0][:n], part_base
        order, offsets = _grouping_padded(pids, num_partitions)
        return order[:n], offsets


def shape_class_count() -> int:
    """Distinct (padded_len, num_partitions) shape classes dispatched so
    far — the compile-cache growth figure the skew regression test bounds
    (salted sub-joins quantize their chunk sizes so a lopsided bucket adds
    at most two classes, not one per chunk)."""
    return len(_SHAPE_CLASSES)


# heavy-hitter sketch sizing: one hash-slot histogram per shuffle writer.
# 512 slots keeps the counter array a single cache line level while a
# dominating key still owns its slot with overwhelming probability.
HOT_SKETCH_SLOTS = 512
HOT_KEYS_K = 8


def heavy_hitter_sketch(keys, k: int = HOT_KEYS_K,
                        num_slots: int = HOT_SKETCH_SLOTS,
                        force_kernel: bool = False,
                        ) -> tuple[tuple[int, int], ...]:
    """Exact top-k heavy hitters of a key column, sketch-then-verify.

    Phase 1 hashes every key into ``num_slots`` counters — the Pallas
    one-hot histogram on TPU (``force_kernel`` for interpret-mode tests),
    the jnp bincount reference elsewhere: the same dispatch as
    ``partition_histogram``, and a single fixed shape class regardless of
    key cardinality. Phase 2 takes the ``k`` heaviest slots as candidates
    and counts their actual keys exactly on the host (a small subset when
    the data is skewed). Returns ``((key, count), ...)`` sorted by
    (-count, key) — deterministic, so the runtime's observed sketch and
    the simulator's recomputation of it are identical tuples.
    """
    n = int(keys.shape[0])
    if n == 0:
        return ()
    k = max(1, int(k))
    keys = jnp.asarray(keys, jnp.int32)
    slot_ids = partition_ids(keys, num_slots)
    hist = np.asarray(partition_histogram(slot_ids, num_slots,
                                          force_kernel=force_kernel))
    cand = np.argsort(-hist, kind="stable")[:k]
    cand = cand[hist[cand] > 0]
    if cand.size == 0:
        return ()
    mask = np.isin(np.asarray(slot_ids), cand)
    sub = np.asarray(keys)[mask]
    uniq, counts = np.unique(sub, return_counts=True)
    order = np.lexsort((uniq, -counts))[:k]
    return tuple((int(uniq[i]), int(counts[i])) for i in order)


def salted_ranges(total_rows: int, salt: int) -> tuple[tuple[int, int], ...]:
    """Row ranges splitting a heavy join bucket ``salt`` ways for the
    salted sub-joins. The chunk size is quantized UP to a power of two
    (``_pad_len``), so every full chunk is exactly one padded shape class
    and only the final remainder chunk can add a second — the cap that
    keeps a skewed bucket from fanning the compile cache into per-chunk
    classes. May return fewer than ``salt`` ranges after quantization."""
    total = int(total_rows)
    if total <= 0:
        return ()
    chunk = _pad_len(-(-total // max(1, int(salt))))
    return tuple((lo, min(lo + chunk, total))
                 for lo in range(0, total, chunk))


def grouping_cache_size() -> int:
    """Compiled-executable count of the jitted grouping body — the CI
    smoke benchmark asserts this stays at one per (shape class, bucket
    count), i.e. no per-partition recompilation."""
    try:
        return int(_grouping_padded._cache_size())
    except AttributeError:  # pragma: no cover - older/newer jax internals
        return -1


# -- joins ---------------------------------------------------------------------


@jax.jit
def sort_merge_join_indices(probe_keys: jax.Array, build_keys: jax.Array):
    """Sort-merge: sort build side, binary-merge probe side.

    Returns (idx_into_build, found) aligned with probe rows.
    """
    build_order = jnp.argsort(build_keys)
    sorted_build = build_keys[build_order]
    pos = jnp.searchsorted(sorted_build, probe_keys)
    pos = jnp.clip(pos, 0, build_keys.shape[0] - 1)
    found = sorted_build[pos] == probe_keys
    idx = jnp.where(found, build_order[pos], 0)
    return idx, found


def _hash_table_size(n: int) -> int:
    # load factor <= 0.25: linear-probing cluster lengths stay far below
    # the probe budget even for multi-million-row build sides
    return max(16, int(2 ** np.ceil(np.log2(4 * n))))


@partial(jax.jit, static_argnames=("max_probes",))
def build_hash_table(build_keys: jax.Array, max_probes: int = 16):
    """Open-addressing (linear probing) insert of unique build keys.

    Parallel insertion: each round, every unplaced key writes its row index
    to its current probe slot; scatter conflicts resolve last-writer-wins,
    losers advance to the next probe position. With load factor <= 0.5 this
    converges in a handful of rounds.
    """
    n = build_keys.shape[0]
    cap = _hash_table_size(n)
    bits = int(np.log2(cap))
    slots = jnp.full((cap,), EMPTY)            # stored row index, -1 = empty
    h0 = _hash(build_keys, bits)
    rows = jnp.arange(n, dtype=jnp.int32)

    def round_(p, carry):
        slots, placed = carry
        pos = (h0 + p) % cap
        # only unplaced keys contending for currently-empty slots
        want = jnp.logical_and(jnp.logical_not(placed), slots[pos] == EMPTY)
        cand = jnp.where(want, rows, EMPTY)
        tgt = jnp.where(want, pos, cap)        # park non-contenders off-table
        slots_ext = jnp.concatenate([slots, jnp.full((1,), EMPTY)])
        slots_ext = slots_ext.at[tgt].max(cand)   # max = deterministic winner
        slots = slots_ext[:cap]
        placed = jnp.logical_or(placed, slots[pos] == rows)
        return slots, placed

    slots, _ = jax.lax.fori_loop(0, max_probes, round_,
                                 (slots, jnp.zeros((n,), bool)))
    return slots


@partial(jax.jit, static_argnames=("max_probes",))
def hash_join_indices(probe_keys: jax.Array, build_keys: jax.Array,
                      slots: jax.Array, max_probes: int = 16):
    """Probe the hash table. Returns (idx_into_build, found) per probe row."""
    cap = slots.shape[0]
    bits = int(np.log2(cap))
    h = _hash(probe_keys, bits)

    def probe(p, carry):
        idx, found = carry
        pos = (h + p) % cap
        cand = slots[pos]
        hit = jnp.logical_and(
            cand != EMPTY,
            jnp.logical_and(build_keys[jnp.maximum(cand, 0)] == probe_keys,
                            jnp.logical_not(found)))
        idx = jnp.where(hit, cand, idx)
        return idx, jnp.logical_or(found, hit)

    idx0 = jnp.zeros_like(probe_keys)
    found0 = jnp.zeros(probe_keys.shape, bool)
    idx, found = jax.lax.fori_loop(0, max_probes, probe, (idx0, found0))
    return idx, found


# -- fused partition+probe (the pipelined join's bucket primitive) -------------

# build sides at or below this padded row count keep the kernel's
# (probe-block, build) one-hot comfortably inside VMEM (~2 MB of int32 at
# 128 x 4096); larger buckets take the jitted sorted-search fallback
FUSED_VMEM_ROWS = 4096


@partial(jax.jit, static_argnames=("num_groups",))
def _fused_probe_padded(pk, v0, v1, bk, bc, bv, num_groups: int):
    """Jitted fallback over shape-class-padded buckets: sort the build side
    once, binary-search every probe key, mask invalid (padding) build rows
    through the sort so a sentinel collision can never fake a match."""
    big = jnp.int32(2**31 - 1)
    keys = jnp.where(bv != 0, bk, big)     # park padding rows at the end
    order = jnp.argsort(keys)
    skeys = keys[order]
    scat = bc[order]
    svalid = bv[order]
    pos = jnp.clip(jnp.searchsorted(skeys, pk), 0, skeys.shape[0] - 1)
    found = jnp.logical_and(skeys[pos] == pk, svalid[pos] != 0)
    cat = jnp.where(found, scat[pos], 0)
    weight = jnp.where(found, v0 * v1, jnp.float32(0.0))
    return cat % num_groups, weight


def fused_probe_groups(probe_keys, v0, v1, build_keys, build_cat,
                       num_groups: int, force_kernel: bool = False):
    """Fused partition+probe+weight for one shuffled join bucket.

    Collapses the bucket's sort-merge join, the found-mask ``where`` and
    the group projection into ONE dispatch: returns ``(group, weight)``
    numpy columns aligned with probe rows, where non-matching probe rows
    carry group 0 / weight 0 — bit-identical to the unfused
    ``join -> where(found) -> cat % G`` pipeline (build keys unique per the
    join contract). Probe and build sides are padded to power-of-two shape
    classes; the Pallas path runs when the build side fits the VMEM budget
    (``FUSED_VMEM_ROWS``), the jitted sorted-search body elsewhere.
    """
    from repro.obs.tracer import get_tracer

    n = int(probe_keys.shape[0])
    m = int(build_keys.shape[0])
    if n == 0 or m == 0:
        return (np.zeros((n,), np.int32), np.zeros((n,), np.float32))
    n_pad, m_pad = _pad_len(n), _pad_len(m)
    _note_padding(n + m, n_pad + m_pad)
    kernel_ok = (on_tpu() or force_kernel) and m_pad <= FUSED_VMEM_ROWS
    with get_tracer().span("kernel/fused_probe", "kernel", rows=n,
                           build_rows=m, shape_class=n_pad,
                           path="pallas" if kernel_ok else "jit"):
        pk = jnp.asarray(probe_keys, jnp.int32)
        v0 = jnp.asarray(v0, jnp.float32)
        v1 = jnp.asarray(v1, jnp.float32)
        if n_pad != n:
            pk = jnp.concatenate([pk, jnp.zeros((n_pad - n,), jnp.int32)])
            v0 = jnp.concatenate([v0, jnp.zeros((n_pad - n,), jnp.float32)])
            v1 = jnp.concatenate([v1, jnp.zeros((n_pad - n,), jnp.float32)])
        bk = jnp.asarray(build_keys, jnp.int32)
        bc = jnp.asarray(build_cat, jnp.int32)
        bv = jnp.ones((m,), jnp.int32)
        if m_pad != m:
            bk = jnp.concatenate([bk, jnp.zeros((m_pad - m,), jnp.int32)])
            bc = jnp.concatenate([bc, jnp.zeros((m_pad - m,), jnp.int32)])
            bv = jnp.concatenate([bv, jnp.zeros((m_pad - m,), jnp.int32)])
        if kernel_ok:
            grp, wgt = _fused_probe(pk, v0, v1, bk, bc, bv, num_groups,
                                    interpret=not on_tpu())
        else:
            grp, wgt = _fused_probe_padded(pk, v0, v1, bk, bc, bv,
                                           num_groups)
        return np.asarray(grp)[:n], np.asarray(wgt)[:n]


# -- aggregation ---------------------------------------------------------------


@partial(jax.jit, static_argnames=("num_segments",))
def segment_sum(values: jax.Array, segment_ids: jax.Array,
                num_segments: int) -> jax.Array:
    """Segment-sum values by id — the grouped-aggregation primitive."""
    return jax.ops.segment_sum(values, segment_ids,
                               num_segments=num_segments)
