"""Radix/hash partition — the shuffle primitive of sort-merge join.

This is the analytics data-plane hot spot (the paper's Fig. 3 "shuffle data
records with the same keys to the same nodes"), TPU-adapted as two passes:

  1. ``partition_histogram`` — per-block histograms (vectorized one-hot
     reduction on the VPU), grid over row blocks.
  2. ``partition_scatter``   — given exclusive per-(block, partition) bases
     (a tiny cumsum on the host side of the kernel), each block computes its
     rows' destination offsets (base + stable local rank via a one-hot
     cumsum) and writes rows to their partition-grouped positions.

Validated against ``ref.partition_scatter_ref`` (stable grouping).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hist_kernel(pid_ref, out_ref, *, num_partitions: int):
    ids = pid_ref[0]                                   # (block,)
    onehot = (ids[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, num_partitions), 1))
    out_ref[0] = jnp.sum(onehot.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("num_partitions", "block",
                                             "interpret"))
def partition_histogram(part_ids: jax.Array, num_partitions: int,
                        block: int = 1024,
                        interpret: bool = False) -> jax.Array:
    """part_ids: (N,) -> per-block histograms (nb, P)."""
    n = part_ids.shape[0]
    block = min(block, n)
    assert n % block == 0
    nb = n // block
    kernel = functools.partial(_hist_kernel, num_partitions=num_partitions)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, num_partitions), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, num_partitions), jnp.int32),
        interpret=interpret,
    )(part_ids.reshape(nb, block))


def _fused_probe_kernel(pk_ref, v0_ref, v1_ref, bk_ref, bc_ref, bv_ref,
                        grp_ref, wgt_ref, *, num_groups: int):
    pk = pk_ref[0]                                     # (block,)
    bk = bk_ref[0]                                     # (m,)
    bc = bc_ref[0]
    bv = bv_ref[0]
    # one-hot equality probe: build keys are unique (join contract), so a
    # probe row matches at most one build column and the masked row-sum of
    # the one-hot matrix *is* the gathered build category
    match = jnp.logical_and(pk[:, None] == bk[None, :],
                            bv[None, :] != 0)          # (block, m)
    mi = match.astype(jnp.int32)
    found = jnp.sum(mi, axis=1) > 0
    cat = jnp.sum(mi * bc[None, :], axis=1)
    grp_ref[0] = cat % num_groups
    wgt_ref[0] = jnp.where(found, v0_ref[0] * v1_ref[0],
                           jnp.float32(0.0))


@functools.partial(jax.jit, static_argnames=("num_groups", "block",
                                             "interpret"))
def fused_probe(probe_keys: jax.Array, v0: jax.Array, v1: jax.Array,
                build_keys: jax.Array, build_cat: jax.Array,
                build_valid: jax.Array, num_groups: int,
                block: int = 128, interpret: bool = False):
    """Fused partition+probe over one join bucket.

    probe_keys/v0/v1: (N,) probe-side columns; build_keys/build_cat/
    build_valid: (M,) build-side columns (``build_valid`` masks padding
    rows). The whole build side rides along as one VMEM-resident block per
    grid step — callers gate on M so the (block, M) one-hot stays inside
    VMEM. Returns ``(group, weight)`` aligned with probe rows: non-matching
    rows get group 0 / weight 0, the same null encoding as the unfused
    join → where() → mod pipeline.
    """
    n = probe_keys.shape[0]
    m = build_keys.shape[0]
    block = min(block, n)
    assert n % block == 0
    nb = n // block
    kernel = functools.partial(_fused_probe_kernel, num_groups=num_groups)
    probe_spec = pl.BlockSpec((1, block), lambda i: (i, 0))
    build_spec = pl.BlockSpec((1, m), lambda i: (0, 0))
    grp, wgt = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[probe_spec, probe_spec, probe_spec,
                  build_spec, build_spec, build_spec],
        out_specs=[pl.BlockSpec((1, block), lambda i: (i, 0)),
                   pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int32),
                   jax.ShapeDtypeStruct((nb, block), jnp.float32)],
        interpret=interpret,
    )(probe_keys.reshape(nb, block), v0.reshape(nb, block),
      v1.reshape(nb, block), build_keys.reshape(1, m),
      build_cat.reshape(1, m), build_valid.reshape(1, m))
    return grp.reshape(n), wgt.reshape(n)


def _scatter_kernel(pid_ref, base_ref, rows_ref, out_ref, *,
                    block: int, num_partitions: int, width: int):
    ids = pid_ref[0]                                   # (block,)
    base = base_ref[0]                                 # (P,)
    onehot = (ids[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, num_partitions), 1))
    onehot = onehot.astype(jnp.int32)
    # stable local rank: how many earlier rows in this block share my pid
    ranks_mat = jnp.cumsum(onehot, axis=0) - onehot    # exclusive
    local_rank = jnp.sum(ranks_mat * onehot, axis=1)   # (block,)
    dest = jnp.sum(base[None, :] * onehot, axis=1) + local_rank

    def write(r, _):
        pos = dest[r]
        pl.store(out_ref, (pl.dslice(pos, 1), pl.dslice(0, width)),
                 rows_ref[0, r][None, :])
        return 0

    jax.lax.fori_loop(0, block, write, 0)


@functools.partial(jax.jit, static_argnames=("num_partitions", "block",
                                             "interpret"))
def partition_scatter(rows: jax.Array, part_ids: jax.Array,
                      num_partitions: int, block: int = 1024,
                      interpret: bool = False):
    """Stable grouping of rows by partition id.

    rows: (N, D); part_ids: (N,). Returns (out_rows, offsets) matching
    ``ref.partition_scatter_ref``.
    """
    n, width = rows.shape
    block = min(block, n)
    assert n % block == 0
    nb = n // block

    hist = partition_histogram(part_ids, num_partitions, block=block,
                               interpret=interpret)          # (nb, P)
    totals = jnp.sum(hist, axis=0)
    part_base = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(totals)[:-1].astype(jnp.int32)])         # (P,)
    block_excl = jnp.cumsum(hist, axis=0) - hist             # (nb, P)
    bases = part_base[None, :] + block_excl                  # (nb, P)

    kernel = functools.partial(_scatter_kernel, block=block,
                               num_partitions=num_partitions, width=width)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, num_partitions), lambda i: (i, 0)),
            pl.BlockSpec((1, block, width), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n, width), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, width), rows.dtype),
        interpret=interpret,
    )(part_ids.reshape(nb, block), bases,
      rows.reshape(nb, block, width))
    return out, part_base
