"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each kernel in this package is validated against these references in
``tests/test_kernels.py`` across shape/dtype sweeps (interpret mode on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q,k,v: (B, S, H, hd) (KV already expanded to H heads). fp32 softmax."""
    b, s, h, hd = q.shape
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (hd ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, length: jax.Array) -> jax.Array:
    """q: (B, H, hd); caches: (B, S, K, hd); length: (B,) valid prefix sizes.

    GQA: H = K * G; query head i attends through kv head i // G.
    """
    b, h, hd = q.shape
    _, s, kh, _ = k_cache.shape
    g = h // kh
    k_exp = jnp.repeat(k_cache, g, axis=2)          # (B, S, H, hd)
    v_exp = jnp.repeat(v_cache, g, axis=2)
    scores = jnp.einsum("bhk,bshk->bhs", q, k_exp,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    valid = jnp.arange(s)[None, :] < length[:, None]
    scores = jnp.where(valid[:, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshk->bhk", probs.astype(v_exp.dtype), v_exp)
    return out.astype(q.dtype)


def partition_histogram_ref(part_ids: jax.Array,
                            num_partitions: int) -> jax.Array:
    """part_ids: (N,) int32 -> (P,) counts."""
    return jnp.bincount(part_ids, length=num_partitions).astype(jnp.int32)


def partition_scatter_ref(rows: jax.Array, part_ids: jax.Array,
                          num_partitions: int):
    """Stable grouping of rows by partition id.

    rows: (N, D); returns (out_rows (N, D), offsets (P,)) where
    out_rows[offsets[p] : offsets[p] + counts[p]] are partition p's rows in
    original order.
    """
    order = jnp.argsort(part_ids, stable=True)
    counts = partition_histogram_ref(part_ids, num_partitions)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    return rows[order], offsets
