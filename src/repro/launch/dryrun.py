import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. runs the control-plane decision workflow (strategy/scale/schedule),
  2. builds the step function (train_step / prefill forward / decode step),
  3. ``jax.jit(...).lower(...).compile()`` against ShapeDtypeStruct inputs
     (no allocation) on the production mesh,
  4. records memory_analysis / cost_analysis / per-collective byte counts
     into a JSON artifact consumed by benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-4b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.common import applicable_shapes, input_specs
from repro.core.config import SHAPES, ModelConfig, OptimizerConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.models.lm import (
    decode_state_axes,
    decode_step,
    forward,
    init_decode_state,
    init_lm,
)
from repro.parallel.sharding import ShardingRules, use_rules
from repro.parallel.strategies import make_rules, plan_cell, strategy_node
from repro.core.decisions import DecisionContext
from repro.training.optimizer import init_opt_state, opt_state_axes
from repro.training.train_step import make_train_step
from repro.launch.hlo_analysis import analyze
from repro.compat import cost_analysis, set_mesh

DEFAULT_OUT = Path("experiments/dryrun")


def _shape_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def _eval_shape_with_axes(fn):
    captured = {}

    def wrapper():
        out, axes = fn()
        captured["axes"] = axes
        return out

    shapes = jax.eval_shape(wrapper)
    return shapes, captured["axes"]


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               pc_overrides=None, profile: str = "optimized"):
    """Returns (fn, example_args(ShapeDtypeStructs), in_shardings, rules, pc).

    This is where the paper's decision workflow executes: strategy_node emits
    the decision tuple and make_rules materializes it as sharding rules.
    """
    if pc_overrides:
        # overrides participate in planning (mb/fsdp depend on them)
        from repro.core.config import ParallelConfig
        pc = plan_cell(cfg, shape, mesh, ParallelConfig(**pc_overrides),
                       profile=profile)
    else:
        pc = plan_cell(cfg, shape, mesh, profile=profile)
    rules = make_rules(mesh, cfg, shape, pc)

    params_shapes, axes = _eval_shape_with_axes(
        lambda: init_lm(cfg, jax.random.PRNGKey(0)))
    p_shardings = jax.tree.map(
        lambda a: rules.sharding(*a), axes,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(isinstance(x, (str, type(None))) for x in v))

    inp = input_specs(cfg, shape)
    inp_axes = {
        "tokens": ("batch", "seq"),
        "labels": ("batch", "seq"),
        "patch_embeds": ("batch", None, "embed"),
        "frame_embeds": ("batch", "seq", None),
    }
    inp_shardings = {k: rules.sharding(*inp_axes[k]) for k in inp}

    if shape.mode == "train":
        if pc.pod_axis_role == "pipeline":
            # packing decision: pipeline the layer stack over pods
            from repro.parallel.pipeline import (
                make_pp_train_step,
                pp_applicable,
                pp_rules,
            )
            assert pp_applicable(cfg, shape, mesh, pc), \
                "pipeline schedule inapplicable to this cell"
            rules = pp_rules(rules)
            p_shardings = jax.tree.map(
                lambda a: rules.sharding(*a), axes,
                is_leaf=lambda v: isinstance(v, tuple)
                and all(isinstance(x, (str, type(None))) for x in v))
            inp_shardings = {k: rules.sharding(*inp_axes[k]) for k in inp}
        opt_shapes = jax.eval_shape(init_opt_state, params_shapes)
        opt_ax = opt_state_axes(axes)
        o_shardings = jax.tree.map(
            lambda a: rules.sharding(*a), opt_ax,
            is_leaf=lambda v: isinstance(v, tuple)
            and all(isinstance(x, (str, type(None))) for x in v))
        state_shapes = {"params": params_shapes, "opt": opt_shapes}
        state_shardings = {"params": p_shardings, "opt": o_shardings}
        if pc.pod_axis_role == "pipeline":
            from repro.parallel.pipeline import make_pp_train_step
            fn = make_pp_train_step(cfg, shape, OptimizerConfig(), pc, rules)
        else:
            fn = make_train_step(cfg, shape, OptimizerConfig(), pc)
        return (fn, (state_shapes, inp), (state_shardings, inp_shardings),
                (state_shardings, None), rules, pc)

    if shape.mode == "prefill":
        fn = partial(forward, cfg=cfg, remat=pc.remat)
        return (fn, (params_shapes, inp), (p_shardings, inp_shardings),
                (None,), rules, pc)

    # decode
    state_shapes, d_axes = _eval_shape_with_axes(
        lambda: (init_decode_state(cfg, shape.global_batch, shape.seq_len),
                 decode_state_axes(cfg)))
    d_shardings = jax.tree.map(
        lambda a: rules.sharding(*a), d_axes,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(isinstance(x, (str, type(None))) for x in v))
    fn = partial(decode_step, cfg=cfg)
    return (fn, (params_shapes, state_shapes, inp["tokens"]),
            (p_shardings, d_shardings, inp_shardings["tokens"]),
            (None, d_shardings), rules, pc)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path = DEFAULT_OUT, pc_overrides=None,
             tag: str = "", profile: str = "optimized") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "ok"}
    if shape_name not in applicable_shapes(cfg):
        record["status"] = "skipped"
        record["reason"] = ("long_500k requires sub-quadratic attention "
                            "(see DESIGN.md §Arch-applicability)")
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"-{tag}" if tag else ""
        (out_dir / f"{arch}--{shape_name}--{mesh_name}{suffix}.json"
         ).write_text(json.dumps(record, indent=2))
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIPPED")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with set_mesh(mesh):
            fn, args, in_sh, out_sh_hint, rules, pc = build_cell(
                cfg, shape, mesh, pc_overrides, profile=profile)
            # donate the mutable state (train: params+opt; decode: caches) —
            # production steps alias these buffers, and without donation the
            # copied outputs double the temp/peak accounting
            donate = (0,) if shape.mode == "train" else \
                (1,) if shape.mode == "decode" else ()
            with use_rules(rules):
                jitted = jax.jit(fn, in_shardings=in_sh,
                                 donate_argnums=donate)
                lowered = jitted.lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = cost_analysis(compiled)
            hlo = compiled.as_text()
            parsed = analyze(hlo)

        from repro.parallel.strategies import exact_param_bytes_per_chip
        n_dev = mesh_devices(mesh)
        record["param_bytes_per_device"] = exact_param_bytes_per_chip(
            cfg, rules)
        record.update({
            "parallel_config": {
                "attn_strategy": pc.attn_strategy,
                "moe_strategy": pc.moe_strategy,
                "layout": pc.layout,
                "microbatches": pc.microbatches,
                "remat": pc.remat,
                "fsdp": pc.fsdp,
                "mlp_mode": pc.mlp_mode,
                "causal_skip": pc.causal_skip,
                "kv_compress": pc.kv_compress,
                "pod_axis_role": pc.pod_axis_role,
            },
            "devices": n_dev,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "tokens_per_step": shape.tokens_per_step,
            # per-device numbers (the HLO is the per-device SPMD program)
            "flops_per_device": parsed.flops,
            "xla_cost_flops_once": float(cost.get("flops", -1.0))
            if cost else -1.0,
            "xla_bytes_accessed_once": float(cost.get("bytes accessed", -1.0))
            if cost else -1.0,
            "collective_bytes_by_kind": parsed.collective_bytes,
            "collective_counts": parsed.collective_counts,
            "collective_bytes": parsed.total_collective_bytes,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
        })
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "peak_memory_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    record[attr] = int(v)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
              f"flops/dev={record['flops_per_device']:.3e}, "
              f"coll={record['collective_bytes']:.3e}B)")
    except Exception as e:  # noqa: BLE001 - record and continue
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"FAILED {record['error']}")

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"-{tag}" if tag else ""
    path = out_dir / f"{arch}--{shape_name}--{mesh_name}{suffix}.json"
    path.write_text(json.dumps(record, indent=2, default=str))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--tag", default="")
    ap.add_argument("--profile", default="optimized",
                    choices=["optimized", "baseline"])
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape_name, multi, Path(args.out),
                               tag=args.tag, profile=args.profile)
                failures += rec["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
