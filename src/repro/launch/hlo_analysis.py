"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` visits each while-loop body exactly once,
so scan-over-layers / microbatch-accumulation programs are undercounted by
the trip count. This module parses optimized HLO text, reconstructs the
computation call graph (while bodies, fusions, calls), extracts loop trip
counts from the while condition computations, and accumulates

  * dot/convolution FLOPs (the MXU work; elementwise flops are negligible),
  * per-collective-kind byte volumes (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute),

each scaled by the product of enclosing trip counts. Validated against
``cost_analysis()`` on unrolled programs (tests/test_hlo_analysis.py).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "c128": 16, "f16": 2, "bf16": 2, "s16": 2,
                "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1,
                "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->")
_WHILE = re.compile(r"\bwhile\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_COLL = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_DOT = re.compile(r"\bdot\(")
_CONV = re.compile(r"\bconvolution\(")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_KERNEL = re.compile(r"window=\{size=([0-9x]+)")


def _shapes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _numel(dims: list[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _nbytes(dt: str, dims: list[int]) -> int:
    return _numel(dims) * _DTYPE_BYTES[dt]


@dataclass
class Computation:
    name: str
    lines: list[str] = field(default_factory=list)


@dataclass
class Costs:
    flops: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0) \
                + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) \
                + v * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def split_computations(hlo: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    entry = ""
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _COMP_HEADER.match(line)
        if m and stripped.endswith("{"):
            current = Computation(m.group(2))
            comps[current.name] = current
            if m.group(1):
                entry = current.name
            continue
        if stripped == "}":
            current = None
            continue
        if current is not None and stripped:
            current.lines.append(stripped)
    return comps, entry


def _result_shapes(line: str) -> list[tuple[str, list[int]]]:
    """Shapes on the LHS (result) of an instruction line."""
    if " = " not in line:
        return []
    rhs = line.split(" = ", 1)[1]
    head = rhs.split("(", 1)[0]
    return _shapes(head)


_OPERAND_NAMES = re.compile(r"%([\w.\-]+)")


def _operand_names(line: str) -> list[str]:
    """Names of the operands of an instruction (optimized HLO has no operand
    types inline — resolve via the computation's symbol table)."""
    if " = " not in line:
        return []
    rhs = line.split(" = ", 1)[1]
    if "(" not in rhs:
        return []
    inner = rhs.split("(", 1)[1]
    depth, end = 1, len(inner)
    for i, ch in enumerate(inner):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_NAMES.findall(inner[:end])


def _symtab(comp: "Computation") -> dict[str, list[tuple[str, list[int]]]]:
    tab: dict[str, list[tuple[str, list[int]]]] = {}
    for line in comp.lines:
        if " = " not in line:
            continue
        name = line.split(" = ", 1)[0].strip().lstrip("%")
        tab[name] = _result_shapes(line)
    return tab


def _dot_flops(line: str, symtab: dict) -> float:
    res = _result_shapes(line)
    names = _operand_names(line)
    if not res or not names:
        return 0.0
    out_elems = sum(_numel(dims) for _, dims in res)
    lhs_shapes = symtab.get(names[0], [])
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1]
    m = _CONTRACT.search(line)
    contracted = 1
    if m:
        for idx in m.group(1).split(","):
            if idx:
                contracted *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contracted


def _conv_flops(line: str, symtab: dict) -> float:
    res = _result_shapes(line)
    names = _operand_names(line)
    if not res or len(names) < 2:
        return 0.0
    out_elems = _numel(res[0][1])
    kshapes = symtab.get(names[1], [])
    if not kshapes:
        return 0.0
    kernel_dims = kshapes[0][1]
    # flops ~= 2 * out_elems * kernel_elems / out_channels
    kernel_elems = _numel(kernel_dims)
    out_ch = res[0][1][-1] if res[0][1] else 1
    per_out = kernel_elems / max(out_ch, 1)
    return 2.0 * out_elems * max(per_out, 1.0)


def _trip_count(cond: Computation) -> int:
    """Extract the loop bound from a while condition computation."""
    best = 1
    for line in cond.lines:
        if "compare(" in line:
            for c in _CONST_INT.findall(line):
                best = max(best, int(c))
    if best == 1:
        for line in cond.lines:
            for c in _CONST_INT.findall(line):
                best = max(best, int(c))
    return max(best, 1)


def analyze(hlo: str) -> Costs:
    comps, entry = split_computations(hlo)
    memo: dict[str, Costs] = {}

    def cost_of(name: str, stack: tuple = ()) -> Costs:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Costs()
        comp = comps[name]
        symtab = _symtab(comp)
        total = Costs()
        for line in comp.lines:
            wm = _WHILE.search(line)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                tm = _TRIP.search(line)
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = _trip_count(comps.get(cond_name,
                                                  Computation("")))
                total.add(cost_of(body_name, stack + (name,)), trips)
                total.add(cost_of(cond_name, stack + (name,)), trips)
                continue
            cm = _COLL.search(line)
            if cm and " = " in line and "-done" not in line.split("(")[0]:
                kind = cm.group(1)
                b = sum(_nbytes(dt, dims) for dt, dims in
                        _result_shapes(line))
                total.collective_bytes[kind] = \
                    total.collective_bytes.get(kind, 0) + b
                total.collective_counts[kind] = \
                    total.collective_counts.get(kind, 0) + 1
            if _DOT.search(line):
                total.flops += _dot_flops(line, symtab)
            elif _CONV.search(line):
                total.flops += _conv_flops(line, symtab)
            for callee in _CALLS.findall(line):
                if "fusion" in line or "call(" in line \
                        or "custom-call" in line or "reduce" in line \
                        or "sort(" in line or "scatter" in line \
                        or "select-and-scatter" in line or "map(" in line:
                    total.add(cost_of(callee, stack + (name,)), 1.0)
        memo[name] = total
        return total

    return cost_of(entry)
