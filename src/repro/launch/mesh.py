"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state: the dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import, then calls ``make_production_mesh``.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(model: int = 1):
    """1-device mesh for CPU smoke tests (data=1, model=1)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_devices(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
