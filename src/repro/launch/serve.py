"""Serving driver: batched request serving with the adaptive batching
decision node.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --requests 16 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import init_lm
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--slo-ms", type=float, default=500.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_seq=args.max_seq, slo_ms=args.slo_ms)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              rng.integers(4, 24)).tolist()
        engine.submit(Request(i, prompt, max_new_tokens=args.max_new))
    done = engine.run(max_steps=4096)
    wall = time.time() - t0

    lat = [time.monotonic() - r.arrival for r in done]
    occ = np.mean(engine.metrics["batch_occupancy"]) \
        if engine.metrics["batch_occupancy"] else 0.0
    print(f"[serve] {cfg.name}: {len(done)}/{args.requests} requests, "
          f"{engine.metrics['generated']} tokens in {wall:.1f}s "
          f"({engine.metrics['generated'] / wall:.1f} tok/s)")
    print(f"[serve] decode steps {engine.metrics['steps']}, prefills "
          f"{engine.metrics['prefills']}, mean batch occupancy {occ:.2f}")
    return done


if __name__ == "__main__":
    main()
