"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt /tmp/repro_run

Runs the full stack: config -> decision workflow (strategy/scale/schedule)
-> sharded train_step -> data pipeline -> supervisor (checkpoint/restart,
straggler watchdog). On CPU use --smoke (reduced config); on a real TPU
slice the same driver runs the full config against the production mesh.
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.config import OptimizerConfig, ParallelConfig, ShapeConfig
from repro.core.decisions import DecisionContext
from repro.ckpt import Supervisor, latest_step, load_checkpoint
from repro.data import Prefetcher, SyntheticSource
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_lm
from repro.parallel.sharding import use_rules
from repro.parallel.strategies import make_rules, strategy_node
from repro.training import init_opt_state, make_train_step
from repro.compat import set_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    shape = ShapeConfig("train_cli", args.seq, args.batch, "train")
    mesh = make_smoke_mesh()

    # control plane: resolve the decision tuple for this cell
    node = strategy_node(cfg, shape, mesh)
    decision = node.decide(DecisionContext())
    pc = decision.extra("parallel_config")
    if args.microbatches > 1:
        import dataclasses
        pc = dataclasses.replace(pc, microbatches=args.microbatches)
    rules = make_rules(mesh, cfg, shape, pc)
    print(f"[train] {cfg.name} decision: {decision.func} "
          f"scale={pc.microbatches} schedule={decision.schedule.policy}")

    opt_cfg = OptimizerConfig(warmup_steps=10)
    with set_mesh(mesh), use_rules(rules):
        params, _ = init_lm(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "opt": init_opt_state(params)}
        start = 0
        if args.resume and latest_step(args.ckpt) is not None:
            state, extra = load_checkpoint(args.ckpt, like=state)
            start = extra.get("step", 0)
            print(f"[train] resumed from step {start}")

        step_fn = jax.jit(make_train_step(cfg, shape, opt_cfg, pc,
                                          total_steps=args.steps,
                                          q_chunk=min(args.seq, 512),
                                          ssm_chunk=min(args.seq, 64)))
        source = SyntheticSource(cfg, shape, seed=1)
        prefetch = Prefetcher(source, start_step=start)
        losses = []

        def wrapped_step(st, batch):
            st, metrics = step_fn(st, batch)
            return st, metrics

        def batch_fn(step):
            s, b = prefetch.next()
            return {k: jnp.asarray(v) for k, v in b.items()}

        sup = Supervisor(wrapped_step, batch_fn, args.ckpt,
                         ckpt_every=args.ckpt_every)

        # run with logging via a small shim
        t0 = time.time()
        step = start
        orig_step_fn = sup.step_fn

        def logging_step(st, batch):
            nonlocal step
            st, metrics = orig_step_fn(st, batch)
            step += 1
            if step % args.log_every == 0:
                loss = float(metrics["loss"])
                losses.append(loss)
                tput = shape.tokens_per_step * args.log_every \
                    / max(time.time() - logging_step.t, 1e-9)
                logging_step.t = time.time()
                print(f"[train] step {step:5d} loss {loss:8.4f} "
                      f"grad_norm {float(metrics['grad_norm']):7.3f} "
                      f"tok/s {tput_fmt(tput=tput)}")
            return st, metrics

        def tput_fmt(tput):
            return f"{tput:,.0f}"

        logging_step.t = time.time()
        sup.step_fn = logging_step
        state, final = sup.run(state, args.steps, start_step=start)
        prefetch.close()
        wall = time.time() - t0
        print(f"[train] finished at step {final} in {wall:.1f}s; "
              f"restarts={sup.restarts} stragglers={len(sup.stragglers)}")
        if len(losses) >= 2:
            print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
                  f"({'improved' if losses[-1] < losses[0] else 'flat'})")
    return losses


if __name__ == "__main__":
    main()
