"""Model zoo: unified LM covering all assigned architecture families."""

from repro.models.lm import (  # noqa: F401
    decode_state_axes,
    decode_step,
    forward,
    init_decode_state,
    init_lm,
)
