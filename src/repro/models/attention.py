"""Grouped-query attention with strategy-aware sharding annotations.

Three control-plane strategies (picked by decision nodes, see
``repro/parallel/strategies.py``) are expressed purely through logical-axis
rules — the math below is strategy-agnostic:

  * head_tp  — heads sharded over ``model`` (Megatron TP); residual replicated.
  * seq_tp   — residual sequence-sharded over ``model``; KV projections are
               *broadcast* (all-gather) to every shard — the paper's hash-join
               move (ship the small table), used when head counts don't divide
               the model axis.
  * decode   — KV cache sharded along its sequence axis; softmax statistics
               combine across shards (flash-decode, GSPMD-inferred).

The einsum formulation here is the pure-JAX data plane; the Pallas kernels in
``repro/kernels`` implement the same contract for the TPU hot path and are
validated against ``repro/kernels/ref.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.core.config import ModelConfig
from repro.models.layers import _init, apply_rope
from repro.parallel.sharding import current_rules, logical_shard

Params = dict
Axes = dict

NEG_INF = -1e9


def init_attention(cfg: ModelConfig, key) -> tuple[Params, Axes]:
    d, h, k_heads = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 4)
    params: Params = {
        "wq": _init(keys[0], (d, h, hd), d ** -0.5, dtype),
        "wk": _init(keys[1], (d, k_heads, hd), d ** -0.5, dtype),
        "wv": _init(keys[2], (d, k_heads, hd), d ** -0.5, dtype),
        "wo": _init(keys[3], (h, hd, d), (h * hd) ** -0.5, dtype),
    }
    axes: Axes = {
        "wq": ("w_embed", "heads", "qkv"),
        "wk": ("w_embed", "kv_heads", "qkv"),
        "wv": ("w_embed", "kv_heads", "qkv"),
        "wo": ("heads", "qkv", "w_embed"),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((h, hd), dtype)
        params["bk"] = jnp.zeros((k_heads, hd), dtype)
        params["bv"] = jnp.zeros((k_heads, hd), dtype)
        axes["bq"] = ("heads", "qkv")
        axes["bk"] = ("kv_heads", "qkv")
        axes["bv"] = ("kv_heads", "qkv")
    return params, axes


def _project_qkv(params: Params, x: jax.Array, positions: jax.Array,
                 cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       q_chunk: int, causal: bool = True,
                       causal_skip: bool = False) -> jax.Array:
    """Blocked causal attention: O(q_chunk * S) score memory.

    q, k, v: (B, S, H, hd) — KV already expanded to H query heads.
    ``causal_skip`` unrolls the chunk loop with static KV prefixes so the
    strictly-upper-triangle chunk blocks are never computed (~2x fewer
    attention FLOPs at long context; §Perf H2).
    """
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    q_chunk = min(q_chunk, s)
    n_chunks = max(1, s // q_chunk)
    assert s % q_chunk == 0, (s, q_chunk)

    def chunk_out(chunk_id, qb, k_in, v_in):
        scores = jnp.einsum("bchk,bshk->bhcs", qb, k_in,
                            preferred_element_type=jnp.float32)
        scores = scores * scale
        if causal:
            q_idx = chunk_id * q_chunk + jnp.arange(q_chunk)
            kv_idx = jnp.arange(k_in.shape[1])
            mask = q_idx[:, None] >= kv_idx[None, :]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v_in.dtype)
        return jnp.einsum("bhcs,bshk->bchk", probs, v_in)

    if causal and causal_skip and n_chunks > 1:
        outs = []
        for ci in range(n_chunks):
            end = (ci + 1) * q_chunk
            qb = q[:, ci * q_chunk: end]
            outs.append(chunk_out(ci, qb, k[:, :end], v[:, :end]))
        return jnp.concatenate(outs, axis=1)

    q_blocks = jnp.moveaxis(q.reshape(b, n_chunks, q_chunk, h, hd), 1, 0)
    out = jax.lax.map(lambda args: chunk_out(args[0], args[1], k, v),
                      (jnp.arange(n_chunks), q_blocks))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


def _int8_broadcast(t: jax.Array) -> jax.Array:
    """Force the seq_tp KV broadcast onto an int8 wire (§Perf H2).

    A with_sharding_constraint on the quantized tensor is NOT enough: the
    partitioner may legally all-gather the bf16 producer and re-quantize
    replicated (measured: zero wire saving). shard_map pins the collective:
    quantize shard-locally (scales over head_dim only), all-gather the int8
    payload + fp32 scale sliver explicitly, dequantize after."""
    rules = current_rules()
    if rules is None or rules.mesh is None \
            or rules.rules.get("seq") is None:
        return logical_shard(t, "batch", "kv_seq", "kv_rep", "qkv")
    mesh = rules.mesh
    in_spec = rules.spec("batch", "seq", "kv_rep", "qkv")
    out_spec = rules.spec("batch", "kv_seq", "kv_rep", "qkv")

    @jax.custom_vjp
    def gather_int8(local):
        absmax = jnp.maximum(jnp.max(jnp.abs(local.astype(jnp.float32)),
                                     axis=3, keepdims=True), 1e-9)
        scale = absmax / 127.0
        q = jnp.clip(jnp.round(local.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        q_full = jax.lax.all_gather(q, "model", axis=1, tiled=True)
        s_full = jax.lax.all_gather(scale.astype(jnp.float32), "model",
                                    axis=1, tiled=True)
        return (q_full.astype(jnp.float32) * s_full).astype(local.dtype)

    # straight-through estimator: round() has zero gradient, so the
    # backward pass is the exact identity-all-gather transpose (bf16
    # reduce-scatter); only fwd + remat-fwd ride the int8 wire.
    def _fwd(local):
        return gather_int8(local), None

    def _bwd(_, g):
        return (jax.lax.psum_scatter(g, "model", scatter_dimension=1,
                                     tiled=True),)

    gather_int8.defvjp(_fwd, _bwd)

    return shard_map(gather_int8, mesh=mesh, in_specs=(in_spec,),
                         out_specs=out_spec, check_vma=False)(t)


def attention(params: Params, x: jax.Array, positions: jax.Array,
              cfg: ModelConfig, q_chunk: int = 1024,
              causal: bool = True) -> jax.Array:
    """Full (train / prefill) attention. x: (B, S, D)."""
    b, s, _ = x.shape
    kh = cfg.num_kv_heads
    g = cfg.num_heads // kh
    hd = cfg.resolved_head_dim
    rules = current_rules()
    kv_compress = bool(rules and rules.rules.get("kv_compress"))
    causal_skip = bool(rules and rules.rules.get("causal_skip"))

    q, k, v = _project_qkv(params, x, positions, cfg)
    q = logical_shard(q, "batch", "seq", "heads", "qkv")
    # Hash-join move: under seq_tp the small (num_kv_heads-wide) KV tensors
    # are broadcast (all-gathered) to every shard *before* the g-fold expand.
    if kv_compress:
        k = _int8_broadcast(k)
        v = _int8_broadcast(v)
    else:
        k = logical_shard(k, "batch", "kv_seq", "kv_rep", "qkv")
        v = logical_shard(v, "batch", "kv_seq", "kv_rep", "qkv")
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = logical_shard(k, "batch", "kv_seq", "heads", "qkv")
    v = logical_shard(v, "batch", "kv_seq", "heads", "qkv")

    out = _chunked_attention(q, k, v, q_chunk=q_chunk, causal=causal,
                             causal_skip=causal_skip)
    out = logical_shard(out, "batch", "seq", "heads", "qkv")
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return logical_shard(y, "batch", "seq", "embed")


def prefill_attention(params: Params, cache: tuple[jax.Array, jax.Array],
                      x: jax.Array, positions: jax.Array, cfg: ModelConfig,
                      q_chunk: int = 1024,
                      ) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Process a whole prompt and populate the KV cache. x: (B, S, D)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, positions, cfg)
    k_cache, v_cache = cache
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, 0, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, 0, 0, 0))
    g = cfg.num_heads // cfg.num_kv_heads
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    out = _chunked_attention(q, k, v, q_chunk=min(q_chunk, s), causal=True)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return logical_shard(y, "batch", "seq", "embed"), (k_cache, v_cache)


# -- Decode path ---------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  dtype=None) -> tuple[jax.Array, jax.Array]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = jnp.zeros((batch, max_seq, kh, hd), dtype)
    v = jnp.zeros((batch, max_seq, kh, hd), dtype)
    return k, v


def cache_axes() -> tuple[str, ...]:
    return ("batch", "cache_seq", "kv_heads", "qkv")


def decode_attention(params: Params, cache: tuple[jax.Array, jax.Array],
                     x: jax.Array, positions: jax.Array, cfg: ModelConfig,
                     ) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One decode step. x: (B, 1, D); positions: (B,) current index.

    The KV cache is sharded along ``cache_seq``; the softmax over the sharded
    sequence axis lowers to per-shard partials + a tiny all-reduce
    (flash-decode, inferred by GSPMD).
    """
    b, one, _ = x.shape
    assert one == 1
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // kh
    k_cache, v_cache = cache
    max_seq = k_cache.shape[1]

    q, k_new, v_new = _project_qkv(params, x, positions[:, None], cfg)
    batch_idx = jnp.arange(b)
    k_cache = k_cache.at[batch_idx, positions].set(k_new[:, 0])
    v_cache = v_cache.at[batch_idx, positions].set(v_new[:, 0])
    k_cache = logical_shard(k_cache, *cache_axes())
    v_cache = logical_shard(v_cache, *cache_axes())

    q = q.reshape(b, kh, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", q, k_cache,
                        preferred_element_type=jnp.float32)
    scores = scores * (hd ** -0.5)
    valid = jnp.arange(max_seq)[None, :] <= positions[:, None]   # (B, S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v_cache)
    out = out.reshape(b, 1, cfg.num_heads, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return logical_shard(y, "batch", "seq", "embed"), (k_cache, v_cache)
