"""Shared model layers: norms, embeddings, RoPE, SwiGLU MLP.

All layers are functional: ``init_*`` builds a param pytree (plus a parallel
pytree of logical-axis annotations used for sharding), ``apply`` style
functions consume it. Compute dtype is bf16 by default with fp32 norm/softmax
accumulation.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import logical_shard, pad_to_multiple

Params = dict
Axes = dict

VOCAB_PAD = 128  # pad vocab to a multiple of this (MXU lane + TP divisibility)


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# -- RMSNorm -----------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.bfloat16) -> tuple[Params, Axes]:
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)).astype(x.dtype)


# -- Embedding / unembedding ---------------------------------------------------


def init_embedding(vocab: int, d: int, key, dtype=jnp.bfloat16,
                   tie: bool = False) -> tuple[Params, Axes]:
    vpad = pad_to_multiple(vocab, VOCAB_PAD)
    k1, k2 = jax.random.split(key)
    params: Params = {"table": _init(k1, (vpad, d), d ** -0.5, dtype)}
    axes: Axes = {"table": ("vocab", "w_embed")}
    if not tie:
        params["unembed"] = _init(k2, (d, vpad), d ** -0.5, dtype)
        axes["unembed"] = ("w_embed", "vocab")
    return params, axes


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    return logical_shard(out, "batch", "seq", "embed")


def unembed(params: Params, x: jax.Array, true_vocab: int) -> jax.Array:
    """Project to (padded) logits; padded columns are forced to -inf."""
    table = params.get("unembed")
    if table is None:
        table = params["table"].T
    logits = jnp.einsum("bsd,dv->bsv", x, table)
    logits = logical_shard(logits, "batch", "seq", "vocab")
    vpad = table.shape[-1]
    if vpad != true_vocab:
        mask = (jnp.arange(vpad) < true_vocab)
        logits = jnp.where(mask[None, None, :], logits, jnp.float32(-1e9))
    return logits


# -- Rotary position embeddings ------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponents = np.arange(0, head_dim, 2, dtype=np.float64) / head_dim
    return jnp.asarray(1.0 / (theta ** exponents), jnp.float32)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    sin = jnp.sin(angles)[..., None, :]                    # (..., s, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rot.astype(x.dtype)


# -- SwiGLU MLP ----------------------------------------------------------------


def init_mlp(d: int, d_ff: int, key, dtype=jnp.bfloat16) -> tuple[Params, Axes]:
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "gate": _init(k1, (d, d_ff), d ** -0.5, dtype),
        "up": _init(k2, (d, d_ff), d ** -0.5, dtype),
        "down": _init(k3, (d_ff, d), d_ff ** -0.5, dtype),
    }
    axes = {
        "gate": ("w_embed", "mlp"),
        "up": ("w_embed", "mlp"),
        "down": ("mlp", "w_embed"),
    }
    return params, axes


def mlp(params: Params, x: jax.Array) -> jax.Array:
    # Megatron-SP transition point: under seq_tp the residual is
    # sequence-sharded; "mlp_seq" -> None triggers the all-gather here and
    # the output annotation below reduce-scatters back.
    x = logical_shard(x, "batch", "mlp_seq", "embed")
    gate = jnp.einsum("bsd,df->bsf", x, params["gate"])
    up = jnp.einsum("bsd,df->bsf", x, params["up"])
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    hidden = logical_shard(hidden, "batch", "mlp_seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", hidden, params["down"])
    return logical_shard(out, "batch", "seq", "embed")


def tree_size(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
