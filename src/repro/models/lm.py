"""Unified LM covering all assigned families (dense / MoE / SSM / hybrid /
VLM-stub / audio-stub).

Layers are organized as R repeats of a *block pattern* of period P
(``cfg.block_pattern``); parameters for each pattern position are stacked over
repeats and the forward pass is a ``lax.scan`` over repeats with the period
unrolled inside — this keeps HLO size O(P), independent of depth (essential
for the 80-layer dry-runs).

All functions are pure and ``jax.eval_shape``-compatible: the multi-pod
dry-run lowers them with ShapeDtypeStruct params and never allocates.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.config import BlockKind, FFNKind, Frontend, ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    _init,
    embed,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
    unembed,
)
from repro.parallel.sharding import logical_shard

Params = dict
AUDIO_FRAME_DIM = 128   # EnCodec latent dim (stub frontend)


# -- structure helpers ----------------------------------------------------------


def _pattern(cfg: ModelConfig) -> tuple[list[BlockKind], int]:
    pattern = [BlockKind(k) for k in cfg.block_pattern]
    p = len(pattern)
    assert cfg.num_layers % p == 0, (cfg.num_layers, p)
    if cfg.moe is not None and cfg.ffn == FFNKind.MOE:
        assert p % cfg.moe.every_k_layers == 0 or cfg.moe.every_k_layers == 1
    return pattern, cfg.num_layers // p


_BLOCK_INIT: dict[BlockKind, Callable] = {
    BlockKind.ATTENTION: attn_mod.init_attention,
    BlockKind.MAMBA: ssm_mod.init_mamba,
    BlockKind.MLSTM: xlstm_mod.init_mlstm,
    BlockKind.SLSTM: xlstm_mod.init_slstm,
}

_BLOCK_APPLY: dict[BlockKind, Callable] = {
    BlockKind.MAMBA: ssm_mod.mamba,
    BlockKind.MLSTM: xlstm_mod.mlstm,
    BlockKind.SLSTM: xlstm_mod.slstm,
}


def _init_layer(cfg: ModelConfig, layer: int, key) -> tuple[Params, dict]:
    kind = cfg.block_kind(layer)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    block_p, block_a = _BLOCK_INIT[kind](cfg, k1)
    n1_p, n1_a = init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype))
    params: Params = {"norm1": n1_p, "block": block_p}
    axes = {"norm1": n1_a, "block": block_a}
    if cfg.ffn != FFNKind.NONE:
        n2_p, n2_a = init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype))
        params["norm2"] = n2_p
        axes["norm2"] = n2_a
        if cfg.layer_is_moe(layer):
            ffn_p, ffn_a = moe_mod.init_moe(cfg, k2)
        else:
            ffn_p, ffn_a = init_mlp(cfg.d_model, cfg.d_ff, k2,
                                    jnp.dtype(cfg.dtype))
        params["ffn"] = ffn_p
        axes["ffn"] = ffn_a
    return params, axes


def init_lm(cfg: ModelConfig, key) -> tuple[Params, dict]:
    """Returns (params, logical_axes) with identical tree structure.

    params["blocks"] is a tuple over pattern positions; each leaf is stacked
    over the R repeats on axis 0 (logical axis "layers").
    """
    pattern, repeats = _pattern(cfg)
    keys = jax.random.split(key, cfg.num_layers + 3)

    emb_p, emb_a = init_embedding(cfg.vocab_size, cfg.d_model, keys[-1],
                                  jnp.dtype(cfg.dtype), tie=cfg.tie_embeddings)
    fnorm_p, fnorm_a = init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype))
    params: Params = {"embed": emb_p, "final_norm": fnorm_p}
    axes = {"embed": emb_a, "final_norm": fnorm_a}

    if cfg.frontend == Frontend.VISION_STUB.value:
        params["patch_proj"] = _init(keys[-2], (cfg.d_model, cfg.d_model),
                                     cfg.d_model ** -0.5, jnp.dtype(cfg.dtype))
        axes["patch_proj"] = ("w_embed", None)
    elif cfg.frontend == Frontend.AUDIO_STUB.value:
        params["frame_proj"] = _init(keys[-2], (AUDIO_FRAME_DIM, cfg.d_model),
                                     AUDIO_FRAME_DIM ** -0.5,
                                     jnp.dtype(cfg.dtype))
        axes["frame_proj"] = (None, "w_embed")

    blocks = []
    blocks_axes = []
    for p in range(len(pattern)):
        per_repeat = [
            _init_layer(cfg, r * len(pattern) + p,
                        keys[r * len(pattern) + p])
            for r in range(repeats)
        ]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[pr[0] for pr in per_repeat])
        ax = jax.tree.map(
            lambda a: ("layers",) + a,
            per_repeat[0][1],
            is_leaf=lambda v: isinstance(v, tuple)
            and all(isinstance(x, (str, type(None))) for x in v),
        )
        blocks.append(stacked)
        blocks_axes.append(ax)
    params["blocks"] = tuple(blocks)
    axes["blocks"] = tuple(blocks_axes)
    return params, axes


# -- forward --------------------------------------------------------------------


def _frontend_embed(params: Params, inputs: dict, cfg: ModelConfig):
    h = embed(params["embed"], inputs["tokens"])
    if cfg.frontend == Frontend.VISION_STUB.value:
        patches = jnp.einsum("bpd,de->bpe",
                             inputs["patch_embeds"].astype(h.dtype),
                             params["patch_proj"])
        h = jnp.concatenate([patches, h], axis=1)
        h = logical_shard(h, "batch", "seq", "embed")
    elif cfg.frontend == Frontend.AUDIO_STUB.value:
        h = h + jnp.einsum("bsf,fd->bsd",
                           inputs["frame_embeds"].astype(h.dtype),
                           params["frame_proj"])
        h = logical_shard(h, "batch", "seq", "embed")
    return h


def _apply_block(kind: BlockKind, layer_params: Params, h: jax.Array,
                 positions: jax.Array, cfg: ModelConfig, chunk: int,
                 q_chunk: int, is_moe: bool, aux: jax.Array):
    normed = rmsnorm(layer_params["norm1"], h, cfg.norm_eps)
    if kind == BlockKind.ATTENTION:
        out = attn_mod.attention(layer_params["block"], normed, positions,
                                 cfg, q_chunk=q_chunk)
    else:
        out = _BLOCK_APPLY[kind](layer_params["block"], normed, cfg,
                                 chunk=chunk)
    h = h + out
    if "ffn" in layer_params:
        normed = rmsnorm(layer_params["norm2"], h, cfg.norm_eps)
        if is_moe:
            out, layer_aux = moe_mod.moe(layer_params["ffn"], normed, cfg)
            aux = aux + layer_aux
        else:
            out = mlp(layer_params["ffn"], normed)
        h = h + out
    h = logical_shard(h, "batch", "seq", "embed")
    return h, aux


def forward_hidden(params: Params, inputs: dict, cfg: ModelConfig,
                   remat: str = "block", q_chunk: int = 1024,
                   ssm_chunk: int = 128) -> tuple[jax.Array, jax.Array]:
    """Full forward up to the final norm. Returns (hidden, moe_aux_loss).

    The unembedding is left to the caller: the training loss fuses it into a
    sequence-chunked cross-entropy so the fp32 logits (B,S,V) never
    materialize.
    """
    pattern, repeats = _pattern(cfg)
    h = _frontend_embed(params, inputs, cfg)
    b, s, _ = h.shape
    positions = inputs.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def layer_group(carry, group_params):
        h, aux = carry
        for p, kind in enumerate(pattern):
            is_moe = cfg.layer_is_moe(p)   # uniform across repeats (P % k == 0)
            h, aux = _apply_block(kind, group_params[p], h, positions, cfg,
                                  ssm_chunk, q_chunk, is_moe, aux)
        return (h, aux), None

    body = layer_group
    if remat == "block":
        body = jax.checkpoint(layer_group,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        body = jax.checkpoint(
            layer_group,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return h, aux


def forward(params: Params, inputs: dict, cfg: ModelConfig,
            remat: str = "block", q_chunk: int = 1024,
            ssm_chunk: int = 128) -> tuple[jax.Array, jax.Array]:
    """Full forward returning fp32 logits (prefill / eval / smoke tests)."""
    h, aux = forward_hidden(params, inputs, cfg, remat=remat,
                            q_chunk=q_chunk, ssm_chunk=ssm_chunk)
    logits = unembed(params["embed"], h, cfg.vocab_size).astype(jnp.float32)
    return logits, aux


# -- decode ----------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Per-pattern-position stacked recurrent state (KV caches / SSM states)."""
    pattern, repeats = _pattern(cfg)

    def one(kind: BlockKind):
        if kind == BlockKind.ATTENTION:
            k, v = attn_mod.init_kv_cache(cfg, batch, max_seq)
            return {"k": k, "v": v}
        if kind == BlockKind.MAMBA:
            return ssm_mod.init_mamba_state(cfg, batch)
        if kind == BlockKind.MLSTM:
            return xlstm_mod.init_mlstm_state(cfg, batch)
        return xlstm_mod.init_slstm_state(cfg, batch)

    states = tuple(
        jax.tree.map(lambda *xs: jnp.stack(xs),
                     *[one(kind) for _ in range(repeats)])
        for kind in pattern
    )
    return {"layers": states, "pos": jnp.zeros((batch,), jnp.int32)}


def decode_state_axes(cfg: ModelConfig) -> dict:
    pattern, _ = _pattern(cfg)

    def one(kind: BlockKind):
        if kind == BlockKind.ATTENTION:
            ca = attn_mod.cache_axes()
            return {"k": ("layers",) + ca, "v": ("layers",) + ca}
        if kind == BlockKind.MAMBA:
            base = ssm_mod.mamba_state_axes()
        elif kind == BlockKind.MLSTM:
            base = xlstm_mod.mlstm_state_axes()
        else:
            base = xlstm_mod.slstm_state_axes()
        return {k: ("layers",) + v for k, v in base.items()}

    return {"layers": tuple(one(k) for k in pattern),
            "pos": ("batch",)}


def _prefill_block(kind: BlockKind, layer_params: Params, state, h,
                   positions, cfg: ModelConfig, q_chunk: int,
                   ssm_chunk: int):
    normed = rmsnorm(layer_params["norm1"], h, cfg.norm_eps)
    if kind == BlockKind.ATTENTION:
        out, (k, v) = attn_mod.prefill_attention(
            layer_params["block"], (state["k"], state["v"]), normed,
            positions, cfg, q_chunk=q_chunk)
        new_state = {"k": k, "v": v}
    elif kind == BlockKind.MAMBA:
        out, new_state = ssm_mod.mamba(layer_params["block"], normed, cfg,
                                       chunk=ssm_chunk, return_state=True)
    elif kind == BlockKind.MLSTM:
        out, new_state = xlstm_mod.mlstm(layer_params["block"], normed, cfg,
                                         return_state=True)
    else:
        out, new_state = xlstm_mod.slstm(layer_params["block"], normed, cfg,
                                         return_state=True)
    h = h + out
    if "ffn" in layer_params:
        normed = rmsnorm(layer_params["norm2"], h, cfg.norm_eps)
        if "router" in layer_params["ffn"]:
            out, _ = moe_mod.moe(layer_params["ffn"], normed, cfg)
        else:
            out = mlp(layer_params["ffn"], normed)
        h = h + out
    return h, new_state


def prefill_step(params: Params, state: dict, inputs: dict,
                 cfg: ModelConfig, q_chunk: int = 1024,
                 ssm_chunk: int = 128) -> tuple[jax.Array, dict]:
    """Process full prompts, populate per-layer states, return last-position
    logits. inputs["tokens"]: (B, S); all prompts occupy positions [0, S)."""
    pattern, repeats = _pattern(cfg)
    h = _frontend_embed(params, inputs, cfg)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def layer_group(h, xs):
        group_params, group_state = xs
        new_states = []
        for p, kind in enumerate(pattern):
            h, ns = _prefill_block(kind, group_params[p], group_state[p], h,
                                   positions, cfg, q_chunk, ssm_chunk)
            new_states.append(ns)
        return h, tuple(new_states)

    h, new_layer_states = jax.lax.scan(
        layer_group, h, (params["blocks"], state["layers"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params["embed"], h[:, -1:], cfg.vocab_size)
    return logits.astype(jnp.float32), {
        "layers": new_layer_states,
        "pos": jnp.full((b,), s, jnp.int32),
    }


def _decode_block(kind: BlockKind, layer_params: Params, state, h, positions,
                  cfg: ModelConfig):
    normed = rmsnorm(layer_params["norm1"], h, cfg.norm_eps)
    if kind == BlockKind.ATTENTION:
        out, (k, v) = attn_mod.decode_attention(
            layer_params["block"], (state["k"], state["v"]), normed,
            positions, cfg)
        new_state = {"k": k, "v": v}
    elif kind == BlockKind.MAMBA:
        out, new_state = ssm_mod.mamba_step(layer_params["block"], state,
                                            normed, cfg)
    elif kind == BlockKind.MLSTM:
        out, new_state = xlstm_mod.mlstm_step(layer_params["block"], state,
                                              normed, cfg)
    else:
        out, new_state = xlstm_mod.slstm_step(layer_params["block"], state,
                                              normed, cfg)
    h = h + out
    if "ffn" in layer_params:
        normed = rmsnorm(layer_params["norm2"], h, cfg.norm_eps)
        if isinstance(layer_params["ffn"], dict) \
                and "router" in layer_params["ffn"]:
            out, _ = moe_mod.moe(layer_params["ffn"], normed, cfg)
        else:
            out = mlp(layer_params["ffn"], normed)
        h = h + out
    return h, new_state


def decode_step(params: Params, state: dict, tokens: jax.Array,
                cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One token for every sequence. tokens: (B, 1) -> logits (B, 1, V)."""
    pattern, repeats = _pattern(cfg)
    h = embed(params["embed"], tokens)
    positions = state["pos"]

    def layer_group(h, xs):
        group_params, group_state = xs
        new_states = []
        for p, kind in enumerate(pattern):
            h, ns = _decode_block(kind, group_params[p], group_state[p], h,
                                  positions, cfg)
            new_states.append(ns)
        return h, tuple(new_states)

    h, new_layer_states = jax.lax.scan(
        layer_group, h, (params["blocks"], state["layers"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg.vocab_size).astype(jnp.float32)
    return logits, {"layers": new_layer_states, "pos": positions + 1}
