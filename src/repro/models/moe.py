"""Token-choice top-k MoE with sort-based capacity dispatch.

The dispatch buffer ``(B, E, C, D)`` is the "shuffle" of the paper's join
analogy. Two control-plane strategies are expressed purely as sharding
constraints on that buffer (decision node ``moe_strategy``):

  * ``all_to_all`` — experts sharded over ``model``; the dispatch scatter
    redistributes tokens to the expert-owning shards (sort-merge join: both
    sides move by key).
  * ``gather``     — dispatch buffer replicated over ``model``; every shard
    sees all tokens, computes only its local experts, partial outputs
    all-reduce (hash join: broadcast the tokens, keep experts in place).
    Wins when experts are small / token volume is low (paper Fig. 4 regime
    where the broadcast side is cheap).

The sort is per batch row so it never crosses the data-parallel sharding.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.core.config import ModelConfig, MoEConfig
from repro.models.layers import _init
from repro.parallel.sharding import current_rules, logical_shard

Params = dict
Axes = dict


def init_moe(cfg: ModelConfig, key) -> tuple[Params, Axes]:
    assert cfg.moe is not None
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_expert
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 4)
    params: Params = {
        "router": _init(keys[0], (d, e), d ** -0.5, jnp.float32),
        "gate": _init(keys[1], (e, d, f), d ** -0.5, dtype),
        "up": _init(keys[2], (e, d, f), d ** -0.5, dtype),
        "down": _init(keys[3], (e, f, d), f ** -0.5, dtype),
    }
    axes: Axes = {
        "router": ("w_embed", None),
        "gate": ("expert", "w_embed", "mlp"),
        "up": ("expert", "w_embed", "mlp"),
        "down": ("expert", "mlp", "w_embed"),
    }
    return params, axes


def _capacity(tokens: int, m: MoEConfig) -> int:
    c = int(tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(4, -(-c // 4) * 4)          # round up to a multiple of 4


def _dispatch_indices(expert_idx: jax.Array, top_k: int, capacity: int):
    """Per-row sort-based dispatch bookkeeping.

    expert_idx: (B, S, k) chosen experts. Returns (sorted_expert, slot,
    token_src, keep) each (B, S*k): destination (expert, slot) of each
    assignment in sorted order, the source token, and a capacity mask.
    """
    b, s, k = expert_idx.shape
    flat = expert_idx.reshape(b, s * k)
    order = jnp.argsort(flat, axis=-1, stable=True)          # (B, S*k)
    sorted_e = jnp.take_along_axis(flat, order, axis=-1)
    # position within each expert's run
    idx = jnp.arange(s * k)
    boundary = jnp.concatenate(
        [jnp.ones((b, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(boundary, idx[None], 0), axis=1)
    slot = idx[None] - run_start
    keep = slot < capacity
    token_src = order // k
    return sorted_e, jnp.minimum(slot, capacity - 1), token_src, order, keep


def _dispatch_row(x_row, p_row, i_row, e: int, cap: int, k: int):
    """Single-sequence dispatch (vmapped over batch: explicit batch indices
    in gather/scatter make GSPMD all-gather the global batch — measured 8 GiB
    per chunk per layer; vmap marks the batch dims so everything stays
    batch-sharded)."""
    flat = i_row.reshape(-1)                                 # (S*k,)
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    idx = jnp.arange(flat.shape[0])
    boundary = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    run_start = jax.lax.cummax(jnp.where(boundary, idx, 0), axis=0)
    slot = jnp.minimum(idx - run_start, cap - 1)
    keep = (idx - run_start) < cap
    token_src = order // k

    gathered = x_row[token_src] * keep[:, None].astype(x_row.dtype)
    buf = jnp.zeros((e, cap, x_row.shape[-1]), x_row.dtype)
    buf = buf.at[sorted_e, slot].add(gathered)
    return buf, (sorted_e, slot, token_src, order, keep)


def _combine_row(out_buf, p_row, bookkeeping, s_chunk: int):
    sorted_e, slot, token_src, order, keep = bookkeeping
    back = out_buf[sorted_e, slot]                           # (S*k, D)
    w = p_row.reshape(-1)[order]
    back = back * (w * keep).astype(back.dtype)[:, None]
    y = jnp.zeros((s_chunk, out_buf.shape[-1]), out_buf.dtype)
    return y.at[token_src].add(back)


def _expert_ffn(params: Params, buf: jax.Array) -> jax.Array:
    """buf: (..., E?, C, D) -> same shape; weights may be pre-sliced."""
    gate = jnp.einsum("becd,edf->becf", buf, params["gate"])
    up = jnp.einsum("becd,edf->becf", buf, params["up"])
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(buf.dtype) * up
    return jnp.einsum("becf,efd->becd", hidden, params["down"])


def moe_shard_map(params: Params, x: jax.Array, cfg: ModelConfig,
                  ) -> tuple[jax.Array, jax.Array]:
    """Explicit all-to-all MoE dispatch (hillclimbed data plane).

    The paper mapping made literal: the dispatch is a sort-merge-join style
    *shuffle* — each model shard routes its own token slice, exchanges
    capacity buffers with the expert-owning shards via two ``all_to_all``s,
    and the combine restores the residual layout. Replaces the
    GSPMD-inferred dispatch (which replicates the token buffers across the
    model axis: 2 orders of magnitude more wire, see EXPERIMENTS.md §Perf).
    """
    rules = current_rules()
    assert rules is not None and rules.mesh is not None
    mesh = rules.mesh
    m = cfg.moe
    tp = int(mesh.shape["model"])
    e_loc = m.num_experts // tp
    seq_sharded = rules.rules.get("seq") is not None
    fsdp_ax = rules.rules.get("w_embed")

    from jax.sharding import PartitionSpec as P

    x_spec = rules.spec("batch", "seq", "embed")
    w_specs = {
        "router": rules.spec("w_embed", None),
        "gate": rules.spec("expert", "w_embed", "mlp_unused"),
        "up": rules.spec("expert", "w_embed", "mlp_unused"),
        "down": rules.spec("expert", "mlp_unused", "w_embed"),
    }

    def body(x_l, wr, wg, wu, wd):
        if fsdp_ax is not None:
            wr = jax.lax.all_gather(wr, fsdp_ax, axis=0, tiled=True)
            wg = jax.lax.all_gather(wg, fsdp_ax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_ax, axis=2, tiled=True)
        b = x_l.shape[0]
        if seq_sharded:
            x_m = x_l                      # tokens already sequence-sharded
        else:
            s_loc = x_l.shape[1] // tp
            x_m = jax.lax.dynamic_slice_in_dim(
                x_l, jax.lax.axis_index("model") * s_loc, s_loc, axis=1)
        s_loc = x_m.shape[1]
        cap = _capacity(s_loc, m)

        logits = jnp.einsum("bsd,de->bse", x_m.astype(jnp.float32), wr)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, m.top_k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        frac = jnp.mean(jax.nn.one_hot(top_i[..., 0], m.num_experts,
                                       dtype=jnp.float32), axis=(0, 1))
        aux_terms = jax.lax.pmean(
            jnp.stack([frac, jnp.mean(probs, axis=(0, 1))]), "model")
        aux = m.num_experts * jnp.sum(aux_terms[0] * aux_terms[1])

        sorted_e, slot, token_src, order, keep = _dispatch_indices(
            top_i, m.top_k, cap)
        bidx = jnp.arange(b)[:, None]
        gathered = x_m[bidx, token_src]
        gathered = gathered * keep[..., None].astype(gathered.dtype)
        buf = jnp.zeros((b, m.num_experts, cap, x_l.shape[-1]), x_l.dtype)
        buf = buf.at[bidx, sorted_e, slot].add(gathered)

        # shuffle: (tp_dest, B, E_loc, C, D) -> peers (sort-merge join move)
        send = jnp.moveaxis(
            buf.reshape(b, tp, e_loc, cap, -1), 1, 0)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        # local experts over all sources' tokens: fold sources into capacity
        mine = jnp.moveaxis(recv, 0, 2)            # (B, E_loc, tp, C, D)
        mine = mine.reshape(b, e_loc, tp * cap, -1)
        out = _expert_ffn({"gate": wg, "up": wu, "down": wd}, mine)
        # shuffle back
        out = jnp.moveaxis(
            out.reshape(b, e_loc, tp, cap, -1), 2, 0)  # (tp_src,B,E_loc,C,D)
        back = jax.lax.all_to_all(out, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        buf_back = jnp.moveaxis(back, 0, 1).reshape(
            b, m.num_experts, cap, -1)

        y_rows = buf_back[bidx, sorted_e, slot]
        w = jnp.take_along_axis(top_p.reshape(b, -1), order, axis=-1)
        y_rows = y_rows * (w * keep).astype(y_rows.dtype)[..., None]
        y = jnp.zeros_like(x_m)
        y = y.at[bidx, token_src].add(y_rows)
        if not seq_sharded:
            y = jax.lax.all_gather(y, "model", axis=1, tiled=True)
        return y, aux

    shard_fn = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, w_specs["router"], w_specs["gate"],
                  w_specs["up"], w_specs["down"]),
        out_specs=(x_spec, P()),
        check_vma=False)
    return shard_fn(x, params["router"], params["gate"], params["up"],
                    params["down"])


def moe_shard_map_local(params: Params, x: jax.Array, cfg: ModelConfig,
                        ) -> tuple[jax.Array, jax.Array]:
    """pure_dp MoE: batch is sharded over the whole mesh, experts are
    data-local — the only wire is the internal ZeRO weight gather. Runs in
    shard_map because the partitioner mis-handles the (even batched)
    dispatch scatter's transpose (measured 8 GiB gathers per chunk)."""
    rules = current_rules()
    assert rules is not None and rules.mesh is not None
    mesh = rules.mesh
    m = cfg.moe
    fsdp_ax = rules.rules.get("w_embed")
    from jax.sharding import PartitionSpec as P

    x_spec = rules.spec("batch", "seq", "embed")
    w_specs = (rules.spec("w_embed", None),
               rules.spec(None, "w_embed", None),
               rules.spec(None, "w_embed", None),
               rules.spec(None, None, "w_embed"))

    def body(x_l, wr, wg, wu, wd):
        if fsdp_ax is not None:
            wr = jax.lax.all_gather(wr, fsdp_ax, axis=0, tiled=True)
            wg = jax.lax.all_gather(wg, fsdp_ax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_ax, axis=2, tiled=True)
        b, s_loc, d = x_l.shape
        cap = _capacity(s_loc, m)
        logits = jnp.einsum("bsd,de->bse", x_l.astype(jnp.float32), wr)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, m.top_k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        frac = jnp.mean(jax.nn.one_hot(top_i[..., 0], m.num_experts,
                                       dtype=jnp.float32), axis=(0, 1))
        stats = jax.lax.pmean(
            jnp.stack([frac, jnp.mean(probs, axis=(0, 1))]),
            tuple(mesh.shape))
        aux = m.num_experts * jnp.sum(stats[0] * stats[1])

        buf, bookkeeping = jax.vmap(
            lambda xr, pr, ir: _dispatch_row(xr, pr, ir, m.num_experts,
                                             cap, m.top_k))(
            x_l, top_p, top_i)
        out_buf = _expert_ffn({"gate": wg, "up": wu, "down": wd}, buf)
        y = jax.vmap(lambda ob, pr, bk: _combine_row(ob, pr, bk, s_loc))(
            out_buf, top_p, bookkeeping)
        return y, aux

    shard_fn = shard_map(
        body, mesh=mesh, in_specs=(x_spec,) + w_specs,
        out_specs=(x_spec, P()), check_vma=False)
    return shard_fn(x, params["router"], params["gate"], params["up"],
                    params["down"])


def moe(params: Params, x: jax.Array, cfg: ModelConfig,
        s_chunk: int = 1024) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_load_balance_loss)."""
    assert cfg.moe is not None
    rules = current_rules()
    if rules is not None and rules.mesh is not None:
        impl = rules.rules.get("moe_impl")
        if impl == "shard_map_a2a":
            return moe_shard_map(params, x, cfg)
        if impl == "shard_map_local":
            return moe_shard_map_local(params, x, cfg)
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                   # (B,S,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # Switch-style load-balance aux loss (fraction-routed x mean-prob).
    frac = jnp.mean(
        jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    s_chunk = min(s_chunk, s)
    assert s % s_chunk == 0
    nc = s // s_chunk
    cap = _capacity(s_chunk, m)

    def split(t):  # (B,S,...) -> (nc,B,chunk,...)
        return jnp.moveaxis(t.reshape(b, nc, s_chunk, *t.shape[2:]), 1, 0)

    def one_chunk(args):
        xc, pc, ic = args                   # (B,C,D), (B,C,k), (B,C,k)
        buf, bookkeeping = jax.vmap(
            lambda xr, pr, ir: _dispatch_row(xr, pr, ir, e, cap, k))(
            xc, pc, ic)
        # "expert_act" -> model = all_to_all strategy (tokens move to the
        # expert-owning shards); -> None = gather strategy (tokens broadcast,
        # experts stay put) — the paper's sort-merge vs hash join.
        buf = logical_shard(buf, "batch", "expert_act", "cap", "embed")

        gate = jnp.einsum("becd,edf->becf", buf, params["gate"])
        up = jnp.einsum("becd,edf->becf", buf, params["up"])
        hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(buf.dtype) * up
        hidden = logical_shard(hidden, "batch", "expert_act", "cap", "mlp")
        out_buf = jnp.einsum("becf,efd->becd", hidden, params["down"])
        out_buf = logical_shard(out_buf, "batch", "expert_act", "cap", "embed")

        yc = jax.vmap(
            lambda ob, pr, bk: _combine_row(ob, pr, bk, s_chunk))(
            out_buf, pc, bookkeeping)
        return logical_shard(yc, "batch", "seq", "embed")

    if nc == 1:
        y = one_chunk((x, top_p, top_i))
    else:
        y_chunks = jax.lax.map(one_chunk, (split(x), split(top_p),
                                           split(top_i)))
        y = jnp.moveaxis(y_chunks, 0, 1).reshape(b, s, d)
    return y, aux
