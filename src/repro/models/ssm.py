"""Mamba (selective SSM) block — chunked associative scan, TP over d_inner.

Training/prefill uses a chunked parallel scan: ``lax.scan`` over sequence
chunks carrying the SSM state, with ``lax.associative_scan`` inside each
chunk. This bounds the materialized (B, chunk, d_inner, N) tensor — the TPU
adaptation of Mamba's fused-SRAM-scan GPU kernel (we tile for VMEM instead).
Decode is the O(1)-per-token recurrence, which is what makes the hybrid archs
eligible for the long_500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, SSMConfig
from repro.models.layers import _init
from repro.parallel.sharding import logical_shard

Params = dict
Axes = dict


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    ssm = cfg.ssm or SSMConfig()
    d_in = ssm.expand * cfg.d_model
    dt_rank = ssm.dt_rank or -(-cfg.d_model // 16)
    return d_in, ssm.d_state, ssm.d_conv, dt_rank


def init_mamba(cfg: ModelConfig, key) -> tuple[Params, Axes]:
    d = cfg.d_model
    d_in, n, d_conv, dt_rank = _dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 6)
    # S4D-real initialization for A.
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (d_in, 1))
    params: Params = {
        "in_proj": _init(keys[0], (d, 2 * d_in), d ** -0.5, dtype),
        "conv_w": _init(keys[1], (d_conv, d_in), d_conv ** -0.5, dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": _init(keys[2], (d_in, dt_rank + 2 * n), d_in ** -0.5, dtype),
        "dt_proj": _init(keys[3], (dt_rank, d_in), dt_rank ** -0.5, dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(keys[4], (d_in,)) * 0.1, 1e-3))
        ).astype(dtype),
        "a_log": jnp.log(a_init).astype(jnp.float32),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": _init(keys[5], (d_in, d), d_in ** -0.5, dtype),
    }
    axes: Axes = {
        "in_proj": ("w_embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "x_proj": ("inner", None),
        "dt_proj": (None, "inner"),
        "dt_bias": ("inner",),
        "a_log": ("inner", None),
        "d_skip": ("inner",),
        "out_proj": ("inner", "w_embed"),
    }
    return params, axes


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv along seq. x: (B,S,Din), w: (K,Din).

    Returns (y, new_state) where state holds the trailing K-1 inputs.
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xpad = jnp.concatenate([state, x], axis=1)
    y = sum(
        xpad[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    ) + b
    new_state = xpad[:, -(k - 1):, :] if k > 1 else state
    return y, new_state


def _ssm_inputs(params: Params, u: jax.Array, cfg: ModelConfig):
    """Selective parameters for each position. u: (B, S, Din)."""
    _, n, _, dt_rank = _dims(cfg)
    proj = jnp.einsum("bsd,dk->bsk", u, params["x_proj"])
    dt, b_ssm, c_ssm = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt, params["dt_proj"])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"])                       # (Din, N)
    a_bar = jnp.exp(dt[..., None] * a[None, None])      # (B,S,Din,N)
    bx = (dt * u.astype(jnp.float32))[..., None] \
        * b_ssm.astype(jnp.float32)[..., None, :]       # (B,S,Din,N)
    return a_bar, bx, c_ssm.astype(jnp.float32)


def _scan_chunk(h0: jax.Array, a_bar: jax.Array, bx: jax.Array):
    """Associative scan within one chunk. h0: (B,Din,N); a/bx: (B,C,Din,N)."""

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    h = b_cum + a_cum * h0[:, None]
    return h, h[:, -1]


def mamba(params: Params, x: jax.Array, cfg: ModelConfig,
          chunk: int = 128, return_state: bool = False):
    """Train/prefill forward. x: (B, S, D) -> (B, S, D) [, final state]."""
    b, s, _ = x.shape
    d_in, n, _, _ = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xz = logical_shard(xz, "batch", "seq", "inner")
    u, z = jnp.split(xz, 2, axis=-1)
    u_raw = u
    u, conv_state = _causal_conv(u, params["conv_w"], params["conv_b"])
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)

    a_bar, bx, c_ssm = _ssm_inputs(params, u, cfg)

    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def step(h, inputs):
        a_c, bx_c, c_c, u_c = inputs
        h_all, h_last = _scan_chunk(h, a_c, bx_c)
        y_c = jnp.einsum("bcdn,bcn->bcd", h_all, c_c)
        y_c = y_c + params["d_skip"] * u_c.astype(jnp.float32)
        return h_last, y_c

    def split(t):  # (B,S,...) -> (nc, B, chunk, ...)
        return jnp.moveaxis(
            t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)

    h0 = jnp.zeros((b, d_in, n), jnp.float32)
    h_last, y_chunks = jax.lax.scan(
        step, h0, (split(a_bar), split(bx), split(c_ssm), split(u)))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(b, s, d_in)

    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = logical_shard(y, "batch", "seq", "inner")
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    out = logical_shard(out, "batch", "seq", "embed")
    if return_state:
        k = params["conv_w"].shape[0]
        tail = u_raw[:, -(k - 1):, :] if k > 1 else conv_state
        return out, {"h": h_last, "conv": tail.astype(conv_state.dtype)}
    return out


# -- Decode --------------------------------------------------------------------


def init_mamba_state(cfg: ModelConfig, batch: int) -> dict:
    d_in, n, d_conv, _ = _dims(cfg)
    return {
        "h": jnp.zeros((batch, d_in, n), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, d_in), jnp.dtype(cfg.dtype)),
    }


def mamba_state_axes() -> dict:
    return {"h": ("batch", "inner", "state"),
            "conv": ("batch", None, "inner")}


def mamba_step(params: Params, state: dict, x: jax.Array,
               cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One decode step. x: (B, 1, D)."""
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_state = _causal_conv(u, params["conv_w"], params["conv_b"],
                                 state["conv"])
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    a_bar, bx, c_ssm = _ssm_inputs(params, u, cfg)
    h = a_bar[:, 0] * state["h"] + bx[:, 0]
    h = logical_shard(h, "batch", "inner", "state")
    y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0])
    y = y + params["d_skip"] * u[:, 0].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) \
        * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    out = logical_shard(out, "batch", "seq", "embed")
    return out, {"h": h, "conv": conv_state}
