"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel
with stabilized exponential gating) and sLSTM (scalar memory, sequential
recurrence with block-diagonal hidden-to-hidden weights).

TPU adaptation: the mLSTM forward uses the chunkwise form — per-chunk
quadratic (attention-like) compute plus a carried (C, n, m) state — which maps
onto the MXU, instead of the CUDA fused recurrent kernel. The value/feature
dimension is tensor-parallel over ``model`` ("inner" logical axis); q/k and the
normalizer are replicated (they are the small, hash-join-broadcast side).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.core.config import ModelConfig, XLSTMConfig
from repro.models.layers import _init
from repro.models.ssm import _causal_conv
from repro.parallel.sharding import logical_shard

Params = dict
Axes = dict


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int, int]:
    x = cfg.xlstm or XLSTMConfig()
    d_in = int(x.proj_factor * cfg.d_model)
    h = cfg.num_heads
    qk = int(x.qk_dim_factor * d_in)
    return d_in, h, qk, qk // h, d_in // h      # d_in, H, qk, dk, dv


def _headnorm(h: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm. h: (..., H, dv); scale: (H*dv,)."""
    h32 = h.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(jnp.square(h32), axis=-1, keepdims=True) + eps)
    out = (h32 * rms).reshape(*h.shape[:-2], -1)
    return (out * scale.astype(jnp.float32)).astype(scale.dtype)


# =========================== mLSTM =============================================


def init_mlstm(cfg: ModelConfig, key) -> tuple[Params, Axes]:
    d = cfg.d_model
    d_in, h, qk, _, _ = _dims(cfg)
    x = cfg.xlstm or XLSTMConfig()
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 6)
    params: Params = {
        "up": _init(keys[0], (d, 2 * d_in), d ** -0.5, dtype),
        "conv_w": _init(keys[1], (x.conv_kernel, d_in), 0.3, dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": _init(keys[2], (d_in, qk), d_in ** -0.5, dtype),
        "wk": _init(keys[3], (d_in, qk), d_in ** -0.5, dtype),
        "wv": _init(keys[4], (d_in, d_in), d_in ** -0.5, dtype),
        "w_if": _init(keys[5], (d_in, 2 * h), d_in ** -0.5, jnp.float32),
        # forget-gate bias init in [3, 6] keeps early training stable (paper).
        "b_if": jnp.concatenate(
            [jnp.zeros((h,)), jnp.linspace(3.0, 6.0, h)]).astype(jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "down": _init(jax.random.fold_in(key, 7), (d_in, d), d_in ** -0.5,
                      dtype),
    }
    axes: Axes = {
        "up": ("w_embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "wq": ("inner", None),
        "wk": ("inner", None),
        "wv": ("inner", "inner"),
        "w_if": ("inner", None),
        "b_if": (None,),
        "norm": ("inner",),
        "down": ("inner", "w_embed"),
    }
    return params, axes


def _mlstm_qkv_gates(params: Params, x: jax.Array, cfg: ModelConfig,
                     conv_state=None):
    """Shared pre-processing. x: (B,S,D) -> q,k,v,(log_i,log_f),z,state."""
    d_in, h, qk, dk, dv = _dims(cfg)
    uz = jnp.einsum("bsd,de->bse", x, params["up"])
    uz = logical_shard(uz, "batch", "seq", "inner")
    u, z = jnp.split(uz, 2, axis=-1)
    c, conv_state = _causal_conv(u, params["conv_w"], params["conv_b"],
                                 conv_state)
    c = jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dk->bsk", c, params["wq"]).reshape(b, s, h, dk)
    k = jnp.einsum("bsd,dk->bsk", c, params["wk"]).reshape(b, s, h, dk)
    v = jnp.einsum("bsd,de->bse", u, params["wv"]).reshape(b, s, h, dv)
    v = logical_shard(v, "batch", "seq", None, "inner")
    gates = jnp.einsum("bsd,dg->bsg", c.astype(jnp.float32), params["w_if"])
    gates = gates + params["b_if"]
    log_i, raw_f = jnp.split(gates.reshape(b, s, 2, h), 2, axis=2)
    log_f = jax.nn.log_sigmoid(raw_f[:, :, 0])          # (B,S,H)
    log_i = log_i[:, :, 0]
    k = k * (dk ** -0.5)
    return q, k, v, log_i, log_f, z, conv_state


def mlstm(params: Params, x: jax.Array, cfg: ModelConfig,
          chunk: int = 256, return_state: bool = False):
    """Chunkwise-parallel mLSTM forward. x: (B,S,D)."""
    b, s, _ = x.shape
    d_in, h, qk, dk, dv = _dims(cfg)
    q, k, v, log_i, log_f, z, conv_tail = _mlstm_qkv_gates(params, x, cfg)

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def split(t, time_axis=1):  # (B,S,...) -> (nc,B,chunk,...)
        t = t.reshape(*t.shape[:time_axis], nc, chunk, *t.shape[time_axis + 1:])
        return jnp.moveaxis(t, time_axis, 0)

    def step(carry, inputs):
        c_mat, n_vec, m = carry            # (B,H,dk,dv), (B,H,dk), (B,H)
        qc, kc, vc, lic, lfc = inputs      # (B,C,H,*)
        lic = jnp.moveaxis(lic, 1, 2)      # (B,H,C)
        lfc = jnp.moveaxis(lfc, 1, 2)
        f_cum = jnp.cumsum(lfc, axis=-1)   # F_t
        g = lic - f_cum                    # g_s = li_s - F_s
        m_running = jax.lax.cummax(g, axis=2)      # (B,H,C)
        mx = jnp.maximum(m[..., None], m_running)
        m_t = f_cum + mx                   # new stabilizer per position
        alpha = jnp.exp(m[..., None] - mx)             # inter-chunk scale
        w = jnp.exp(g[:, :, None, :] - mx[..., None])  # (B,H,t,s)
        t_idx = jnp.arange(chunk)
        causal = t_idx[:, None] >= t_idx[None, :]
        w = jnp.where(causal[None, None], w, 0.0)

        qf = jnp.moveaxis(qc, 1, 2).astype(jnp.float32)  # (B,H,C,dk)
        kf = jnp.moveaxis(kc, 1, 2).astype(jnp.float32)
        vf = jnp.moveaxis(vc, 1, 2).astype(jnp.float32)  # (B,H,C,dv)
        # pin the value/feature dim sharding through the scan body —
        # without these the partitioner flip-flops between dv- and H-
        # sharded layouts and inserts full rematerializations (§Perf H3)
        vf = logical_shard(vf, "batch", None, None, "inner")

        scores = jnp.einsum("bhtk,bhsk->bhts", qf, kf) * w
        num = jnp.einsum("bhts,bhsv->bhtv", scores, vf) \
            + alpha[..., None] * jnp.einsum("bhtk,bhkv->bhtv", qf, c_mat)
        num = logical_shard(num, "batch", None, None, "inner")
        n_t = jnp.einsum("bhts,bhsk->bhtk", w, kf) \
            + alpha[..., None] * n_vec[:, :, None]
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhtk,bhtk->bht", qf, n_t)), jnp.exp(-m_t))
        h_out = num / den[..., None]       # (B,H,C,dv)

        # carry update at t = chunk end
        w_last = jnp.exp(g - mx[..., -1:])             # (B,H,C)
        c_new = alpha[..., -1, None, None] * c_mat \
            + jnp.einsum("bhs,bhsk,bhsv->bhkv", w_last, kf, vf)
        c_new = logical_shard(c_new, "batch", None, None, "inner")
        n_new = n_t[:, :, -1]
        m_new = m_t[..., -1]
        return (c_new, n_new, m_new), jnp.moveaxis(h_out, 1, 2)  # (B,C,H,dv)

    carry0 = (
        jnp.zeros((b, h, dk, dv), jnp.float32),
        jnp.zeros((b, h, dk), jnp.float32),
        jnp.full((b, h), -1e9, jnp.float32),
    )
    carry, h_chunks = jax.lax.scan(
        step, carry0,
        (split(q), split(k), split(v), split(log_i), split(log_f)))
    h_all = jnp.moveaxis(h_chunks, 0, 1).reshape(b, s, h, dv)

    y = _headnorm(h_all, params["norm"])
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = logical_shard(y, "batch", "seq", "inner")
    out = jnp.einsum("bsd,de->bse", y, params["down"])
    out = logical_shard(out, "batch", "seq", "embed")
    if return_state:
        return out, {"c": carry[0], "n": carry[1], "m": carry[2],
                     "conv": conv_tail}
    return out


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    d_in, h, qk, dk, dv = _dims(cfg)
    x = cfg.xlstm or XLSTMConfig()
    return {
        "c": jnp.zeros((batch, h, dk, dv), jnp.float32),
        "n": jnp.zeros((batch, h, dk), jnp.float32),
        "m": jnp.full((batch, h), -1e9, jnp.float32),
        "conv": jnp.zeros((batch, x.conv_kernel - 1, d_in),
                          jnp.dtype(cfg.dtype)),
    }


def mlstm_state_axes() -> dict:
    return {"c": ("batch", None, None, "inner"),
            "n": ("batch", None, None),
            "m": ("batch", None),
            "conv": ("batch", None, "inner")}


def mlstm_step(params: Params, state: dict, x: jax.Array,
               cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One decode step. x: (B,1,D)."""
    q, k, v, log_i, log_f, z, conv_state = _mlstm_qkv_gates(
        params, x, cfg, state["conv"])
    qf = q[:, 0].astype(jnp.float32)       # (B,H,dk)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)       # (B,H,dv)
    li, lf = log_i[:, 0], log_f[:, 0]      # (B,H)

    m_new = jnp.maximum(lf + state["m"], li)
    f_sc = jnp.exp(lf + state["m"] - m_new)
    i_sc = jnp.exp(li - m_new)
    c_new = f_sc[..., None, None] * state["c"] \
        + i_sc[..., None, None] * kf[..., :, None] * vf[..., None, :]
    c_new = logical_shard(c_new, "batch", None, None, "inner")
    n_new = f_sc[..., None] * state["n"] + i_sc[..., None] * kf
    num = jnp.einsum("bhk,bhkv->bhv", qf, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n_new)),
                      jnp.exp(-m_new))
    h_out = (num / den[..., None])[:, None]          # (B,1,H,dv)

    y = _headnorm(h_out, params["norm"])
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bsd,de->bse", y, params["down"])
    out = logical_shard(out, "batch", "seq", "embed")
    return out, {"c": c_new, "n": n_new, "m": m_new, "conv": conv_state}


# =========================== sLSTM =============================================


def init_slstm(cfg: ModelConfig, key) -> tuple[Params, Axes]:
    d = cfg.d_model
    d_in, h, _, _, dv = _dims(cfg)
    x = cfg.xlstm or XLSTMConfig()
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 4)
    params: Params = {
        "up": _init(keys[0], (d, 2 * d_in), d ** -0.5, dtype),
        "conv_w": _init(keys[1], (x.conv_kernel, d_in), 0.3, dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "w_gates": _init(keys[2], (d_in, 4 * d_in), d_in ** -0.5, dtype),
        "r_gates": _init(keys[3], (4, h, dv, dv), dv ** -0.5, jnp.float32),
        "b_gates": jnp.concatenate([
            jnp.zeros((2 * d_in,)),                     # z, i
            jnp.full((d_in,), 3.0),                     # f bias
            jnp.zeros((d_in,)),                         # o
        ]).astype(jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "down": _init(jax.random.fold_in(key, 5), (d_in, d), d_in ** -0.5,
                      dtype),
    }
    axes: Axes = {
        "up": ("w_embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "w_gates": ("inner", "inner"),
        "r_gates": (None, None, None, None),
        "b_gates": (None,),
        "norm": ("inner",),
        "down": ("inner", "w_embed"),
    }
    return params, axes


def _slstm_scan(params: Params, gates_x: jax.Array, h: int, dv: int,
                state: dict):
    """Sequential recurrence. gates_x: (B,S,4*d_in) precomputed input part.

    Wrapped in shard_map over the batch axes when a mesh is active: under
    plain GSPMD the backward pass all-reduces the recurrent-weight gradient
    at EVERY timestep (64 MiB x seq_len x layers — the dominant xlstm wire,
    §Perf H3); inside shard_map the local dR accumulates through the scan
    and is psummed once at the boundary.
    """
    from repro.parallel.sharding import current_rules

    rules = current_rules()
    r = params["r_gates"]                  # (4,H,dv,dv)
    if rules is not None and rules.mesh is not None \
            and rules.rules.get("batch") is not None:
        from jax.sharding import PartitionSpec as P

        b_ax = rules.rules["batch"]
        bspec3 = P(b_ax, None, None)
        bspec2 = P(b_ax, None)
        state_specs = {k: bspec3 if v.ndim == 3 else bspec2
                       for k, v in state.items() if k != "conv"}
        st = {k: v for k, v in state.items() if k != "conv"}
        fn = shard_map(
            lambda r_, gx_, st_: _slstm_scan_body(r_, gx_, h, dv, st_),
            mesh=rules.mesh,
            in_specs=(P(None, None, None, None), bspec3, state_specs),
            out_specs=(bspec3, (bspec2,) * 4),
            check_vma=False)
        hs, carry = fn(r, gates_x, st)
        return hs, carry
    st = {k: v for k, v in state.items() if k != "conv"}
    return _slstm_scan_body(r, gates_x, h, dv, st)


def _slstm_scan_body(r: jax.Array, gates_x: jax.Array, h: int, dv: int,
                     state: dict):
    def step(carry, gx):
        c, n, hid, m = carry               # (B,d_in) each
        hid_heads = hid.reshape(hid.shape[0], h, dv)
        rec = jnp.einsum("bhv,ghvw->gbhw", hid_heads, r)
        rec = rec.reshape(4, hid.shape[0], h * dv)
        zt, it, ft, ot = jnp.split(gx, 4, axis=-1)
        zt = jnp.tanh(zt + rec[0])
        li = it + rec[1]
        lf = jax.nn.log_sigmoid(ft + rec[2])
        ot = jax.nn.sigmoid(ot + rec[3])
        m_new = jnp.maximum(lf + m, li)
        i_sc = jnp.exp(li - m_new)
        f_sc = jnp.exp(lf + m - m_new)
        c_new = f_sc * c + i_sc * zt
        n_new = jnp.maximum(f_sc * n + i_sc, jnp.exp(-m_new))
        hid_new = ot * (c_new / n_new)
        return (c_new, n_new, hid_new, m_new), hid_new

    carry0 = (state["c"], state["n"], state["h"], state["m"])
    carry, hs = jax.lax.scan(step, carry0,
                             jnp.moveaxis(gates_x.astype(jnp.float32), 1, 0))
    return jnp.moveaxis(hs, 0, 1), carry   # (B,S,d_in)


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    d_in, _, _, _, _ = _dims(cfg)
    x = cfg.xlstm or XLSTMConfig()
    return {
        "c": jnp.zeros((batch, d_in), jnp.float32),
        "n": jnp.ones((batch, d_in), jnp.float32),
        "h": jnp.zeros((batch, d_in), jnp.float32),
        "m": jnp.zeros((batch, d_in), jnp.float32),
        "conv": jnp.zeros((batch, x.conv_kernel - 1, d_in),
                          jnp.dtype(cfg.dtype)),
    }


def slstm_state_axes() -> dict:
    return {"c": ("batch", "inner"), "n": ("batch", "inner"),
            "h": ("batch", "inner"), "m": ("batch", "inner"),
            "conv": ("batch", None, "inner")}


def _slstm_core(params: Params, x: jax.Array, cfg: ModelConfig, state: dict):
    d_in, h, _, _, dv = _dims(cfg)
    uz = jnp.einsum("bsd,de->bse", x, params["up"])
    u, z = jnp.split(uz, 2, axis=-1)
    c, conv_state = _causal_conv(u, params["conv_w"], params["conv_b"],
                                 state["conv"])
    c = jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)
    gates_x = jnp.einsum("bsd,dg->bsg", c, params["w_gates"]) \
        .astype(jnp.float32) + params["b_gates"]
    hs, carry = _slstm_scan(params, gates_x, h, dv, state)
    new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3],
                 "conv": conv_state}
    y = _headnorm(hs.reshape(*hs.shape[:2], h, dv).astype(x.dtype),
                  params["norm"])
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bsd,de->bse", y, params["down"])
    return logical_shard(out, "batch", "seq", "embed"), new_state


def slstm(params: Params, x: jax.Array, cfg: ModelConfig,
          chunk: int = 0, return_state: bool = False):
    out, state = _slstm_core(params, x, cfg,
                             init_slstm_state(cfg, x.shape[0]))
    return (out, state) if return_state else out


def slstm_step(params: Params, state: dict, x: jax.Array,
               cfg: ModelConfig) -> tuple[jax.Array, dict]:
    return _slstm_core(params, x, cfg, state)
