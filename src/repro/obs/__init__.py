"""Observability substrate: spans, decision audit, critical path, export.

``repro.obs`` is dependency-free (pure stdlib) so every runtime layer —
scheduler, executor, invoker, store, kernels, decision nodes — can import
it without cycles. The global ``Tracer`` (``get_tracer``) records a
parent/child span DAG per query (trace id == app name) into a bounded ring
buffer; the global ``DecisionAuditLog`` (``get_audit_log``) records every
``DecisionNode`` binding with the context snapshot it saw. On top:
``critical_path`` walks the span DAG to the chain bounding a query's
makespan, and ``to_chrome_trace``/``write_chrome_trace`` emit a
Perfetto-loadable timeline.
"""

from repro.obs.audit import (
    AuditEntry,
    DecisionAuditLog,
    bound_app,
    get_audit_log,
    set_audit_log,
)
from repro.obs.critical_path import CriticalPath, PathStep, critical_path
from repro.obs.export import (
    to_chrome_trace,
    validate_chrome_trace,
    write_bench_artifacts,
    write_chrome_trace,
)
from repro.obs.tracer import Span, Tracer, get_tracer, set_tracer

__all__ = [
    "AuditEntry",
    "CriticalPath",
    "DecisionAuditLog",
    "PathStep",
    "Span",
    "Tracer",
    "bound_app",
    "critical_path",
    "get_audit_log",
    "get_tracer",
    "set_audit_log",
    "set_tracer",
    "to_chrome_trace",
    "validate_chrome_trace",
    "write_bench_artifacts",
    "write_chrome_trace",
]
