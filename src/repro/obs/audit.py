"""Decision audit log: every ``DecisionNode`` binding, with what it saw.

The paper's Fig. 5 loop (system knowledge in, decision tuple out) is only
inspectable if each binding records its inputs: the profile/feedback
snapshot, the observed data distributions, the free-slot view, the
candidate implementations, and the decisions already bound upstream.
``DecisionNode.decide`` reports every binding here; the log is bounded and
thread-safe, and ``sequence(app)`` reproduces exactly the ``(stage, func)``
decision sequence the differential tests diff against the simulator.

Decision nodes don't know which query they are deciding for — the caller
does. ``bound_app(app)`` sets a thread-local attribution scope around the
``decide`` call: ``WorkflowRun.decide`` binds its run's app, the executor's
recovery policy binds the failing app, the speculation policy binds the
straggling invocation's app.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field


_tls = threading.local()


@contextmanager
def bound_app(app: str | None):
    """Attribute decisions made inside this scope (same thread) to ``app``."""
    stack = getattr(_tls, "apps", None)
    if stack is None:
        stack = _tls.apps = []
    stack.append(app)
    try:
        yield
    finally:
        stack.pop()


def current_app() -> str | None:
    stack = getattr(_tls, "apps", None)
    return stack[-1] if stack else None


def _dist_summary(data_dist) -> dict:
    out = {}
    for name, d in (data_dist or {}).items():
        out[name] = {"bytes": int(getattr(d, "size", 0)),
                     "rows": int(getattr(d, "rows", 0)),
                     "skew": float(getattr(d, "skew", 0.0))}
    return out


@dataclass
class AuditEntry:
    """One decision binding: the chosen tuple plus the context snapshot."""

    seq: int                       # global binding order
    ts: float                      # perf_counter at binding
    app: str | None                # query the binding was attributed to
    node: str                      # decision node name
    func: str                      # chosen implementation variant
    scale: int
    schedule: str                  # placement policy name
    nodes: tuple[int, ...] = ()    # placement candidate node set
    extras: tuple = ()
    candidates: tuple[str, ...] = ()   # the variants the node chooses among
    profile: dict = field(default_factory=dict)
    data_dist: dict = field(default_factory=dict)  # name -> bytes/rows/skew
    prior: tuple[tuple[str, str], ...] = ()  # (stage, func) bound upstream
    free_slots: dict = field(default_factory=dict)

    def format(self) -> str:
        dists = ", ".join(f"{k}={v['bytes']}B/{v['rows']}r"
                          f"(skew {v['skew']:.2f})"
                          for k, v in sorted(self.data_dist.items()))
        return (f"#{self.seq} [{self.app or '-'}] {self.node}: "
                f"{self.func} x{self.scale} via {self.schedule}"
                f"{list(self.nodes)}"
                f" | candidates {list(self.candidates) or '[]'}"
                f" | prior {list(self.prior) or '[]'}"
                f" | dist {{{dists}}}")


class DecisionAuditLog:
    """Bounded, thread-safe log of decision bindings."""

    def __init__(self, capacity: int = 4096, enabled: bool = True):
        self._lock = threading.Lock()
        self.enabled = enabled
        self.capacity = int(capacity)
        self._entries: deque[AuditEntry] = deque(maxlen=self.capacity)
        self._seq = itertools.count(1)

    def record(self, node, ctx, decision, app: str | None = None,
               ) -> AuditEntry | None:
        """Called by ``DecisionNode.decide``; ``app`` defaults to the
        thread's ``bound_app`` scope."""
        if not self.enabled:
            return None
        entry = AuditEntry(
            next(self._seq), time.perf_counter(),
            app if app is not None else current_app(), node.name,
            decision.func, int(decision.scale),
            decision.schedule.policy, tuple(decision.schedule.nodes),
            tuple(decision.extras),
            tuple(getattr(node, "candidates", ()) or ()),
            dict(ctx.profile or {}), _dist_summary(ctx.data_dist),
            tuple((k, d.func) for k, d in (ctx.decisions or {}).items()),
            dict(getattr(ctx.node_status, "free_slots", {}) or {}))
        with self._lock:
            self._entries.append(entry)
        return entry

    def entries(self, app: str | None = None, node: str | None = None,
                ) -> list[AuditEntry]:
        with self._lock:
            snap = list(self._entries)
        return [e for e in snap
                if (app is None or e.app == app)
                and (node is None or e.node == node)]

    def sequence(self, app: str | None = None,
                 nodes=None) -> list[tuple[str, str]]:
        """The ``(node, func)`` binding sequence — directly diffable against
        ``WorkflowRun.sequence``'s ``(stage, decision.func)`` pairs.
        ``nodes`` restricts to a node-name subset (e.g. a workflow's stages,
        excluding interleaved speculation/recovery bindings)."""
        keep = set(nodes) if nodes is not None else None
        return [(e.node, e.func) for e in self.entries(app)
                if keep is None or e.node in keep]

    def format(self, app: str | None = None) -> str:
        return "\n".join(e.format() for e in self.entries(app))

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


_default = DecisionAuditLog()


def get_audit_log() -> DecisionAuditLog:
    return _default


def set_audit_log(log: DecisionAuditLog) -> DecisionAuditLog:
    global _default
    prev, _default = _default, log
    return prev
