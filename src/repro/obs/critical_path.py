"""Critical-path analysis over a query's span DAG.

Walks the stage dependency edges (recorded on the executor's ``stage/*``
spans) backwards from the invocation that finishes last, at each stage
picking the *bounding* invocation — the one whose completion gated the
downstream stage. For every step the invocation's wall time is split:

* ``store``     — time inside direct child ``store`` spans (put/get,
                  including emulated transfer),
* ``slot_wait`` — time inside child ``wait`` spans (fair-share gate waits,
                  failed-claim release waits; a batched member also charges
                  its enclosing batch's waits),
* ``compute``   — the remainder of the span,
* ``queue``     — the gap between the predecessor step's end and this
                  step's start (scheduling/driver latency, admission).

The totals answer the operator's question directly: *is this query bound
by compute, data movement, slot contention, or queueing?* Pipelined
(partition-granularity) execution makes producer and consumer spans
overlap; the path then follows the earliest-released producer with a zero
queue gap, and the breakdown attributes each wall-clock instant to exactly
one step (chronological frontier walk), so the phase totals sum to the
makespan whether stages barrier or pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.obs.tracer import Span

PHASES = ("compute", "store", "slot_wait", "queue")


@dataclass
class PathStep:
    """One invocation on the critical path, with its time split."""

    name: str
    stage: str
    node: int | None
    start: float
    end: float
    compute: float
    store: float
    slot_wait: float
    queue: float                   # gap behind the predecessor on the path

    @property
    def seconds(self) -> float:
        return max(0.0, self.end - self.start)

    def to_dict(self) -> dict:
        return {"name": self.name, "stage": self.stage, "node": self.node,
                "seconds": round(self.seconds, 6),
                "compute": round(self.compute, 6),
                "store": round(self.store, 6),
                "slot_wait": round(self.slot_wait, 6),
                "queue": round(self.queue, 6)}


@dataclass
class CriticalPath:
    """The chain bounding one query's makespan, plus its time breakdown."""

    app: str
    makespan: float                # trace start -> last invocation end
    steps: list[PathStep] = field(default_factory=list)
    breakdown: dict = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        """The phase that bounds the path (largest breakdown share)."""
        if not self.breakdown:
            return "unknown"
        return max(self.breakdown, key=self.breakdown.get)

    def to_dict(self) -> dict:
        return {"app": self.app, "makespan_s": round(self.makespan, 6),
                "dominant": self.dominant,
                "breakdown": {k: round(v, 6)
                              for k, v in self.breakdown.items()},
                "steps": [s.to_dict() for s in self.steps]}

    def format(self) -> str:
        lines = [f"critical path [{self.app}]: makespan "
                 f"{self.makespan * 1e3:.2f} ms, dominant phase "
                 f"{self.dominant}",
                 "  breakdown: " + "  ".join(
                     f"{k} {self.breakdown.get(k, 0.0) * 1e3:.2f}ms"
                     for k in PHASES)]
        for s in self.steps:
            lines.append(
                f"  {s.stage:14s} {s.name:28s} node={s.node} "
                f"total {s.seconds * 1e3:7.2f}ms  "
                f"compute {s.compute * 1e3:7.2f}  store {s.store * 1e3:7.2f}"
                f"  slot_wait {s.slot_wait * 1e3:7.2f}"
                f"  queue {s.queue * 1e3:7.2f}")
        return "\n".join(lines)


def _split(span: Span, children: dict, by_id: dict,
           ) -> tuple[float, float, float]:
    """(compute, store, slot_wait) seconds for one invocation span."""
    store = sum(c.seconds for c in children.get(span.span_id, ())
                if c.cat == "store")
    wait = sum(c.seconds for c in children.get(span.span_id, ())
               if c.cat == "wait")
    parent = by_id.get(span.parent_id)
    if parent is not None and parent.cat == "invoker" and \
            parent.attrs.get("kind") == "batch":
        # a batched member: the claim/gate waits were paid by the batch
        wait += sum(c.seconds for c in children.get(parent.span_id, ())
                    if c.cat == "wait")
    compute = max(0.0, span.seconds - store - wait)
    return compute, store, wait


def critical_path(spans, app: str | None = None) -> CriticalPath | None:
    """Compute the critical path from a span list (e.g. ``tracer.spans()``).

    Returns ``None`` when the trace holds no invocation spans for ``app``.
    """
    if app is not None:
        spans = [s for s in spans if s.trace == app]
    spans = list(spans)
    if not spans:
        return None

    by_id = {s.span_id: s for s in spans}
    children: dict[int, list[Span]] = {}
    for s in spans:
        if s.parent_id is not None:
            children.setdefault(s.parent_id, []).append(s)

    stage_deps: dict[str, tuple[str, ...]] = {}
    for s in spans:
        if s.cat == "executor" and "stage" in s.attrs:
            deps = tuple(s.attrs.get("deps", ()) or ())
            prev = stage_deps.get(s.attrs["stage"], ())
            stage_deps[s.attrs["stage"]] = tuple(dict.fromkeys(prev + deps))

    by_stage: dict[str, list[Span]] = {}
    invs = [s for s in spans
            if s.cat == "invoker" and s.attrs.get("kind") == "invocation"]
    for s in invs:
        by_stage.setdefault(s.attrs.get("stage", s.name), []).append(s)
    if not invs:
        return None

    trace_start = min(s.start for s in spans)
    terminal = max(invs, key=lambda s: s.end)

    chain: list[tuple[Span, float]] = []    # (span, queue gap behind it)
    cur = terminal
    visited = {cur.attrs.get("stage", cur.name)}
    while True:
        preds = [p for d in stage_deps.get(cur.attrs.get("stage", ""), ())
                 for p in by_stage.get(d, ())
                 if p.attrs.get("stage") not in visited]
        if not preds:
            chain.append((cur, max(0.0, cur.start - trace_start)))
            break
        # A predecessor only *gated* this invocation if it finished before
        # the invocation started; among those the latest finisher is the
        # binding one. Under a pipelined (partition-granularity) launch the
        # consumer may start before any producer ends — producer and
        # consumer spans genuinely overlap — so when no predecessor
        # finished in time, follow the one released first (earliest end):
        # it bounds how early the overlap could begin, and the queue gap
        # is zero because nothing idled between the two.
        gating = [p for p in preds if p.end <= cur.start]
        if gating:
            pred = max(gating, key=lambda s: s.end)
            gap = max(0.0, cur.start - pred.end)
        else:
            pred = min(preds, key=lambda s: s.end)
            gap = 0.0
        chain.append((cur, gap))
        visited.add(pred.attrs.get("stage", pred.name))
        cur = pred

    steps = []
    for span, gap in reversed(chain):
        compute, store, wait = _split(span, children, by_id)
        steps.append(PathStep(span.name, span.attrs.get("stage", span.name),
                              span.node, span.start, span.end, compute,
                              store, wait, gap))
    # Aggregate via a chronological frontier walk so overlapped path steps
    # are only counted once: each step contributes the wall-clock window it
    # *extends* beyond everything already attributed (w), with its
    # compute/store/wait split scaled into that window, plus any idle gap
    # before it. The totals therefore sum to the makespan even when
    # pipelined steps overlap; on non-overlapping chains w equals the
    # step's full duration and the numbers are unchanged.
    breakdown = {k: 0.0 for k in PHASES}
    frontier = trace_start
    for s in sorted(steps, key=lambda s: s.start):
        breakdown["queue"] += max(0.0, s.start - frontier)
        w = max(0.0, s.end - max(s.start, frontier))
        scale = (w / s.seconds) if s.seconds > 0 else 0.0
        breakdown["compute"] += s.compute * scale
        breakdown["store"] += s.store * scale
        breakdown["slot_wait"] += s.slot_wait * scale
        frontier = max(frontier, s.end)
    return CriticalPath(app if app is not None else terminal.trace,
                        max(0.0, terminal.end - trace_start), steps,
                        breakdown)
