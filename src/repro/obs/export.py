"""Chrome-trace (Perfetto-loadable) JSON export of the span buffer.

Layout: each cluster node becomes one *process* (``pid = 10 + node``) whose
threads are slot lanes — concurrent invocations on a node are packed into
as few lanes as they genuinely overlap, so the lane count *is* the node's
observed slot occupancy. Control-plane spans (scheduler roots, stage
lifecycle, recovery — no node) live in a ``control-plane`` process with
one lane set per query. Counter samples (``store_bytes/<app>``, live store
footprint; ``slots/node<N>``, slots in use) become ``ph:"C"`` counter
tracks; delta samples are integrated here.

Open the artifact at https://ui.perfetto.dev (or chrome://tracing).
"""

from __future__ import annotations

import json

CONTROL_PID = 1
NODE_PID_BASE = 10


def _assign_lanes(tops) -> dict[int, int]:
    """Interval-pack top-level spans into the fewest lanes (span_id->lane)."""
    lanes: list[float] = []        # last end per lane
    out: dict[int, int] = {}
    for s in sorted(tops, key=lambda s: (s.start, s.end)):
        for i, last_end in enumerate(lanes):
            if s.start >= last_end - 1e-9:
                lanes[i] = s.end
                out[s.span_id] = i
                break
        else:
            out[s.span_id] = len(lanes)
            lanes.append(s.end)
    return out


def to_chrome_trace(tracer, app: str | None = None) -> dict:
    """Render the tracer's buffer as a Chrome-trace dict (one query when
    ``app`` is given, the whole buffer otherwise)."""
    spans = tracer.spans(app)
    counters = tracer.counters()
    if app is not None:
        counters = [c for c in counters
                    if c[1].endswith(f"/{app}") or c[1].startswith("slots")]
    if not spans and not counters:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    t0 = min([s.start for s in spans] + [c[0] for c in counters])
    by_id = {s.span_id: s for s in spans}

    def pid(s) -> int:
        return CONTROL_PID if s.node is None else NODE_PID_BASE + int(s.node)

    # lane packing per process: tops are spans whose parent lives in a
    # different process (or outside the exported set); descendants inherit
    # their top ancestor's lane
    groups: dict[int, list] = {}
    for s in spans:
        groups.setdefault(pid(s), []).append(s)
    lane_of: dict[int, tuple[int, int]] = {}   # span_id -> (pid, tid)
    events: list[dict] = []
    for p, members in sorted(groups.items()):
        tops = [s for s in members
                if s.parent_id not in by_id or pid(by_id[s.parent_id]) != p]
        lanes = _assign_lanes(tops)
        for s in tops:
            lane_of[s.span_id] = (p, lanes[s.span_id])
        pname = "control-plane" if p == CONTROL_PID \
            else f"node {p - NODE_PID_BASE}"
        events.append({"ph": "M", "name": "process_name", "pid": p, "tid": 0,
                       "args": {"name": pname}})
        for tid in sorted(set(lanes.values())):
            tname = f"lane {tid}" if p == CONTROL_PID else f"slot {tid}"
            events.append({"ph": "M", "name": "thread_name", "pid": p,
                           "tid": tid, "args": {"name": tname}})

    def resolve_lane(s) -> tuple[int, int]:
        cur = s
        hops = 0
        while cur.span_id not in lane_of and hops < 64:
            parent = by_id.get(cur.parent_id)
            if parent is None or pid(parent) != pid(s):
                return (pid(s), 0)
            cur = parent
            hops += 1
        return lane_of.get(cur.span_id, (pid(s), 0))

    for s in spans:
        p, tid = resolve_lane(s)
        events.append({
            "name": s.name, "cat": s.cat, "ph": "X",
            "ts": round((s.start - t0) * 1e6, 3),
            "dur": max(round(s.seconds * 1e6, 3), 0.001),
            "pid": p, "tid": tid,
            "args": dict(s.attrs, trace=s.trace),
        })

    # counter tracks: integrate delta samples per track, clamp at zero
    by_track: dict[str, list] = {}
    for ts, track, value, is_delta in counters:
        by_track.setdefault(track, []).append((ts, value, is_delta))
    for track, samples in sorted(by_track.items()):
        running = 0.0
        for ts, value, is_delta in sorted(samples):
            running = max(0.0, running + value) if is_delta else value
            events.append({"name": track, "cat": "counter", "ph": "C",
                           "pid": CONTROL_PID, "tid": 0,
                           "ts": round((ts - t0) * 1e6, 3),
                           "args": {"value": running}})

    events.sort(key=lambda e: (e.get("ts", -1), e["pid"], e["tid"]))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer, app: str | None = None) -> dict:
    """Export the buffer to ``path``; returns the trace dict."""
    trace = to_chrome_trace(tracer, app=app)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def write_bench_artifacts(bench_path, apps=(), tracer=None) -> dict:
    """Benchmark exit hook: write ``TRACE_<name>.json`` next to a
    ``BENCH_<name>.json`` artifact and compute each listed app's critical
    path. Returns ``{"trace": path, "critical_path": {app: cp_dict}}`` —
    the ``observability`` block the benchmarks embed in their reports.
    """
    import os

    from repro.obs.critical_path import critical_path
    from repro.obs.tracer import get_tracer

    tr = tracer if tracer is not None else get_tracer()
    bench_path = os.fspath(bench_path)
    d, name = os.path.split(bench_path)
    if name.startswith("BENCH_"):
        name = name[len("BENCH_"):]
    trace_path = os.path.join(d, "TRACE_" + name)
    write_chrome_trace(trace_path, tr)
    spans = tr.spans()
    cps = {}
    for app in apps:
        cp = critical_path(spans, app=app)
        if cp is not None:
            cps[app] = cp.to_dict()
    return {"trace": trace_path, "critical_path": cps}


def validate_chrome_trace(trace) -> dict:
    """Structural validation of a Chrome-trace dict (or JSON string).

    Raises ``ValueError`` on malformed input; returns summary stats —
    ``{"events", "cats", "counter_tracks", "pids"}`` — the integrity tests
    and the CI smoke step assert against.
    """
    if isinstance(trace, (str, bytes)):
        trace = json.loads(trace)
    if not isinstance(trace, dict) or \
            not isinstance(trace.get("traceEvents"), list):
        raise ValueError("not a Chrome-trace object: missing traceEvents")
    cats: set[str] = set()
    tracks: set[str] = set()
    pids: set[int] = set()
    n = 0
    for ev in trace["traceEvents"]:
        if not isinstance(ev, dict) or "ph" not in ev or "pid" not in ev:
            raise ValueError(f"malformed event: {ev!r}")
        pids.add(int(ev["pid"]))
        if ev["ph"] == "X":
            if not (isinstance(ev.get("ts"), (int, float))
                    and isinstance(ev.get("dur"), (int, float))
                    and ev["ts"] >= 0 and ev["dur"] > 0 and "name" in ev):
                raise ValueError(f"malformed duration event: {ev!r}")
            cats.add(ev.get("cat", ""))
            n += 1
        elif ev["ph"] == "C":
            if "value" not in ev.get("args", {}):
                raise ValueError(f"malformed counter event: {ev!r}")
            tracks.add(ev["name"])
    return {"events": n, "cats": sorted(cats),
            "counter_tracks": sorted(tracks), "pids": sorted(pids)}
