"""Thread-safe span tracer with a bounded ring buffer.

A ``Span`` is one timed region of runtime work, tagged with a *trace id*
(the application/query name — every span of one query shares it), a
category (``scheduler`` | ``executor`` | ``invoker`` | ``store`` |
``kernel`` | ``wait``) and free-form attributes. Spans form a DAG:

* within a thread, ``tracer.span(...)`` nests — the innermost open span is
  the default parent (a store read inside a function body parents to the
  invocation span automatically);
* across threads, layers publish *anchors*: the executor anchors each
  stage span under ``("stage", app, stage)`` and the invoker — running in
  a worker thread with an empty stack — parents its invocation spans to
  the anchored stage span. The scheduler likewise anchors the query root
  under ``("query", app)``.

The tracer is on by default and cheap enough to stay on: a finished span
is one dataclass plus one lock-guarded ``deque.append`` into a ring buffer
(``capacity`` spans — old spans fall off, the tracer never grows without
bound), and with ``enabled=False`` every entry point is an early-out no-op
(the CI smoke benchmark asserts the enabled-vs-disabled overhead stays
under 5%). Timestamps are ``time.perf_counter()`` — the same clock as
``InvocationRecord`` — so spans and metrics line up.

``count(track, value)`` records counter samples (e.g. live store bytes per
app, slots in use per node) that the Chrome-trace exporter renders as
counter tracks; ``delta=True`` samples are integrated at export time.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field


_CURRENT = object()     # sentinel: parent = the calling thread's open span


@dataclass
class Span:
    """One finished (or in-flight) timed region of runtime work."""

    span_id: int
    trace: str                     # trace id: the app/query name
    name: str                      # e.g. "stage/join", "query/scan_fact/3"
    cat: str                       # scheduler|executor|invoker|store|kernel|wait
    start: float                   # perf_counter seconds
    end: float = 0.0
    parent_id: int | None = None
    node: int | None = None        # placement, when the work has one
    attrs: dict = field(default_factory=dict)

    @property
    def seconds(self) -> float:
        return max(0.0, self.end - self.start)


class Tracer:
    """Bounded, thread-safe collector of spans and counter samples."""

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self._lock = threading.Lock()
        self.enabled = enabled
        self.capacity = int(capacity)
        self._spans: deque[Span] = deque(maxlen=self.capacity)
        # (ts, track, value, is_delta)
        self._counters: deque[tuple[float, str, float, bool]] = \
            deque(maxlen=self.capacity)
        self._anchors: dict[object, Span] = {}
        self._ids = itertools.count(1)
        self._tls = threading.local()

    # -- per-thread span stack (intra-thread parenting) -----------------------

    def _stack(self) -> list[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def current(self) -> Span | None:
        """The calling thread's innermost open span, if any."""
        st = getattr(self._tls, "stack", None)
        return st[-1] if st else None

    # -- span lifecycle -------------------------------------------------------

    def start(self, name: str, cat: str, trace: str | None = None,
              node: int | None = None, parent=_CURRENT, **attrs,
              ) -> Span | None:
        """Open a span (not pushed on the thread stack — pair with ``end``).

        ``parent`` defaults to the calling thread's innermost open span;
        pass an explicit ``Span`` (e.g. an anchor) or ``None`` for a root.
        ``trace`` inherits from the parent when omitted.
        """
        if not self.enabled:
            return None
        if parent is _CURRENT:
            parent = self.current()
        if trace is None:
            trace = parent.trace if parent is not None else "global"
        return Span(next(self._ids), trace, name, cat, time.perf_counter(),
                    parent_id=parent.span_id if parent is not None else None,
                    node=node, attrs=attrs)

    def end(self, span: Span | None, **attrs) -> None:
        """Close a span and commit it to the ring buffer."""
        if span is None:
            return
        span.end = time.perf_counter()
        if attrs:
            span.attrs.update(attrs)
        with self._lock:
            self._spans.append(span)

    @contextmanager
    def span(self, name: str, cat: str, trace: str | None = None,
             node: int | None = None, parent=_CURRENT, **attrs):
        """Context-managed span, pushed on the thread stack so spans opened
        inside (same thread) parent to it automatically."""
        if not self.enabled:
            yield None
            return
        sp = self.start(name, cat, trace=trace, node=node, parent=parent,
                        **attrs)
        self._stack().append(sp)
        try:
            yield sp
        finally:
            self._stack().pop()
            self.end(sp)

    @contextmanager
    def adopt(self, span: Span | None):
        """Adopt another thread's open span as this thread's innermost
        parent — the cross-thread hand-off for helper threads (prefetch,
        speculation backups) whose own stack is empty: spans they open
        while the adoption is active parent to ``span`` instead of landing
        orphaned. Purely a stack push; the adopted span's timing is not
        touched."""
        if not self.enabled or span is None:
            yield span
            return
        self._stack().append(span)
        try:
            yield span
        finally:
            self._stack().pop()

    def record(self, name: str, cat: str, start: float,
               end: float | None = None, trace: str | None = None,
               node: int | None = None, parent=_CURRENT, **attrs,
               ) -> Span | None:
        """Commit an already-elapsed region retroactively — used for waits
        recorded only when blocking actually occurred (a slot-gate wait, a
        failed-claim release wait, admission queueing)."""
        if not self.enabled:
            return None
        if parent is _CURRENT:
            parent = self.current()
        if trace is None:
            trace = parent.trace if parent is not None else "global"
        sp = Span(next(self._ids), trace, name, cat, start,
                  end=time.perf_counter() if end is None else end,
                  parent_id=parent.span_id if parent is not None else None,
                  node=node, attrs=attrs)
        with self._lock:
            self._spans.append(sp)
        return sp

    # -- anchors (cross-thread parenting) -------------------------------------

    def anchor(self, key, span: Span | None) -> None:
        """Publish an open span under ``key`` so work in *other* threads can
        parent to it (``("query", app)``, ``("stage", app, stage)``)."""
        if span is None:
            return
        with self._lock:
            self._anchors[key] = span

    def anchored(self, key) -> Span | None:
        if not self.enabled:
            return None
        with self._lock:
            return self._anchors.get(key)

    def release_anchor(self, key) -> None:
        with self._lock:
            self._anchors.pop(key, None)

    # -- counter tracks -------------------------------------------------------

    def count(self, track: str, value: float, delta: bool = False) -> None:
        """Record a counter sample (absolute, or a ``delta`` to integrate at
        export time) — e.g. ``store_bytes/<app>`` or ``slots/node<N>``."""
        if not self.enabled:
            return
        ts = time.perf_counter()
        with self._lock:
            self._counters.append((ts, str(track), float(value), bool(delta)))

    # -- snapshots ------------------------------------------------------------

    def spans(self, trace: str | None = None) -> list[Span]:
        """Finished spans (ring-buffer order ≈ end time), optionally for one
        trace id."""
        with self._lock:
            snap = list(self._spans)
        if trace is None:
            return snap
        return [s for s in snap if s.trace == trace]

    def counters(self) -> list[tuple[float, str, float, bool]]:
        with self._lock:
            return list(self._counters)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._counters.clear()
            self._anchors.clear()


_default = Tracer()


def get_tracer() -> Tracer:
    """The process-global tracer every runtime layer reports into."""
    return _default


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the global tracer (tests, a disabled tracer for overhead runs);
    returns the previous one."""
    global _default
    prev, _default = _default, tracer
    return prev
