"""Distribution layer: logical-axis sharding, strategy decision nodes."""

from repro.parallel.sharding import (  # noqa: F401
    ShardingRules,
    current_rules,
    logical_shard,
    make_param_sharding,
    pad_to_multiple,
    use_rules,
)
