"""Distributed-optimization collectives: gradient compression.

Cross-pod (DCN) gradient all-reduce is the dominant multi-pod cost for big
models; ``compressed_allreduce`` implements an int8 ring-style all-reduce as
all_to_all(int8) -> local dequant-sum -> all_gather(int8), cutting wire bytes
~4x vs fp32 (2x vs bf16) at the cost of one requantization. Used inside
``shard_map`` over the pod/data axis when
``OptimizerConfig.grad_compression`` is enabled; validated against
``lax.psum`` in tests (quantization-bounded error).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import axis_size, shard_map


def _quantize(x: jax.Array, bits: int = 8):
    lim = float(2 ** (bits - 1) - 1)
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = absmax / lim
    q = jnp.clip(jnp.round(x / scale), -lim, lim).astype(jnp.int8)
    return q, scale


def compressed_allreduce(x: jax.Array, axis_name: str,
                         bits: int = 8) -> jax.Array:
    """int8-wire all-reduce along ``axis_name`` (call inside shard_map).

    x: identical-shape fp array on each shard. Returns sum over shards.
    """
    n = axis_size(axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)

    q, scale = _quantize(chunks, bits)
    # reduce-scatter phase: shard i receives chunk i from every peer
    gathered = jax.lax.all_to_all(q[:, None], axis_name, split_axis=0,
                                  concat_axis=1)          # (1, n, chunk)
    scales = jax.lax.all_gather(scale, axis_name)         # (n,)
    partial_sum = jnp.sum(
        gathered[0].astype(jnp.float32) * scales[:, None], axis=0)

    # all-gather phase: requantize the reduced chunk, share with all peers
    q2, scale2 = _quantize(partial_sum, bits)
    all_q = jax.lax.all_gather(q2, axis_name)              # (n, chunk)
    all_s = jax.lax.all_gather(scale2, axis_name)          # (n,)
    total = (all_q.astype(jnp.float32) * all_s[:, None]).reshape(-1)
    return total[: x.size].reshape(x.shape).astype(x.dtype)


def make_compressed_grad_allreduce(mesh: Mesh, axis: str = "pod",
                                   bits: int = 8):
    """Returns fn(grads_pytree) -> mean-reduced over ``axis`` with int8 wire.

    Grads must be replicated (or unsharded) along ``axis``; other axes pass
    through unchanged.
    """

    def one(g):
        spec = P()  # fully addressed inside; shard_map over `axis` only

        @partial(shard_map, mesh=mesh, in_specs=P(*([None] * g.ndim)),
                 out_specs=P(*([None] * g.ndim)), check_vma=False)
        def _ar(local):
            summed = compressed_allreduce(local, axis, bits)
            return summed / axis_size(axis)

        return _ar(g)

    return lambda grads: jax.tree.map(one, grads)
