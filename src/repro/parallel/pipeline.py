"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

The *packing* schedule decision (paper Fig. 4e) applied to pods: instead of
stretching data parallelism across the slow cross-pod links (gradient
all-reduce of the full model every step), weights stay pod-local — each pod
owns a contiguous slice of the layer stack — and only microbatch activations
cross pods (one ppermute per pipeline tick). This is the structural answer
to the 72B wire bound recorded in EXPERIMENTS.md §Perf H5.

Implementation: ``shard_map`` manual over ``pod`` only (``axis_names``);
``data``/``model`` stay auto-partitioned by GSPMD inside, so the per-stage
layer stack keeps its TP/FSDP shardings. The schedule is the static GPipe
grid: tick t runs microbatch (t - stage) on each stage, activations move
forward via ``ppermute``; backward is plain AD through the loop (transposed
permutes run the reverse schedule).

Scope: uniform-attention dense archs (block pattern period 1) in train mode,
repeats divisible by the stage count, microbatches >= stages.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core.config import (
    BlockKind,
    ModelConfig,
    OptimizerConfig,
    ParallelConfig,
    ShapeConfig,
)
from repro.models import lm as lm_mod
from repro.models.layers import rmsnorm
from repro.parallel.sharding import ShardingRules, use_rules
from repro.training.losses import chunked_cross_entropy
from repro.training.optimizer import apply_updates

AUX_LOSS_WEIGHT = 0.01


def pp_applicable(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  pc: ParallelConfig) -> bool:
    if "pod" not in getattr(mesh, "shape", {}):
        return False
    stages = int(mesh.shape["pod"])
    pattern, repeats = lm_mod._pattern(cfg)
    return (shape.mode == "train"
            and all(BlockKind(k) == BlockKind.ATTENTION for k in pattern)
            and repeats % stages == 0
            and max(1, pc.microbatches) >= stages)


def make_pp_train_step(cfg: ModelConfig, shape: ShapeConfig,
                       opt_cfg: OptimizerConfig, pc: ParallelConfig,
                       rules: ShardingRules, total_steps: int = 10000,
                       q_chunk: int = 1024):
    """Returns train_step(state, batch). Layer stacks must be sharded over
    ``pod`` on their leading (repeats) axis — use pp_rules()."""
    mesh = rules.mesh
    stages = int(mesh.shape["pod"])
    pattern, repeats = lm_mod._pattern(cfg)
    assert pp_applicable(cfg, shape, mesh, pc)
    mb = max(stages, pc.microbatches)

    def block_specs(template) -> object:
        """P('pod', ...) on every stacked block leaf (auto elsewhere)."""
        return jax.tree.map(lambda x: P("pod"), template)

    def stage_apply(group_params, h, positions):
        """Run this pod's layer slice (scan over R/stages repeats)."""

        def body(carry, layer_params):
            h, aux = carry
            h, aux = lm_mod._apply_block(
                BlockKind.ATTENTION, layer_params, h, positions, cfg,
                128, q_chunk, False, aux)
            return (h, aux), None

        wrapped = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable) \
            if pc.remat != "none" else body
        (h, aux), _ = jax.lax.scan(
            wrapped, (h, jnp.zeros((), jnp.float32)), group_params)
        return h, aux

    def pp_loss(params, tokens_mb, labels_mb):
        """tokens/labels: (M, B_mb, S).

        Embedding and loss run OUTSIDE the manual region (plain GSPMD);
        the shard_map is purely the layer pipeline, and the only cross-
        boundary gradients are dense f32 activation psums (XLA CPU's
        AllReducePromotion crashes on the bf16 / scatter-shaped psums that
        in-region embedding grads would need — micro-repros in tests).
        """
        m_, b_mb, s = tokens_mb.shape
        d = cfg.d_model

        def body(blocks0, h0_all):
            ctx = use_rules(None)   # rules reference the full-auto mesh
            ctx.__enter__()
            stage = jax.lax.axis_index("pod")
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b_mb, s))
            h_recv = jnp.zeros((b_mb, s, d), jnp.dtype(cfg.dtype))
            out_acc = jnp.zeros((mb, b_mb, s, d), jnp.float32)

            perm_fwd = [(i, i + 1) for i in range(stages - 1)]
            for t in range(mb + stages - 1):
                mb_idx = t - stage
                active = jnp.logical_and(mb_idx >= 0, mb_idx < mb)
                safe_idx = jnp.clip(mb_idx, 0, mb - 1)
                h0 = jax.lax.dynamic_index_in_dim(
                    h0_all, safe_idx, axis=0, keepdims=False)
                x_in = jnp.where(stage == 0, h0.astype(h_recv.dtype),
                                 h_recv)
                x_in = jnp.where(active, x_in, jnp.zeros_like(x_in))
                h_out, _ = stage_apply(blocks0, x_in, positions)
                if t >= stages - 1:   # static: last stage can be active
                    take = jnp.logical_and(stage == stages - 1, active)
                    prev = jax.lax.dynamic_index_in_dim(
                        out_acc, safe_idx, axis=0, keepdims=False)
                    upd = jnp.where(take, h_out.astype(jnp.float32), prev)
                    out_acc = jax.lax.dynamic_update_index_in_dim(
                        out_acc, upd, safe_idx, axis=0)
                h_recv = jax.lax.ppermute(h_out, "pod", perm_fwd)

            ctx.__exit__(None, None, None)
            # combine: only the last stage wrote non-zeros; f32 psum is the
            # one all-reduce flavor the CPU backend handles under AD.
            return jax.lax.psum(out_acc, "pod")

        blocks0 = params["blocks"][0]
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(block_specs(blocks0), P()),
            out_specs=P(),
            axis_names={"pod"}, check_vma=True)

        h0_all = jax.vmap(lambda t: lm_mod.embed(params["embed"], t))(
            tokens_mb).astype(jnp.float32)
        h_final = fn(blocks0, h0_all)

        def mb_loss(h, labels):
            h_last = rmsnorm(params["final_norm"], h.astype(cfg.dtype),
                             cfg.norm_eps)
            ce, cnt = chunked_cross_entropy(params["embed"], h_last,
                                            labels, cfg)
            return ce * cnt, cnt
        losses, counts = jax.vmap(mb_loss)(h_final, labels_mb)
        return jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)

    def train_step(state, batch):
        params = state["params"]
        b = batch["tokens"].shape[0]

        def split(t):
            return t.reshape(mb, b // mb, *t.shape[1:])

        def loss_wrap(p):
            return pp_loss(p, split(batch["tokens"]),
                           split(batch["labels"]))

        loss, grads = jax.value_and_grad(loss_wrap)(params)
        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, state["opt"], opt_cfg, total_steps)
        metrics = dict(opt_metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    train_step.pp_loss = pp_loss   # exposed for tests / fwd-only probes
    return train_step


def pp_rules(rules: ShardingRules) -> ShardingRules:
    """Variant rule set: layer stacks sharded over pod (weights stay
    pod-local); batch stays on data only."""
    new = dict(rules.rules)
    new["layers"] = "pod"
    new["batch"] = "data"
    return ShardingRules(rules.mesh, new)
