"""Logical-axis sharding for Proteus-JAX.

Model code annotates tensors with *logical* axis names (``"batch"``,
``"seq"``, ``"embed"``, ``"heads"``, ``"expert"``, ...). A ``ShardingRules``
mapping — produced by the control-plane decision nodes in
``repro.parallel.strategies`` — binds logical names to physical mesh axes.
Inside an active rules context, ``logical_shard`` applies
``jax.lax.with_sharding_constraint``; outside (unit tests, CPU smoke runs)
it is a no-op, so model code never depends on a mesh being present.
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_RULES: contextvars.ContextVar["ShardingRules | None"] = contextvars.ContextVar(
    "sharding_rules", default=None
)


class ShardingRules:
    """Binds logical axis names to mesh axes (or None = replicated)."""

    def __init__(self, mesh: Mesh | None,
                 rules: Mapping[str, Any]):
        self.mesh = mesh
        self.rules = dict(rules)

    def spec(self, *logical_axes: str | None) -> P:
        parts = []
        used: set[str] = set()
        for ax in logical_axes:
            if ax is None:
                parts.append(None)
                continue
            phys = self.rules.get(ax)
            if phys is None:
                parts.append(None)
            elif isinstance(phys, (tuple, list)):
                fresh = tuple(p for p in phys if p not in used)
                used.update(fresh)
                parts.append(fresh if fresh else None)
            else:
                if phys in used:
                    parts.append(None)
                else:
                    used.add(phys)
                    parts.append(phys)
        return P(*parts)

    def sharding(self, *logical_axes: str | None) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical_axes))

    def axis_size(self, logical: str) -> int:
        """Number of shards a logical axis is split into."""
        if self.mesh is None:
            return 1
        phys = self.rules.get(logical)
        if phys is None:
            return 1
        if isinstance(phys, (tuple, list)):
            return int(np.prod([self.mesh.shape[p] for p in phys]))
        return int(self.mesh.shape[phys])


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    token = _RULES.set(rules)
    try:
        yield rules
    finally:
        _RULES.reset(token)


def current_rules() -> ShardingRules | None:
    return _RULES.get()


def logical_shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op w/o rules)."""
    rules = _RULES.get()
    if rules is None or rules.mesh is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(
            f"rank mismatch: {x.shape} vs logical axes {logical_axes}"
        )
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec(*logical_axes))
    )


def pad_to_multiple(n: int, multiple: int) -> int:
    return int(math.ceil(n / multiple) * multiple)


def divisible(n: int, logical: str) -> bool:
    rules = _RULES.get()
    if rules is None:
        return True
    return n % rules.axis_size(logical) == 0


# Canonical logical-axis vocabulary used across the code base -----------------
#
#   batch      global batch dim (DP: data (+pod))
#   seq        sequence dim (SP: sharded over model between blocks when the
#              seq_tp strategy is active)
#   embed      d_model / residual stream (never sharded)
#   heads      attention query heads (TP under head_tp)
#   kv_heads   attention kv heads (TP when divisible, else replicated)
#   qkv        per-head feature dim (never sharded)
#   mlp        FFN hidden dim (TP column/row)
#   expert     MoE expert dim (EP)
#   cap        MoE capacity dim
#   vocab      vocabulary dim (TP)
#   inner      SSM / xLSTM inner feature dim (TP)
#   state      SSM state dim (never sharded)
#   stage      pipeline stage (PP over pod when packing is selected)


def make_param_sharding(rules: ShardingRules, logical_tree) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: rules.sharding(*axes),
        logical_tree,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(isinstance(a, (str, type(None))) for a in v),
    )
