"""Control-plane strategy decisions per (arch x shape x mesh) cell.

This module is the LM-side instantiation of the paper's Fig. 6 decision
node: given *system knowledge* (mesh shape, link bandwidths, free slots) and
*data distribution* (tensor/token sizes from the model + shape configs), the
decision nodes emit the decision tuple

    func     -> attention/MoE implementation strategy,
    scale    -> microbatch count (function instances ∝ data size),
    schedule -> pod-axis role: "data" (round-robin spread) or
                "pipeline" (packing for ICI locality),

which `make_rules` then materializes as logical->physical sharding rules.
Everything is napkin-math cost-modeled the way the paper's T1/T2 thresholds
are: byte counts over link bandwidth vs compute over peak FLOP/s.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np
from jax.sharding import Mesh

from repro.core.config import (
    FFNKind,
    ModelConfig,
    ParallelConfig,
    ShapeConfig,
)
from repro.core.decisions import (
    Decision,
    DecisionContext,
    DecisionNode,
    DecisionWorkflow,
    Schedule,
)
from repro.parallel.sharding import ShardingRules, pad_to_multiple
from repro.models.layers import VOCAB_PAD

# v5e-like hardware model (also used by the roofline analysis).
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
HBM_BYTES = 16 * 2 ** 30     # per chip


# ---------------------------------------------------------------------------
# Cost-model helpers (napkin math, the T1/T2 analogue)
# ---------------------------------------------------------------------------


def attn_strategy_cost(cfg: ModelConfig, shape: ShapeConfig, tp: int) -> dict:
    """Per-layer extra-communication bytes of each attention strategy."""
    s, b = shape.seq_len, shape.global_batch
    hd = cfg.resolved_head_dim
    kv_bytes = 2 * s * cfg.num_kv_heads * hd * 2          # K+V bf16, per seq
    res_bytes = s * cfg.d_model * 2                        # residual, per seq
    return {
        # head_tp: Megatron f/g collectives: 2 all-reduces of the residual
        "head_tp": 2 * 2 * res_bytes * b,
        # seq_tp: KV broadcast (hash join) + AG/RS around the FFN
        "seq_tp": (kv_bytes + 2 * res_bytes) * b,
        # replicated attention: no comm but tp x redundant compute -> charge
        # the waste as equivalent bytes at the compute roofline
        "replicated": (2 * s * s * cfg.num_heads * hd * b / PEAK_FLOPS)
        * ICI_BW * (tp - 1),
    }


def pick_attention_strategy(cfg: ModelConfig, shape: ShapeConfig,
                            tp: int) -> str:
    if not any(k == "attention" for k in cfg.block_pattern):
        return "none"
    if shape.mode == "decode":
        # decode: cache sharded along sequence; heads sharded iff divisible
        return "decode_kv_shard"
    costs = attn_strategy_cost(cfg, shape, tp)
    feasible = {}
    if cfg.num_heads % tp == 0:
        feasible["head_tp"] = costs["head_tp"]
    if shape.seq_len % tp == 0:
        feasible["seq_tp"] = costs["seq_tp"]
    feasible["replicated"] = costs["replicated"]
    return min(feasible, key=feasible.get)


def pick_moe_strategy(cfg: ModelConfig, shape: ShapeConfig, tp: int) -> str:
    if cfg.ffn != FFNKind.MOE or cfg.moe is None:
        return "none"
    m = cfg.moe
    if shape.mode == "decode":
        # decode: activations are already replicated across the model axis
        # (the broadcast is free) and volumes are latency-dominated — keep
        # experts in place and psum outputs (hash join: ship nothing big).
        return "gather"
    tokens = shape.seq_len
    # train/prefill: the explicit shard_map shuffle (sort-merge-join move)
    # is strictly cheaper than both GSPMD-inferred strategies when shapes
    # divide (§Perf H1: 150-190x less wire than the inferred dispatch).
    if m.num_experts % tp == 0 and tokens % tp == 0:
        return "shard_map_a2a"
    a2a = 2 * m.top_k * tokens * cfg.d_model / tp
    gather = m.capacity_factor * m.top_k * tokens * cfg.d_model \
        * (tp - 1) / tp
    return "all_to_all" if a2a < gather and m.num_experts % tp == 0 \
        else "gather"


def exact_param_bytes_per_chip(cfg: ModelConfig, rules: ShardingRules) -> int:
    """Exact per-chip parameter bytes under a rule set (via eval_shape)."""
    import jax
    from repro.models.lm import init_lm

    captured = {}

    def f():
        p, a = init_lm(cfg, jax.random.PRNGKey(0))
        captured["axes"] = a
        return p

    shapes = jax.eval_shape(f)
    axes = captured["axes"]
    total = 0
    is_axes = lambda v: isinstance(v, tuple) and all(
        isinstance(x, (str, type(None))) for x in v)
    flat_s = jax.tree.leaves(shapes)
    flat_a = jax.tree.leaves(axes, is_leaf=is_axes)
    for s, a in zip(flat_s, flat_a):
        shards = 1
        for dim, logical in zip(s.shape, a):
            if logical is None:
                continue
            n = rules.axis_size(logical)
            if n > 1 and dim % n == 0:
                shards *= n
        total += int(np.prod(s.shape)) * s.dtype.itemsize // shards
    return total


def estimate_activation_bytes(cfg: ModelConfig, shape: ShapeConfig, dp: int,
                              tp: int, microbatches: int,
                              seq_sharded: bool) -> float:
    """Saved-residual bytes/chip with block remat (+50% temp headroom)."""
    b_local = max(1, shape.global_batch // dp) / microbatches
    res = cfg.num_layers * b_local * shape.seq_len * cfg.d_model * 2
    if seq_sharded:
        res /= tp
    return 1.5 * res


def plan_memory(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                pc_attn: str, fsdp_pref: str,
                layout: str = "tp") -> tuple[str, int]:
    """Resolve (fsdp, microbatches) from exact param bytes + act estimate."""
    tp = int(mesh.shape["model"])
    devices = int(np.prod(list(mesh.shape.values())))
    if layout == "pure_dp":
        dp, tp = devices, 1
    else:
        dp_axes = [a for a in mesh.shape if a != "model"]
        dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    seq_sharded = pc_attn == "seq_tp" and layout != "pure_dp"
    state_mult = 8.0 if shape.mode == "train" else 1.0  # (2+12+2)/2 per bf16

    def fixed_bytes(fsdp: str) -> float:
        pc = ParallelConfig(attn_strategy=pc_attn, fsdp=fsdp, layout=layout)
        rules = make_rules(mesh, cfg, shape, pc)
        return exact_param_bytes_per_chip(cfg, rules) * state_mult

    if shape.mode != "train":
        fsdp = "off" if fsdp_pref == "auto" else fsdp_pref
        if fixed_bytes(fsdp) > 0.9 * HBM_BYTES and fsdp == "off":
            fsdp = "on"
        return fsdp, 1

    fsdp = fsdp_pref
    if fsdp == "auto":
        fsdp = "off" if fixed_bytes("off") < 0.35 * HBM_BYTES else "on"
    fixed = fixed_bytes(fsdp)

    mb = 1
    max_mb = max(1, shape.global_batch // dp)
    while mb < max_mb and fixed + estimate_activation_bytes(
            cfg, shape, dp, tp, mb, seq_sharded) > 0.8 * HBM_BYTES:
        mb *= 2
    return fsdp, mb


def pick_pod_role(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> str:
    """Round-robin (pod=data) vs packing (pod=pipeline) — paper Fig. 4(e).

    DP over the slow cross-pod links costs a gradient all-reduce of the full
    model every step; pipelining keeps weights pod-local and only ships
    activations. Pick pipeline when grad bytes >> activation bytes.
    """
    if "pod" not in mesh.shape:
        return "data"
    if shape.mode != "train":
        return "data"
    grad_bytes = cfg.param_count() * 2
    act_bytes = shape.global_batch * shape.seq_len * cfg.d_model * 2
    return "pipeline" if grad_bytes > 4 * act_bytes else "data"


# ---------------------------------------------------------------------------
# Decision node + workflow (paper-facing API)
# ---------------------------------------------------------------------------


def plan_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              overrides: ParallelConfig | None = None,
              profile: str = "optimized") -> ParallelConfig:
    """Resolve all 'auto' fields of ParallelConfig for one cell.

    profile="baseline" reproduces the paper-faithful pre-hillclimb plan
    (GSPMD-inferred MoE dispatch, TP-only layouts, full-S^2 attention);
    profile="optimized" applies the validated §Perf defaults.
    """
    tp = int(mesh.shape["model"])
    dp = int(np.prod([mesh.shape[a] for a in mesh.shape if a != "model"]))
    devices = int(np.prod(list(mesh.shape.values())))
    pc = overrides or ParallelConfig()
    optimized = profile == "optimized"
    layout = pc.layout
    if layout == "auto":
        layout = pick_layout(cfg, shape, mesh) if optimized else "tp"
    attn = pc.attn_strategy
    if layout == "pure_dp":
        attn = "replicated" if attn == "auto" else attn
    elif attn == "auto":
        attn = pick_attention_strategy(cfg, shape, tp)
    moe = pc.moe_strategy
    if moe == "auto":
        moe = pick_moe_strategy(cfg, shape, tp)
        if not optimized and moe == "shard_map_a2a":
            moe = "all_to_all"
    if layout == "pure_dp":
        moe = "gather" if moe not in ("none",) else moe
    fsdp, mb_auto = plan_memory(cfg, shape, mesh, attn, pc.fsdp, layout)
    mb = pc.microbatches if pc.microbatches > 1 else mb_auto
    pod_role = pc.pod_axis_role
    if pod_role == "auto":
        pod_role = pick_pod_role(cfg, shape, mesh)
    # semantics-preserving defaults from the §Perf hillclimbs:
    causal_skip = pc.causal_skip or (optimized and shape.mode != "decode")
    mlp_mode = pc.mlp_mode
    if optimized and mlp_mode == "tp":
        mlp_mode = "auto"
    remat = pc.remat
    if layout == "pure_dp" and remat == "block":
        remat = "dots"   # activations are tiny under full-mesh DP
    return dataclasses.replace(
        pc,
        attn_strategy=attn,
        moe_strategy=moe,
        layout=layout,
        microbatches=mb,
        fsdp=fsdp,
        remat=remat,
        causal_skip=causal_skip,
        mlp_mode=mlp_mode,
        pod_axis_role=pod_role,
        sequence_sharded_residual=(attn == "seq_tp"),
    )


def pick_layout(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> str:
    """Pure data parallelism + full-mesh ZeRO beats any tensor parallelism
    when the model is small enough: zero TP collectives, wire = one weight
    gather + one gradient reduce-scatter per step. The scale decision of the
    paper (function count ∝ data size) applied to a fixed mesh."""
    if shape.mode != "train":
        return "tp"
    devices = int(np.prod(list(mesh.shape.values())))
    if shape.global_batch % devices != 0:
        return "tp"
    if cfg.d_model % devices != 0:    # ZeRO shards the w_embed dim
        return "tp"
    opt_bytes = cfg.param_count() * 14 / devices
    b_loc = shape.global_batch // devices
    act_bytes = 1.5 * cfg.num_layers * b_loc * shape.seq_len \
        * cfg.d_model * 2
    if opt_bytes + act_bytes > 0.5 * HBM_BYTES:
        return "tp"
    # wire comparison: pure_dp pays ~8 bytes/param/step (3x ZeRO weight
    # gathers + gradient reduce-scatter) vs TP's per-layer residual traffic
    pure_dp_wire = 8.0 * cfg.param_count()
    tp_dp = int(np.prod([mesh.shape[a] for a in mesh.shape
                         if a != "model"]))
    tp_wire = 3 * cfg.num_layers * (shape.global_batch / tp_dp) \
        * shape.seq_len * cfg.d_model * 2 * 2
    return "pure_dp" if pure_dp_wire < tp_wire else "tp"


def strategy_node(cfg: ModelConfig, shape: ShapeConfig,
                  mesh: Mesh) -> DecisionNode:
    """Paper-style decision node wrapping plan_cell (Fig. 6 analogue)."""

    def fn(ctx: DecisionContext) -> Decision:
        pc = plan_cell(cfg, shape, mesh)
        nodes = tuple(range(len(mesh.devices.flat)))
        policy = "packing" if pc.pod_axis_role == "pipeline" else "round-robin"
        return Decision(
            func=f"attn={pc.attn_strategy},moe={pc.moe_strategy}",
            scale=pc.microbatches,
            schedule=Schedule(policy, nodes),
            extras=(("parallel_config", pc),),
        )

    return DecisionNode(f"strategy:{cfg.name}:{shape.name}", fn)


# ---------------------------------------------------------------------------
# Rules materialization
# ---------------------------------------------------------------------------


def _dp_axes(mesh: Mesh, pod_role: str):
    if "pod" in mesh.shape and pod_role == "data":
        return ("pod", "data")
    return ("data",) if "data" in mesh.shape else None


def make_rules(mesh: Mesh, cfg: ModelConfig, shape: ShapeConfig,
               pc: ParallelConfig) -> ShardingRules:
    tp = int(mesh.shape["model"])
    dp_ax = _dp_axes(mesh, pc.pod_axis_role)
    dp = int(np.prod([mesh.shape[a] for a in dp_ax])) if dp_ax else 1

    if pc.layout == "pure_dp":
        all_axes = tuple(mesh.shape)
        devices = int(np.prod(list(mesh.shape.values())))
        batch_local = shape.global_batch // max(1, pc.microbatches)
        batch_rule = all_axes if batch_local % devices == 0 else dp_ax
        rules: dict = {name: None for name in (
            "seq", "kv_seq", "mlp_seq", "cache_seq", "embed", "qkv", "cap",
            "state", "layers", "kv_rep", "vocab", "mlp", "heads",
            "kv_heads", "expert", "expert_act", "inner")}
        rules["batch"] = batch_rule
        rules["w_embed"] = all_axes     # full-mesh ZeRO-3 weight sharding
        if pc.causal_skip:
            rules["causal_skip"] = True
        if cfg.moe is not None and batch_rule == all_axes:
            rules["moe_impl"] = "shard_map_local"
        return ShardingRules(mesh, rules)

    batch_local = shape.global_batch // max(1, pc.microbatches) \
        if shape.mode == "train" else shape.global_batch
    batch_rule = dp_ax if dp_ax and batch_local % dp == 0 else (
        "data" if batch_local % int(mesh.shape.get("data", 1)) == 0 else None)

    vpad = pad_to_multiple(cfg.vocab_size, VOCAB_PAD)
    d_inner = 0
    if cfg.ssm is not None:
        d_inner = cfg.ssm.expand * cfg.d_model
    elif cfg.xlstm is not None:
        d_inner = int(cfg.xlstm.proj_factor * cfg.d_model)

    # FSDP (ZeRO-3): shard the weight-matrix embed dim over the data axis
    # when params+opt would otherwise blow HBM (only within a pod: the pod
    # axis keeps full replicas so cross-pod traffic stays gradient-only).
    fsdp = pc.fsdp
    if fsdp == "auto":  # normally resolved by plan_memory via plan_cell
        fsdp = "on" if cfg.param_count() * 14 / tp > 0.4 * HBM_BYTES \
            else "off"
    fsdp_ax = "data" if (fsdp == "on" and "data" in mesh.shape
                         and cfg.d_model % int(mesh.shape["data"]) == 0) \
        else None

    # ship-weights-vs-ship-activations (the hash-join question for the FFN):
    # under a sequence-sharded residual, keeping activations put and
    # replicating MLP weights over `model` beats AG/RS when the per-layer
    # weight bytes are smaller than the activation traffic.
    mlp_mode = pc.mlp_mode
    if mlp_mode == "auto":
        # activation AG/RS happens once per step; weight gathers repeat per
        # microbatch — compare at the step level
        act_wire = 2 * (shape.global_batch / max(1, dp)) \
            * shape.seq_len * cfg.d_model * 2
        w_wire = 3 * cfg.d_model * max(cfg.d_ff, 1) * 2 \
            * max(1, pc.microbatches)
        mlp_mode = "seq" if (pc.attn_strategy == "seq_tp"
                             and w_wire < act_wire) else "tp"
    rules_mlp_seq = "model" if (mlp_mode == "seq"
                                and pc.attn_strategy == "seq_tp") else None

    rules: dict = {
        "batch": batch_rule,
        "seq": None, "kv_seq": None, "cache_seq": None,
        "mlp_seq": rules_mlp_seq,
        "embed": None, "qkv": None, "cap": None, "state": None,
        "layers": None, "kv_rep": None,
        "w_embed": fsdp_ax,
        "vocab": "model" if vpad % tp == 0 else None,
        "mlp": None if rules_mlp_seq else (
            "model" if cfg.d_ff and cfg.d_ff % tp == 0 else None),
        "heads": None, "kv_heads": None,
        "expert": None, "expert_act": None,
        "inner": "model" if d_inner and d_inner % tp == 0 else None,
    }

    if cfg.moe is not None:
        if cfg.moe.num_experts % tp == 0:
            rules["expert"] = "model"
            if pc.moe_strategy == "all_to_all":
                rules["expert_act"] = "model"
            elif pc.moe_strategy == "shard_map_a2a" \
                    and shape.seq_len % tp == 0 and shape.mode != "decode":
                # explicit shuffle data plane (see models/moe.moe_shard_map)
                rules["moe_impl"] = "shard_map_a2a"
        else:  # experts not divisible: fall back to mlp-dim TP inside experts
            rules["expert"] = None
            rules["mlp"] = "model" if cfg.moe.d_expert % tp == 0 else None

    if pc.kv_compress:
        rules["kv_compress"] = True
    if pc.causal_skip:
        rules["causal_skip"] = True


    strat = pc.attn_strategy
    if strat == "head_tp":
        rules["heads"] = "model"
        kv_div = cfg.num_kv_heads % tp == 0
        rules["kv_heads"] = "model" if kv_div else None
        rules["kv_rep"] = "model" if kv_div else None
    elif strat == "seq_tp":
        rules["seq"] = "model"
        # KV stays at num_kv_heads width and is broadcast (hash join).
    elif strat == "decode_kv_shard":
        rules["cache_seq"] = "model"
        if cfg.num_heads % tp == 0:
            rules["heads"] = "model"
        if cfg.num_kv_heads % tp == 0:
            rules["kv_heads"] = "model"
    # "replicated"/"none": leave attention axes unsharded.

    if shape.name == "long_500k":
        # batch=1: recruit the idle data axis for state/cache sharding.
        extra = ("data", "model")
        if d_inner and d_inner % (dp * tp) == 0:
            rules["inner"] = extra
        if shape.seq_len % (dp * tp) == 0:
            rules["cache_seq"] = extra
        if vpad % (dp * tp) == 0:
            rules["vocab"] = extra
        rules["batch"] = None

    return ShardingRules(mesh, rules)


def build_workflow(cfg: ModelConfig, shape: ShapeConfig,
                   mesh: Mesh) -> DecisionWorkflow:
    wf = DecisionWorkflow(f"{cfg.name}:{shape.name}")
    wf.add(strategy_node(cfg, shape, mesh))
    return wf
