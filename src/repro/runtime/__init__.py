"""Executable serverless function runtime (paper's shared substrate).

The control plane (``repro.core``) decides *func/scale/schedule*; this
package executes those decisions for real: stateless function instances
(``invoker``) run registered partitioned-analytics functions (``functions``)
over an ephemeral externalized-state store (``store``), orchestrated as a
stage DAG (``executor``), with per-invocation metrics (``metrics``) folded
back into the decision workflows and optionally replayed into the cluster
simulator so both data planes share one plan.
"""

from repro.runtime.storage import (  # noqa: F401
    DiskBackend,
    MemoryBackend,
    ObjectStoreBackend,
    StorageBackend,
    make_backend,
)
from repro.runtime.store import (  # noqa: F401
    Blob,
    QuotaExceededError,
    ShuffleStore,
    StageLostError,
)
from repro.runtime.faults import (  # noqa: F401
    CrashFault,
    FaultInjector,
    FaultPlan,
    InjectedCrashError,
    InjectedFault,
    RecoveryError,
    SpeculationPolicy,
    StageLossFault,
    StragglerFault,
    WorkerKilledError,
    WorkerKillFault,
)
from repro.runtime.lineage import (  # noqa: F401
    LineageLog,
    RecoveryEvent,
    StageLineage,
    expected_recovery,
)
from repro.runtime.metrics import (  # noqa: F401
    InvocationRecord,
    MetricsSink,
    StageMetrics,
)
from repro.runtime.invoker import (  # noqa: F401
    FnContext,
    InlineInvoker,
    Invocation,
    InvocationError,
    Invoker,
    SlotGate,
    ThreadPoolInvoker,
)
from repro.runtime.functions import FUNCTIONS, register  # noqa: F401
from repro.runtime.workers import (  # noqa: F401
    ProcessPoolInvoker,
    WorkerPool,
)
from repro.runtime.executor import (  # noqa: F401
    DAGExecutor,
    Runtime,
    RuntimeStage,
    StagePlanner,
)
from repro.runtime.scheduler import (  # noqa: F401
    FairShareGate,
    GateTimeoutError,
    QueryJob,
    QueryResult,
    QueryScheduler,
)
