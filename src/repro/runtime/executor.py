"""Dependency-driven DAG executor: decision tuples -> real invocations.

``RuntimeStage`` is the materialized form of one decision-workflow stage: a
named group of invocations plus its upstream stage dependencies. The
executor launches any stage whose dependencies are satisfied — under a
parallel invoker independent stages (e.g. ``scan_fact`` and ``scan_dim``)
run concurrently — and interleaves decision evaluation with stage
completion: a ``planner`` callback is invoked as each stage finishes, folds
the measured metrics and observed output distributions back into its
decision-workflow context (paper Fig. 5 step 4), binds the next decisions,
and returns newly materialized stages to extend the DAG mid-query.
``barrier=True`` restores the legacy one-stage-at-a-time, list-order
execution (kept as the baseline for the executor benchmark).

``Runtime`` bundles the store + invoker + metrics behind one handle; several
applications (private controllers) can share it, contending for slots
through the one ``GlobalController`` — that is the paper's shared serverless
substrate.
"""

from __future__ import annotations

from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from repro.core.controllers import GlobalController, PrivateController
from repro.runtime.invoker import (
    InlineInvoker,
    Invocation,
    Invoker,
    ThreadPoolInvoker,
)
from repro.runtime.metrics import MetricsSink, StageMetrics
from repro.runtime.store import ShuffleStore


@dataclass
class RuntimeStage:
    """One stage of the physical plan: parallel invocations + stage deps."""

    name: str
    invocations: list[Invocation]
    deps: tuple[str, ...] = ()
    ephemeral_inputs: tuple[str, ...] = ()   # stages to GC once this finishes


class StagePlanner:
    """Protocol for planners that extend the DAG as stages complete.

    ``initial_stages`` materializes the stages known up front;
    ``on_stage_complete`` is called after each stage finishes (metrics
    recorded, ephemeral inputs not yet reclaimed) and returns further
    stages to schedule — typically by binding the next late-bound decisions
    of a ``WorkflowRun``. Return an empty list when nothing new unlocks.
    """

    def initial_stages(self) -> list[RuntimeStage]:  # pragma: no cover
        return []

    def on_stage_complete(self, stage: str, runtime: "Runtime",
                          pc: PrivateController | None = None,
                          ) -> list[RuntimeStage]:  # pragma: no cover
        return []


class DAGExecutor:
    """Dependency-driven stage scheduler over a pluggable invoker."""

    def __init__(self, runtime: "Runtime", barrier: bool = False):
        self.runtime = runtime
        self.barrier = barrier

    def run(self, stages: Sequence[RuntimeStage],
            pc: PrivateController | None = None,
            planner: StagePlanner | None = None) -> dict[str, StageMetrics]:
        known: dict[str, RuntimeStage] = {}
        pending: dict[str, RuntimeStage] = {}   # insertion-ordered
        completed: set[str] = set()

        def admit(batch):
            batch = list(batch or ())
            for st in batch:
                if st.name in known:
                    raise ValueError(f"duplicate stage {st.name!r}")
                known[st.name] = st
                pending[st.name] = st
            for st in batch:
                missing = [d for d in st.deps if d not in known]
                if missing:
                    raise ValueError(
                        f"stage {st.name!r} depends on unknown {missing}")

        admit(stages)
        if not known:
            return {}
        app = next(st.invocations[0].app for st in known.values()
                   if st.invocations)
        invoker = self.runtime.invoker
        metrics = self.runtime.metrics

        def dep_invs(st: RuntimeStage) -> tuple[str, ...]:
            return tuple(inv.name for d in st.deps
                         for inv in known[d].invocations)

        def finish(st: RuntimeStage) -> None:
            completed.add(st.name)
            if pc is not None:
                pc.record_profile(
                    **metrics.profile_feedback(app, stage=st.name))
            if planner is not None:
                admit(planner.on_stage_complete(st.name, self.runtime, pc))
            for src in st.ephemeral_inputs:
                # under a quota the stage is sealed (lazily evicted when the
                # app needs headroom); otherwise dropped immediately
                self.runtime.store.reclaim_stage(app, src)

        if self.barrier or not getattr(invoker, "parallel", False):
            self._run_serial(pending, completed, invoker, dep_invs, finish)
        else:
            self._run_concurrent(pending, completed, invoker, dep_invs,
                                 finish)
        return metrics.by_stage(app)

    def _run_serial(self, pending, completed, invoker, dep_invs, finish):
        """One stage at a time. ``barrier`` keeps strict admission order
        (the legacy executor); otherwise the first *ready* stage runs, so
        dynamically admitted stages interleave correctly."""
        while pending:
            if self.barrier:
                name = next(iter(pending))
                blocked = [d for d in pending[name].deps
                           if d not in completed]
                if blocked:
                    raise ValueError(
                        f"stage {name!r} blocked on incomplete {blocked} "
                        f"(barrier mode runs stages in admission order)")
            else:
                ready = [n for n, st in pending.items()
                         if all(d in completed for d in st.deps)]
                if not ready:
                    raise ValueError(
                        f"stages {sorted(pending)} blocked on unsatisfied "
                        f"dependencies")
                name = ready[0]
            st = pending.pop(name)
            invoker.run_stage(st.invocations, deps=dep_invs(st))
            finish(st)

    def _run_concurrent(self, pending, completed, invoker, dep_invs, finish):
        """Every ready stage gets a driver thread; completions unlock
        dependents (and, via the planner, late-bound decisions) while
        sibling stages are still in flight."""
        max_drivers = max(2, int(getattr(invoker, "max_workers", 8)))
        with ThreadPoolExecutor(max_workers=max_drivers) as drivers:
            in_flight: dict = {}
            while pending or in_flight:
                ready = [n for n, st in pending.items()
                         if all(d in completed for d in st.deps)]
                for name in ready:
                    st = pending.pop(name)
                    fut = drivers.submit(invoker.run_stage, st.invocations,
                                         deps=dep_invs(st))
                    in_flight[fut] = st
                if not in_flight:
                    raise ValueError(
                        f"stages {sorted(pending)} blocked on unsatisfied "
                        f"dependencies")
                done, _ = wait(in_flight, return_when=FIRST_COMPLETED)
                for fut in done:
                    st = in_flight.pop(fut)
                    fut.result()        # propagate the first failure
                    finish(st)


class Runtime:
    """The executable serverless substrate: store + invoker + metrics.

    ``invoker`` may be an ``Invoker`` instance or one of the backend names
    ``"inline"`` / ``"threads"``.
    """

    def __init__(self, gc: GlobalController,
                 invoker: Invoker | str = "inline",
                 store: ShuffleStore | None = None,
                 metrics: MetricsSink | None = None, max_workers: int = 8,
                 net_bw: float | None = None, disaggregated: bool = False):
        self.gc = gc
        self.store = store or ShuffleStore(net_bw=net_bw,
                                           disaggregated=disaggregated)
        self.metrics = metrics or MetricsSink()
        if isinstance(invoker, str):
            if invoker == "inline":
                invoker = InlineInvoker(gc, self.store, self.metrics)
            elif invoker == "threads":
                invoker = ThreadPoolInvoker(gc, self.store, self.metrics,
                                            max_workers=max_workers)
            else:
                raise ValueError(f"unknown invoker backend {invoker!r}")
        self.invoker = invoker

    def seed(self, app: str, stage: str,
             partitions: Mapping[int, object]) -> list[tuple[int, int]]:
        """Load base data (node -> table) into the store; returns the
        ``[(partition, home_node), ...]`` layout the planner places against.
        """
        return self.store.ingest(app, stage, partitions)

    def execute(self, stages: Sequence[RuntimeStage],
                pc: PrivateController | None = None,
                planner: StagePlanner | None = None,
                barrier: bool = False) -> dict[str, StageMetrics]:
        return DAGExecutor(self, barrier=barrier).run(stages, pc=pc,
                                                      planner=planner)

    def result(self, app: str, stage: str = "result", column: str = "sum",
               ) -> np.ndarray:
        t = self.store.get(app, stage, 0, node=-1, account=False)
        if t is None:
            raise KeyError(f"no result blob for app {app!r}")
        return np.asarray(t[column])

    def replay_into(self, sim, app: str | None = None,
                    rates: Mapping[str, float] | None = None) -> int:
        """Feed the invocation trace to a ``ClusterSim`` (one shared plan)."""
        return self.metrics.replay_into(sim, app=app, rates=rates)

    def release(self, app: str) -> int:
        """Tear down an application's ephemeral state; returns bytes freed."""
        return self.store.clear_app(app)
