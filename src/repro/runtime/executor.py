"""DAG executor: decision tuples -> real function invocations.

``RuntimeStage`` is the materialized form of one decision-workflow stage: a
named group of invocations plus its upstream stage dependencies. The
executor walks stages in dependency order with a barrier per stage (shuffle
consumers must see every producer's slice), drives the pluggable invoker,
and folds per-stage metrics back into the application's private controller
profile so the *next* decision sees what the last execution cost (paper
Fig. 5 step 4).

``Runtime`` bundles the store + invoker + metrics behind one handle; several
applications (private controllers) can share it, contending for slots
through the one ``GlobalController`` — that is the paper's shared serverless
substrate.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.core.controllers import GlobalController, PrivateController
from repro.runtime.invoker import (
    InlineInvoker,
    Invocation,
    Invoker,
    ThreadPoolInvoker,
)
from repro.runtime.metrics import MetricsSink, StageMetrics
from repro.runtime.store import ShuffleStore


@dataclass
class RuntimeStage:
    """One stage of the physical plan: parallel invocations + stage deps."""

    name: str
    invocations: list[Invocation]
    deps: tuple[str, ...] = ()
    ephemeral_inputs: tuple[str, ...] = ()   # stages to GC once this finishes


class DAGExecutor:
    """Barrier-per-stage DAG driver over an invoker."""

    def __init__(self, runtime: "Runtime"):
        self.runtime = runtime

    def run(self, stages: Sequence[RuntimeStage],
            pc: PrivateController | None = None) -> dict[str, StageMetrics]:
        seen: dict[str, RuntimeStage] = {}
        for stage in stages:
            missing = [d for d in stage.deps if d not in seen]
            if missing:
                raise ValueError(
                    f"stage {stage.name!r} depends on unknown {missing}")
            if stage.name in seen:
                raise ValueError(f"duplicate stage {stage.name!r}")
            seen[stage.name] = stage

        invoker = self.runtime.invoker
        metrics = self.runtime.metrics
        app = stages[0].invocations[0].app if stages else ""
        for stage in stages:
            dep_invs = tuple(inv.name for d in stage.deps
                             for inv in seen[d].invocations)
            invoker.run_stage(stage.invocations, deps=dep_invs)
            if pc is not None:
                pc.record_profile(
                    **metrics.profile_feedback(app, stage=stage.name))
            for src in stage.ephemeral_inputs:
                self.runtime.store.delete_stage(app, src)
        return metrics.by_stage(app)


class Runtime:
    """The executable serverless substrate: store + invoker + metrics.

    ``invoker`` may be an ``Invoker`` instance or one of the backend names
    ``"inline"`` / ``"threads"``.
    """

    def __init__(self, gc: GlobalController,
                 invoker: Invoker | str = "inline",
                 store: ShuffleStore | None = None,
                 metrics: MetricsSink | None = None, max_workers: int = 8):
        self.gc = gc
        self.store = store or ShuffleStore()
        self.metrics = metrics or MetricsSink()
        if isinstance(invoker, str):
            if invoker == "inline":
                invoker = InlineInvoker(gc, self.store, self.metrics)
            elif invoker == "threads":
                invoker = ThreadPoolInvoker(gc, self.store, self.metrics,
                                            max_workers=max_workers)
            else:
                raise ValueError(f"unknown invoker backend {invoker!r}")
        self.invoker = invoker

    def seed(self, app: str, stage: str,
             partitions: Mapping[int, object]) -> list[tuple[int, int]]:
        """Load base data (node -> table) into the store; returns the
        ``[(partition, home_node), ...]`` layout the planner places against.
        """
        return self.store.ingest(app, stage, partitions)

    def execute(self, stages: Sequence[RuntimeStage],
                pc: PrivateController | None = None) -> dict[str, StageMetrics]:
        return DAGExecutor(self).run(stages, pc=pc)

    def result(self, app: str, stage: str = "result", column: str = "sum",
               ) -> np.ndarray:
        t = self.store.get(app, stage, 0, node=-1, account=False)
        if t is None:
            raise KeyError(f"no result blob for app {app!r}")
        return np.asarray(t[column])

    def replay_into(self, sim, app: str | None = None,
                    rates: Mapping[str, float] | None = None) -> int:
        """Feed the invocation trace to a ``ClusterSim`` (one shared plan)."""
        return self.metrics.replay_into(sim, app=app, rates=rates)

    def release(self, app: str) -> int:
        """Tear down an application's ephemeral state; returns bytes freed."""
        return self.store.clear_app(app)
