"""Dependency-driven DAG executor: decision tuples -> real invocations.

``RuntimeStage`` is the materialized form of one decision-workflow stage: a
named group of invocations plus its upstream stage dependencies. The
executor launches any stage whose dependencies are satisfied — under a
parallel invoker independent stages (e.g. ``scan_fact`` and ``scan_dim``)
run concurrently — and interleaves decision evaluation with stage
completion: a ``planner`` callback is invoked as each stage finishes, folds
the measured metrics and observed output distributions back into its
decision-workflow context (paper Fig. 5 step 4), binds the next decisions,
and returns newly materialized stages to extend the DAG mid-query.
``barrier=True`` restores the legacy one-stage-at-a-time, list-order
execution (kept as the baseline for the executor benchmark).

``Runtime`` bundles the store + invoker + metrics behind one handle; several
applications (private controllers) can share it, contending for slots
through the one ``GlobalController`` — that is the paper's shared serverless
substrate.
"""

from __future__ import annotations

import threading
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from repro.core.controllers import GlobalController, PrivateController
from repro.core.decisions import DecisionContext, DecisionNode
from repro.obs.audit import bound_app
from repro.obs.tracer import get_tracer
from repro.runtime.faults import RecoveryError
from repro.runtime.invoker import (
    InlineInvoker,
    Invocation,
    Invoker,
    ThreadPoolInvoker,
)
from repro.runtime.lineage import LineageLog, RecoveryEvent
from repro.runtime.metrics import MetricsSink, StageMetrics
from repro.runtime.store import ShuffleStore, StageLostError


@dataclass
class RuntimeStage:
    """One stage of the physical plan: parallel invocations + stage deps."""

    name: str
    invocations: list[Invocation]
    deps: tuple[str, ...] = ()
    ephemeral_inputs: tuple[str, ...] = ()   # stages to GC once this finishes
    decision: str | None = None              # decision node that emitted it


class StagePlanner:
    """Protocol for planners that extend the DAG as stages complete.

    ``initial_stages`` materializes the stages known up front;
    ``on_stage_complete`` is called after each stage finishes (metrics
    recorded, ephemeral inputs not yet reclaimed) and returns further
    stages to schedule — typically by binding the next late-bound decisions
    of a ``WorkflowRun``. Return an empty list when nothing new unlocks.
    """

    def initial_stages(self) -> list[RuntimeStage]:  # pragma: no cover
        return []

    def on_stage_complete(self, stage: str, runtime: "Runtime",
                          pc: PrivateController | None = None,
                          ) -> list[RuntimeStage]:  # pragma: no cover
        return []


class DAGExecutor:
    """Dependency-driven stage scheduler over a pluggable invoker.

    Failure handling: every admitted stage is registered with the runtime's
    ``LineageLog``; when a read during a stage hits a lost shuffle stage
    (``StageLostError`` — evicted ephemeral data, quota pressure, injected
    fault), the executor asks the lineage for a bounded recovery plan and
    re-executes only the lost partitions' producer invocations (recursively,
    for producers whose own inputs are gone), then retries the stage's
    not-yet-committed invocations. Recovery runs through the normal invoker,
    so it honors slot-fairness gates and store quotas like first-run work.
    ``recovery`` picks the policy: ``"lineage"`` (default), ``"rerun"``
    (surface ``RecoveryError`` at the first loss — the caller reruns the
    query), or a ``DecisionNode`` (e.g. ``repro.core.decisions.
    recovery_node``) deciding per-loss from the plan size.
    """

    def __init__(self, runtime: "Runtime", barrier: bool = False,
                 max_recoveries: int = 8,
                 recovery: str | DecisionNode = "lineage",
                 pipeline: bool = False):
        self.runtime = runtime
        self.barrier = barrier
        self.max_recoveries = max_recoveries
        self.recovery = recovery
        self.pipeline = pipeline
        self._recover_lock = threading.Lock()
        # pipelined mode: committed invocation names + a condition the
        # metrics listener notifies on every commit — partition-granularity
        # readiness (an invocation whose ``needs`` are all committed may
        # run before its producer *stage* has finished)
        self._ok: set[str] = set()
        self._ok_cond = threading.Condition()
        self._abort = threading.Event()

    def _on_record(self, rec) -> None:
        if rec.status != "ok":
            return
        with self._ok_cond:
            self._ok.add(rec.name)
            self._ok_cond.notify_all()

    def run(self, stages: Sequence[RuntimeStage],
            pc: PrivateController | None = None,
            planner: StagePlanner | None = None) -> dict[str, StageMetrics]:
        known: dict[str, RuntimeStage] = {}
        pending: dict[str, RuntimeStage] = {}   # insertion-ordered
        completed: set[str] = set()

        def admit(batch):
            batch = list(batch or ())
            for st in batch:
                if st.name in known:
                    raise ValueError(f"duplicate stage {st.name!r}")
                known[st.name] = st
                pending[st.name] = st
                self.runtime.lineage.register_stage(st)
            for st in batch:
                missing = [d for d in st.deps if d not in known]
                if missing:
                    raise ValueError(
                        f"stage {st.name!r} depends on unknown {missing}")

        admit(stages)
        if not known:
            return {}
        app = next(st.invocations[0].app for st in known.values()
                   if st.invocations)
        invoker = self.runtime.invoker
        metrics = self.runtime.metrics
        # root the query's span tree: when no scheduler anchored a
        # ("query", app) span (direct executor use), open one here so stage
        # spans always have a live cross-thread parent
        tr = get_tracer()
        own_root = None
        if tr.enabled and tr.anchored(("query", app)) is None:
            own_root = tr.start(f"query/{app}", "executor", trace=app,
                                parent=None)
            tr.anchor(("query", app), own_root)

        def dep_invs(st: RuntimeStage) -> tuple[str, ...]:
            return tuple(inv.name for d in st.deps
                         for inv in known[d].invocations)

        def finish(st: RuntimeStage) -> None:
            completed.add(st.name)
            if pc is not None:
                pc.record_profile(
                    **metrics.profile_feedback(app, stage=st.name))
            if planner is not None:
                admit(planner.on_stage_complete(st.name, self.runtime, pc))
            for src in st.ephemeral_inputs:
                # under a quota the stage is sealed (lazily evicted when the
                # app needs headroom); otherwise dropped immediately
                self.runtime.store.reclaim_stage(app, src)

        prev_honor = getattr(invoker, "honor_plan", False)
        if self.pipeline:
            metrics.subscribe(self._on_record)
            invoker.honor_plan = True
        try:
            if self.barrier or not getattr(invoker, "parallel", False):
                self._run_serial(pending, completed, invoker, dep_invs,
                                 finish)
            else:
                self._run_concurrent(pending, completed, invoker, dep_invs,
                                     finish)
        finally:
            if self.pipeline:
                invoker.honor_plan = prev_honor
                metrics.unsubscribe(self._on_record)
            if own_root is not None:
                tr.release_anchor(("query", app))
                tr.end(own_root, stages=len(known))
        return metrics.by_stage(app)

    def _run_serial(self, pending, completed, invoker, dep_invs, finish):
        """One stage at a time. ``barrier`` keeps strict admission order
        (the legacy executor); otherwise the first *ready* stage runs, so
        dynamically admitted stages interleave correctly."""
        while pending:
            if self.barrier:
                name = next(iter(pending))
                blocked = [d for d in pending[name].deps
                           if d not in completed]
                if blocked:
                    raise ValueError(
                        f"stage {name!r} blocked on incomplete {blocked} "
                        f"(barrier mode runs stages in admission order)")
            else:
                ready = [n for n, st in pending.items()
                         if all(d in completed for d in st.deps)]
                if not ready:
                    raise ValueError(
                        f"stages {sorted(pending)} blocked on unsatisfied "
                        f"dependencies")
                name = ready[0]
            st = pending.pop(name)
            self._run_stage_recovering(st, dep_invs(st))
            finish(st)

    def _run_concurrent(self, pending, completed, invoker, dep_invs, finish):
        """Every ready stage gets a driver thread; completions unlock
        dependents (and, via the planner, late-bound decisions) while
        sibling stages are still in flight."""
        max_drivers = max(2, int(getattr(invoker, "max_workers", 8)))
        with ThreadPoolExecutor(max_workers=max_drivers) as drivers:
            in_flight: dict = {}
            while pending or in_flight:
                ready = [n for n, st in pending.items()
                         if all(d in completed for d in st.deps)]
                if self.pipeline:
                    # partial readiness: a stage whose every invocation
                    # carries partition-granularity ``needs`` may launch
                    # while its producer stages are still in flight — its
                    # driver admits invocations wave-by-wave as their
                    # producers commit. Capacity-capped so a wave-waiting
                    # consumer can never occupy the driver slot its own
                    # producer is queued for.
                    active = {st.name for st in in_flight.values()}
                    for n, st in pending.items():
                        if (n in ready
                                or len(in_flight) + len(ready)
                                >= max_drivers - 1):
                            continue
                        if (st.invocations
                                and all(iv.needs for iv in st.invocations)
                                and all(d in completed or d in active
                                        for d in st.deps)):
                            ready.append(n)
                for name in ready:
                    st = pending.pop(name)
                    fut = drivers.submit(self._run_stage_recovering, st,
                                         dep_invs(st))
                    in_flight[fut] = st
                if not in_flight:
                    raise ValueError(
                        f"stages {sorted(pending)} blocked on unsatisfied "
                        f"dependencies")
                done, _ = wait(in_flight, return_when=FIRST_COMPLETED)
                for fut in done:
                    st = in_flight.pop(fut)
                    try:
                        fut.result()    # propagate the first failure
                    except BaseException:
                        # wake every wave-waiting driver before unwinding,
                        # or the pool shutdown would join them forever
                        self._abort.set()
                        with self._ok_cond:
                            self._ok_cond.notify_all()
                        raise
                    finish(st)

    # -- lineage-based recovery -----------------------------------------------

    def _run_stage_recovering(self, st: RuntimeStage,
                              deps: tuple[str, ...]) -> None:
        """Run one stage, healing lost-stage reads via lineage recompute.

        Each round retries only the stage's not-yet-committed invocations
        (writer-label overwrite makes duplicates safe anyway). A loss
        surfacing *during* recovery (a deeper input also gone, or a
        concurrent eviction) is replanned on the next round against the
        store's current state; ``max_recoveries`` bounds the rounds so an
        unrecoverable store can never wedge the executor.
        """
        invoker = self.runtime.invoker
        metrics = self.runtime.metrics
        # stage lifecycle span, anchored so invocation spans in invoker
        # worker threads parent to it; trace id = the app
        tr = get_tracer()
        app = st.invocations[0].app if st.invocations else None
        ssp = None
        if app is not None:
            ssp = tr.start(f"stage/{st.name}", "executor", trace=app,
                           parent=tr.anchored(("query", app)), stage=st.name,
                           deps=list(st.deps), decision=st.decision,
                           invocations=len(st.invocations))
            tr.anchor(("stage", app, st.name), ssp)
        # only records born in *this* run count as committed: a rerun of the
        # same app on the same Runtime must not skip invocations whose
        # previous-attempt outputs were torn down with the old store state
        first_record = len(metrics.records)
        todo = list(st.invocations)
        rounds = 0
        try:
            while True:
                try:
                    if todo:
                        if (self.pipeline
                                and all(iv.needs for iv in todo)):
                            self._run_stage_waves(todo, deps)
                        else:
                            invoker.run_stage(todo, deps=deps)
                    return
                except StageLostError as e:
                    rounds += 1
                    if rounds > self.max_recoveries:
                        raise RecoveryError(
                            f"stage {st.name!r}: recovery budget "
                            f"({self.max_recoveries}) exhausted healing "
                            f"{e.stage!r}") from e
                    try:
                        self._recover(e)
                    except StageLostError:
                        # deeper loss mid-recovery: replan next round against
                        # the store's current state
                        pass
                    ok = {r.name for r in metrics.records[first_record:]
                          if r.stage == st.name and r.status == "ok"}
                    todo = [iv for iv in st.invocations
                            if iv.name not in ok] or list(st.invocations)
        finally:
            if app is not None:
                tr.release_anchor(("stage", app, st.name))
                tr.end(ssp, recovery_rounds=rounds)

    def _run_stage_waves(self, todo: list[Invocation],
                         deps: tuple[str, ...]) -> None:
        """Admit a stage's invocations in waves as their producers commit.

        Every invocation in ``todo`` carries ``needs`` (producer invocation
        names); a wave is the subset whose needs are all committed. The
        commit listener wakes the wait, so a join partition starts the
        moment its input buckets are published — no stage barrier. The
        timeout re-check and the abort event keep a wave from outliving a
        failed producer stage.
        """
        invoker = self.runtime.invoker
        remaining = list(todo)
        while remaining:
            with self._ok_cond:
                while True:
                    if self._abort.is_set():
                        raise RecoveryError(
                            "pipelined stage abandoned: an upstream stage "
                            "failed while invocations awaited their "
                            "producers")
                    wave = [iv for iv in remaining
                            if set(iv.needs) <= self._ok]
                    if wave:
                        break
                    self._ok_cond.wait(timeout=0.1)
            launched = {iv.name for iv in wave}
            remaining = [iv for iv in remaining if iv.name not in launched]
            invoker.run_stage(wave, deps=deps)

    def _recover(self, err: StageLostError) -> None:
        """Re-execute the lost partitions' producers, bottom-up."""
        store = self.runtime.store
        lineage = self.runtime.lineage
        with self._recover_lock:
            lost_now = store.lost_partitions(err.app, err.stage)
            if not lost_now or (err.partitions is not None and
                                not lost_now & set(err.partitions)):
                return          # a concurrent driver already healed this
            # heal every partition of the stage that is currently lost, not
            # just the one read that tripped — a whole-stage loss read
            # partition-by-partition must cost one recovery round, not one
            # per partition (which would burn max_recoveries spuriously)
            target = sorted(lost_now)
            plan = lineage.recovery_plan(err.app, err.stage, target,
                                         store, metrics=self.runtime.metrics)
            if plan is None:
                raise RecoveryError(
                    f"{err.app!r}/{err.stage!r} lost but has no lineage "
                    f"(base input?): only a whole-query rerun can restore "
                    f"it") from err
            n_invs = sum(len(invs) for _, _, invs in plan)
            if self._recovery_choice(err, n_invs) == "rerun":
                raise RecoveryError(
                    f"{err.app!r}/{err.stage!r}: recovery policy chose "
                    f"whole-query rerun over recomputing {n_invs} "
                    f"invocations") from err
            tr = get_tracer()
            with tr.span(f"recovery/{err.stage}", "executor", trace=err.app,
                         parent=tr.anchored(("query", err.app)),
                         lost_stage=err.stage, partitions=list(target),
                         reexec_invocations=n_invs):
                for data_stage, parts, invs in plan:
                    if invs:
                        self.runtime.invoker.run_stage(invs, deps=())
                    # producers re-ran: any still-absent healed partition is
                    # genuinely empty, not missing — but only the partitions
                    # this plan covered
                    store.clear_lost(err.app, data_stage,
                                     None if parts is None else sorted(parts))
            self.runtime.recoveries.append(RecoveryEvent(
                err.app, err.stage, tuple(target),
                tuple(ds for ds, _, _ in plan), n_invs))

    def _recovery_choice(self, err: StageLostError, n_invs: int) -> str:
        if isinstance(self.recovery, DecisionNode):
            ctx = DecisionContext(
                node_status=self.runtime.gc.node_status(),
                profile={
                    "recovery.lost_stage": err.stage,
                    "recovery.reexec_invocations": n_invs,
                    "recovery.total_invocations":
                        self.runtime.lineage.total_invocations(err.app),
                })
            with bound_app(err.app):
                decision = self.recovery.decide(ctx)
            return "rerun" if decision.func == "rerun" else "recompute"
        return "rerun" if self.recovery == "rerun" else "recompute"


class Runtime:
    """The executable serverless substrate: store + invoker + metrics.

    ``invoker`` may be an ``Invoker`` instance or one of the backend names
    ``"inline"`` / ``"threads"`` / ``"process"`` (long-lived worker
    subprocesses — see ``repro.runtime.workers``).
    """

    def __init__(self, gc: GlobalController,
                 invoker: Invoker | str = "inline",
                 store: ShuffleStore | None = None,
                 metrics: MetricsSink | None = None, max_workers: int = 8,
                 net_bw: float | None = None, disaggregated: bool = False,
                 batching: bool = True, storage="memory",
                 spill_backends=None):
        self.gc = gc
        # ``storage`` picks the store's primary backend (name or
        # StorageBackend instance); ``spill_backends`` adds colder tiers
        # the tiering decision may demote sealed stages into. Both are
        # ignored when an explicit ``store`` is supplied.
        self.store = store or ShuffleStore(net_bw=net_bw,
                                           disaggregated=disaggregated,
                                           backend=storage,
                                           spill_backends=spill_backends)
        self.metrics = metrics or MetricsSink()
        if isinstance(invoker, str):
            if invoker == "inline":
                invoker = InlineInvoker(gc, self.store, self.metrics,
                                        batching=batching)
            elif invoker == "threads":
                invoker = ThreadPoolInvoker(gc, self.store, self.metrics,
                                            max_workers=max_workers,
                                            batching=batching)
            elif invoker == "process":
                # imported lazily: the worker plane pulls multiprocessing
                # machinery most runtimes never need
                from repro.runtime.workers import ProcessPoolInvoker
                invoker = ProcessPoolInvoker(gc, self.store, self.metrics,
                                             max_workers=max_workers,
                                             batching=batching)
            else:
                raise ValueError(f"unknown invoker backend {invoker!r}")
        self.invoker = invoker
        self.lineage = LineageLog()
        self.recoveries: list[RecoveryEvent] = []

    def seed(self, app: str, stage: str, partitions,
             tier: str | None = None) -> list[tuple[int, int]]:
        """Load base data (``{node: table}`` or ``[(node, table), ...]`` for
        several partitions per node) into the store; ``tier`` seeds
        straight into a cold backend (the Lambada cold-data scenario).
        Returns the ``[(partition, home_node), ...]`` layout the planner
        places against.
        """
        return self.store.ingest(app, stage, partitions, tier=tier)

    def execute(self, stages: Sequence[RuntimeStage],
                pc: PrivateController | None = None,
                planner: StagePlanner | None = None,
                barrier: bool = False, max_recoveries: int = 8,
                recovery: str | DecisionNode = "lineage",
                pipeline: bool = False) -> dict[str, StageMetrics]:
        return DAGExecutor(self, barrier=barrier,
                           max_recoveries=max_recoveries,
                           recovery=recovery,
                           pipeline=pipeline).run(stages, pc=pc,
                                                  planner=planner)

    def result(self, app: str, stage: str = "result", column: str = "sum",
               ) -> np.ndarray:
        t = self.store.get(app, stage, 0, node=-1, account=False)
        if t is None:
            raise KeyError(f"no result blob for app {app!r}")
        return np.asarray(t[column])

    def replay_into(self, sim, app: str | None = None,
                    rates: Mapping[str, float] | None = None) -> int:
        """Feed the invocation trace to a ``ClusterSim`` (one shared plan)."""
        return self.metrics.replay_into(sim, app=app, rates=rates)

    def release(self, app: str) -> int:
        """Tear down an application's ephemeral state; returns bytes freed."""
        return self.store.clear_app(app)
