"""Deterministic fault injection for the serverless runtime.

The paper's extensibility claim is strongest where it is hardest: failure
handling. A disaggregated ephemeral store loses stages (the ServerMix
tension), function instances crash, and straggler nodes stretch tails (what
Lambada works around with exchange-operator retries). This module makes
every one of those failure modes a *reproducible test fixture*: a
``FaultPlan`` is a declarative, seedable schedule of faults, and a
``FaultInjector`` arms it on a ``Runtime`` — hooking the invoker (crashes,
injected latency) and the shuffle store (stage loss on the k-th read).

Fault-plan schema
-----------------

``FaultPlan(crashes=[...], stragglers=[...], losses=[...])`` where

* ``CrashFault(stage, index, when, attempt, times)`` — kill a function
  invocation of physical stage ``stage`` (``index=None`` matches any
  instance). ``when="before"`` crashes before the body runs
  (crash-before-commit: no store writes land); ``when="after"`` crashes
  after the body ran (crash-after-write: outputs are in the store under the
  invocation's writer label, so the retry *overwrites* instead of
  duplicating). ``attempt`` selects which retry attempt to kill (default 0,
  the first), ``times`` how many matching invocations to kill.
* ``StragglerFault(node, delay, stage)`` — every matching invocation placed
  on ``node`` (optionally only for ``stage``) sleeps ``delay`` seconds
  before its body runs, emulating a slow node. ``times`` bounds how many
  invocations straggle (default: all).
* ``StageLossFault(stage, partitions, on_read)`` — evict the *data* stage
  ``stage`` (all partitions, or just ``partitions``) from the store
  immediately before its ``on_read``-th read (1-based), leaving lost
  tombstones so the reader raises ``StageLostError`` — the trigger for
  lineage-based recovery.
* ``WorkerKillFault(stage, index, attempt, times)`` — SIGKILL the worker
  *subprocess* running a matching invocation mid-body (process-backed
  invokers only; thread invokers have no process to kill and ignore it).
  The host surfaces the dead pipe as ``WorkerKilledError`` — a crashed
  attempt record — and retries on a freshly provisioned worker. Because a
  worker's writes are buffered worker-side and committed by the host only
  after the body completes, a killed worker never leaves partial store
  writes.

All triggers are match-count based (never wall-clock), so a plan replays
identically under the inline invoker, the thread-pool invoker, and the
cluster simulator's failure models.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

from repro.core.decisions import DecisionContext, NodeStatus, speculation_node
from repro.runtime.store import StageLostError  # noqa: F401  (re-export)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.runtime.invoker import Invocation


class InjectedFault(RuntimeError):
    """Base class for faults raised by a ``FaultInjector``."""


class InjectedCrashError(InjectedFault):
    """An invocation was killed by the fault plan; the invoker retries it
    (stateless functions + writer-label overwrite make the retry safe)."""


class WorkerKilledError(InjectedCrashError):
    """A worker subprocess died (SIGKILL, OOM, injected worker-kill) while
    running an invocation. A subclass of ``InjectedCrashError`` so the
    invoker's existing crash machinery records it and retries — on a fresh
    worker, since the dead one's pipe is gone."""


class RecoveryError(RuntimeError):
    """Lineage recovery could not (or was told not to) heal a lost stage:
    no lineage recorded, recovery budget exhausted, or the recovery
    decision node chose a whole-query rerun."""


@dataclass(frozen=True)
class CrashFault:
    stage: str
    index: int | None = None
    when: str = "before"          # "before" (no writes) | "after" (written)
    attempt: int = 0
    times: int = 1


@dataclass(frozen=True)
class StragglerFault:
    node: int
    delay: float
    stage: str | None = None
    times: int | None = None      # None = every matching invocation


@dataclass(frozen=True)
class StageLossFault:
    stage: str                    # *data* stage name, e.g. "joined"
    partitions: tuple[int, ...] | None = None
    on_read: int = 1              # trigger before the k-th get (1-based)


@dataclass(frozen=True)
class WorkerKillFault:
    stage: str
    index: int | None = None      # None matches any instance of the stage
    attempt: int = 0
    times: int = 1
    # "body": the worker SIGKILLs itself at its first store read (claim
    # live, body started, nothing written); "late": after the body ran —
    # its writes are buffered worker-side and die with it, proving the
    # no-partial-writes invariant
    when: str = "body"


@dataclass
class FaultPlan:
    """A declarative, replayable schedule of injected faults."""

    crashes: list[CrashFault] = field(default_factory=list)
    stragglers: list[StragglerFault] = field(default_factory=list)
    losses: list[StageLossFault] = field(default_factory=list)
    worker_kills: list[WorkerKillFault] = field(default_factory=list)

    @classmethod
    def seeded(cls, seed: int, stages: Sequence[str] = ("scan_fact", "join"),
               data_stages: Sequence[str] = ("joined",),
               nodes: Sequence[int] = (0, 1), n_crashes: int = 2,
               n_losses: int = 1, n_stragglers: int = 1,
               delay: float = 0.25) -> "FaultPlan":
        """Deterministically derive a plan from ``seed`` — the chaos tests'
        and benchmarks' reproducible fixture generator."""
        import numpy as np

        rng = np.random.default_rng(seed)
        crashes = [CrashFault(str(rng.choice(list(stages))), None,
                              when=("before", "after")[int(rng.integers(2))])
                   for _ in range(n_crashes)]
        losses = [StageLossFault(str(rng.choice(list(data_stages))),
                                 on_read=int(rng.integers(1, 3)))
                  for _ in range(n_losses)]
        stragglers = [StragglerFault(int(rng.choice(list(nodes))), delay)
                      for _ in range(n_stragglers)]
        return cls(crashes=crashes, stragglers=stragglers, losses=losses)


class FaultInjector:
    """Arms a ``FaultPlan`` on a runtime: invoker + store hooks.

    Thread-safe; all trigger counters are under one lock so a plan fires
    each fault exactly ``times`` times no matter how invocations interleave.
    ``install(runtime)`` wires both hook points and returns the injector.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._crash_fired = [0] * len(plan.crashes)
        self._straggle_fired = [0] * len(plan.stragglers)
        self._loss_fired = [False] * len(plan.losses)
        self._kill_fired = [0] * len(getattr(plan, "worker_kills", []))
        self._reads: dict[tuple[str, str], int] = {}   # (app, stage) -> gets
        self._store = None
        self.injected: list[tuple[str, str]] = []      # (kind, detail) log

    def install(self, runtime) -> "FaultInjector":
        runtime.invoker.injector = self
        runtime.store.injector = self
        self._store = runtime.store
        return self

    # -- invoker hooks -------------------------------------------------------

    def _match_crash(self, inv: "Invocation", attempt: int,
                     when: str) -> bool:
        with self._lock:
            for i, c in enumerate(self.plan.crashes):
                if c.when != when or c.stage != inv.stage:
                    continue
                if c.index is not None and c.index != inv.index:
                    continue
                if c.attempt != attempt or self._crash_fired[i] >= c.times:
                    continue
                self._crash_fired[i] += 1
                self.injected.append(("crash-" + when, inv.name))
                return True
        return False

    def before_body(self, inv: "Invocation", attempt: int) -> None:
        """Runs while the slot claim is live, before the function body:
        injected latency (stragglers) first, then crash-before-commit."""
        delay = 0.0
        with self._lock:
            for i, s in enumerate(self.plan.stragglers):
                if s.node != inv.node:
                    continue
                if s.stage is not None and s.stage != inv.stage:
                    continue
                if s.times is not None and self._straggle_fired[i] >= s.times:
                    continue
                self._straggle_fired[i] += 1
                self.injected.append(("straggle", inv.name))
                delay = max(delay, s.delay)
        if delay > 0:
            time.sleep(delay)
        if self._match_crash(inv, attempt, "before"):
            raise InjectedCrashError(
                f"{inv.name}: injected crash before body (attempt {attempt})")

    def match_worker_kill(self, inv: "Invocation",
                          attempt: int) -> "WorkerKillFault | None":
        """Consulted by process-backed invokers as they dispatch ``inv`` to
        a worker: a returned fault means SIGKILL that worker mid-invocation
        (its ``when`` picks the kill point). Match-count semantics are
        identical to ``CrashFault`` so a plan replays deterministically."""
        kills = getattr(self.plan, "worker_kills", [])
        with self._lock:
            for i, k in enumerate(kills):
                if k.stage != inv.stage:
                    continue
                if k.index is not None and k.index != inv.index:
                    continue
                if k.attempt != attempt or self._kill_fired[i] >= k.times:
                    continue
                self._kill_fired[i] += 1
                self.injected.append(("worker-kill", inv.name))
                return k
        return None

    def after_body(self, inv: "Invocation", attempt: int) -> None:
        """Runs after the body wrote its outputs, before the claim commits:
        crash-after-write — the retry overwrites under the writer label."""
        if self._match_crash(inv, attempt, "after"):
            raise InjectedCrashError(
                f"{inv.name}: injected crash after write (attempt {attempt})")

    # -- store hook ----------------------------------------------------------

    def on_get(self, app: str, stage: str, partition: int,
               node: int) -> None:
        """Called at the top of every ``ShuffleStore.get`` (store lock held,
        re-entrant): the k-th read of a stage may lose it right now."""
        with self._lock:
            count = self._reads.get((app, stage), 0) + 1
            self._reads[(app, stage)] = count
            fire = []
            for i, loss in enumerate(self.plan.losses):
                if loss.stage != stage or self._loss_fired[i]:
                    continue
                if count != loss.on_read:
                    continue
                self._loss_fired[i] = True
                self.injected.append(("stage-loss", f"{app}/{stage}"))
                fire.append(loss)
        for loss in fire:
            self._store.lose_stage(app, stage, partitions=loss.partitions)


class SpeculationPolicy:
    """Straggler mitigation as a failure-feedback decision node.

    A parallel invoker exposes per-invocation elapsed times to this policy
    while a stage is in flight; the wrapped ``speculation_node`` decides —
    from the observed completion distribution — whether to launch a backup
    invocation on another node. First completion wins: both copies write
    under the same writer label, so the loser's (identical) output
    overwrites harmlessly. ``interval`` is the invoker's polling period,
    ``multiple`` the p50-multiple past which an invocation counts as a
    straggler, ``min_done`` how many sibling completions are needed before
    a p50 is trusted.
    """

    def __init__(self, multiple: float = 2.0, min_done: int = 2,
                 floor: float = 0.05, interval: float = 0.02):
        self.node = speculation_node(multiple=multiple, min_done=min_done,
                                     floor=floor)
        self.interval = interval

    def backup_node(self, inv: "Invocation", elapsed: float,
                    done_seconds: Sequence[float],
                    status: NodeStatus) -> int | None:
        """The node to launch a backup on, or None to keep waiting."""
        from repro.obs.audit import bound_app
        ctx = DecisionContext(node_status=status, profile={
            "speculation.stage": inv.stage,
            "speculation.node": inv.node,
            "speculation.elapsed_s": elapsed,
            "speculation.done_s": tuple(done_seconds),
        })
        with bound_app(inv.app):
            decision = self.node.decide(ctx)
        if decision.func != "speculate" or decision.scale < 1:
            return None
        placed = decision.schedule.place(1)
        return placed[0] if placed else None
