"""The partitioned analytics function library.

Each entry is a stateless serverless function: it reads its inputs from the
shuffle store, computes with ``repro.analytics.operators`` on the JAX data
plane, and writes its outputs back — no state survives the invocation, so
the invoker may retry it after preemption. Registered names are what the
executor puts into ``Invocation.func``; the decision tuple's ``func`` field
("hash_join" / "merge_join") selects between the two join variants exactly
as in the paper's Fig. 6.

Stage-name and partition parameters arrive via ``ctx.params``:

    scan_filter      src, dst, partition [, filter_col, filter_gt]
    shuffle_write    src, dst, partition, num_buckets
    broadcast_write  src, dst, partition
    hash_join_partition / merge_join_partition
                     fact_stage, fact_partitions, dim_stage,
                     dim_partitions | "all", dst, partition, num_groups
    partial_aggregate  src, dst, partition, num_groups
    final_aggregate    src, dst, num_groups
"""

from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp
import numpy as np

from repro.analytics import operators as ops
from repro.analytics.table import Table

FUNCTIONS: Dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        FUNCTIONS[name] = fn
        return fn
    return deco


def _empty_joined() -> Table:
    return Table({"group": jnp.zeros((0,), jnp.int32),
                  "weight": jnp.zeros((0,), jnp.float32)})


@register("scan_filter")
def scan_filter(ctx) -> None:
    """Partition scan: read a base partition, drop filtered rows, rewrite.

    Unlike the in-process JAX path (static shapes + validity column), the
    runtime genuinely compacts: dropped rows never hit the shuffle store.
    """
    p = ctx.params
    t = ctx.get(p["src"], p["partition"])
    if t is None:
        return
    col = p.get("filter_col")
    if col is not None and t.num_rows:
        t = t.mask(t[col] > p.get("filter_gt", 0.0))
    ctx.put(p["dst"], p["partition"], t)


@register("shuffle_write")
def shuffle_write(ctx) -> None:
    """Hash-partition one input partition into the join's bucket space.

    Writes bucket ``r`` of stage ``dst`` for every non-empty bucket; the
    store appends this writer's slice to whatever other map instances wrote
    for the same bucket — that append *is* the all-to-all shuffle.
    """
    p = ctx.params
    t = ctx.get(p["src"], p["partition"])
    if t is None or t.num_rows == 0:
        return
    nb = int(p["num_buckets"])
    pids = np.asarray(ops.partition_ids(t["key"], nb))
    for r in range(nb):
        idx = np.nonzero(pids == r)[0]
        if idx.size:
            ctx.put(p["dst"], r, t.take(jnp.asarray(idx)))


@register("broadcast_write")
def broadcast_write(ctx) -> None:
    """Publish a (small) build-side partition for broadcast consumption.

    Every join instance later reads *all* partitions of ``dst``; the store
    charges each remote read to this partition's home node, reproducing the
    sender-serialization broadcast cost of Fig. 4(c).
    """
    p = ctx.params
    t = ctx.get(p["src"], p["partition"])
    if t is not None:
        ctx.put(p["dst"], p["partition"], t)


def _read_side(ctx, stage: str, parts):
    if parts == "all":
        return ctx.get_all(stage)
    out = None
    for part in parts:
        t = ctx.get(stage, part)
        if t is None or t.num_rows == 0:
            continue
        out = t if out is None else out.concat(t)
    return out


def _join_partition(ctx, method: str) -> None:
    p = ctx.params
    fact = _read_side(ctx, p["fact_stage"], p["fact_partitions"])
    dim = _read_side(ctx, p["dim_stage"], p["dim_partitions"])
    if fact is None or fact.num_rows == 0 or dim is None or dim.num_rows == 0:
        ctx.put(p["dst"], p["partition"], _empty_joined())
        return
    joined = ops.join(fact, dim, method=method)
    found = joined["found"]
    weight = jnp.where(found, joined["v0"] * joined["v1"], 0.0)
    group = joined["cat"].astype(jnp.int32) % int(p["num_groups"])
    ctx.put(p["dst"], p["partition"],
            Table({"group": group, "weight": weight}))


@register("hash_join_partition")
def hash_join_partition(ctx) -> None:
    """Broadcast hash join: build over the dim side, probe the fact side."""
    _join_partition(ctx, "hash")


@register("merge_join_partition")
def merge_join_partition(ctx) -> None:
    """Shuffled sort-merge join over one co-partitioned bucket."""
    _join_partition(ctx, "merge")


@register("partial_aggregate")
def partial_aggregate(ctx) -> None:
    p = ctx.params
    g = int(p["num_groups"])
    t = ctx.get(p["src"], p["partition"])
    if t is None or t.num_rows == 0:
        vec = jnp.zeros((g,), jnp.float32)
    else:
        vec = ops.groupby_sum(t["group"], t["weight"], g)
    ctx.put(p["dst"], p["partition"], Table({"sum": vec}))


@register("final_aggregate")
def final_aggregate(ctx) -> None:
    p = ctx.params
    total = np.zeros(int(p["num_groups"]), dtype=np.float64)
    for part in ctx.partitions(p["src"]):
        t = ctx.get(p["src"], part)
        if t is not None and t.num_rows:
            total += np.asarray(t["sum"], dtype=np.float64)
    ctx.put(p["dst"], 0, Table({"sum": jnp.asarray(total, jnp.float32)}))
