"""The partitioned analytics function library.

Each entry is a stateless serverless function: it reads its inputs from the
shuffle store, computes with ``repro.analytics.operators`` (which routes
through the kernel dispatch layer ``repro.kernels.ops``) and writes its
outputs back — no state survives the invocation, so the invoker may retry
it after preemption. Registered names are what the executor puts into
``Invocation.func``; the decision tuple's ``func`` field ("hash_join" /
"merge_join") selects between the two join variants exactly as in the
paper's Fig. 6.

Hot functions are **single-pass and loop-free**: ``shuffle_write`` computes
one grouping permutation on the device and publishes every bucket as a
``TableSlice`` view over the permuted buffer through ``ctx.put_many`` (one
store round trip for all buckets); multi-partition reads concatenate with
one multi-way ``Table.concat_all`` per column; the final aggregate folds
all partials in one vectorized reduction. ``shuffle_write_loop`` keeps the
legacy per-bucket ``nonzero``/``take``/``put`` loop as the benchmark
baseline (``benchmarks/bench_dataplane.py``).

Stage-name and partition parameters arrive via ``ctx.params``:

    scan_filter      src, dst, partition [, filter_col, filter_gt]
    shuffle_write    src, dst, partition, num_buckets
    broadcast_write  src, dst, partition
    hash_join_partition / merge_join_partition
                     fact_stage, fact_partitions, dim_stage,
                     dim_partitions | "all", dst, partition, num_groups
    salted_join_partition
                     join params + fact_writers (one writer shard of a
                     heavy bucket) [, drop_keys]
    hot_filter_write src, src_partitions, keys, dst
    hot_join_partition
                     join params + keep_keys (heavy-hitter probe split)
    partial_aggregate  src, dst, partition, num_groups
    final_aggregate    src, dst, num_groups
    cpu_spin         dst, partition [, iters]
"""

from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp
import numpy as np

from repro.analytics import operators as ops
from repro.analytics.table import Table
from repro.kernels import ops as kops

FUNCTIONS: Dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        FUNCTIONS[name] = fn
        return fn
    return deco


@register("cpu_spin")
def cpu_spin(ctx) -> None:
    """GIL-bound compute stage for the worker-plane benchmarks: a pure
    Python accumulation loop that holds the interpreter lock for its whole
    duration, so thread-backed invokers serialize it while process-backed
    workers run it truly in parallel (``benchmarks/bench_elastic.py``).
    The result is deterministic in ``(partition, iters)``, so fan-out
    outputs stay verifiable across backends."""
    p = ctx.params
    iters = int(p.get("iters", 100_000))
    x = int(p["partition"]) + 1
    acc = 0
    for i in range(iters):
        acc = (acc + x * i) % 1_000_003
    ctx.put(p["dst"], p["partition"],
            Table({"acc": jnp.asarray([acc], jnp.int32)}))


def _empty_joined() -> Table:
    return Table({"group": jnp.zeros((0,), jnp.int32),
                  "weight": jnp.zeros((0,), jnp.float32)})


@register("scan_filter")
def scan_filter(ctx) -> None:
    """Partition scan: read a base partition, drop filtered rows, rewrite.

    Unlike the in-process JAX path (static shapes + validity column), the
    runtime genuinely compacts: dropped rows never hit the shuffle store.
    """
    p = ctx.params
    t = ctx.get(p["src"], p["partition"])
    if t is None:
        return
    col = p.get("filter_col")
    if col is not None and t.num_rows:
        t = t.mask(t[col] > p.get("filter_gt", 0.0))
    ctx.put(p["dst"], p["partition"], t)


@register("shuffle_write")
def shuffle_write(ctx) -> None:
    """Hash-partition one input partition into the join's bucket space —
    the single-pass columnar path.

    One kernel dispatch (``ops.grouping_indices``: Pallas histogram +
    scatter on TPU, jitted stable sort elsewhere, padded to a power-of-two
    shape class so heterogeneous partitions share compilations) yields the
    grouping permutation and every bucket's offset range; one gather per
    column permutes the partition; each non-empty bucket is then a
    zero-copy ``TableSlice`` of the permuted buffer, published together
    via ``ctx.put_many``. The store appends this writer's slices to
    whatever other map instances wrote for the same buckets — that append
    *is* the all-to-all shuffle.
    """
    p = ctx.params
    t = ctx.get(p["src"], p["partition"])
    if t is None or t.num_rows == 0:
        return
    nb = int(p["num_buckets"])
    pids = ops.partition_ids(t["key"], nb)
    order, offsets = ops.grouping_indices(pids, nb)
    # land the permuted buffer on the host ONCE (one transfer per column):
    # every bucket slice is then a zero-copy numpy view, and readers
    # concatenate views with a memcpy — device programs are reserved for
    # the kernels, not for per-(shape, range) slice/concat plumbing
    permuted = Table({k: np.asarray(v) for k, v in t.take(order).columns.items()})
    bounds = np.asarray(offsets)
    # skew detection rides the grouping we already paid for: the offset
    # diffs ARE the per-bucket row histogram, and the heavy-hitter sketch
    # is one fixed-shape hash-slot histogram (Pallas on TPU) plus an exact
    # host count of the candidate slots. Lands on the invocation record via
    # ctx.stats -> profile_feedback, where the planner's skew node reads
    # the observed (not estimated) distribution.
    rows_hist = np.diff(bounds)
    row_nb = sum(int(np.prod(v.shape[1:])) * v.dtype.itemsize
                 for v in permuted.columns.values())
    ctx.stats["partition_rows"] = tuple(int(r) for r in rows_hist)
    ctx.stats["partition_bytes"] = tuple(int(r) * row_nb for r in rows_hist)
    ctx.stats["hot_keys"] = kops.heavy_hitter_sketch(t["key"])
    out = {r: permuted.slice(bounds[r], bounds[r + 1])
           for r in range(nb) if bounds[r + 1] > bounds[r]}
    ctx.put_many(p["dst"], out)


@register("shuffle_write_loop")
def shuffle_write_loop(ctx) -> None:
    """Legacy per-bucket shuffle: one host round trip (``np.nonzero``), one
    gather and one store ``put`` *per bucket*. Kept as the benchmark
    baseline the batched columnar path is measured against; not planned by
    default."""
    p = ctx.params
    t = ctx.get(p["src"], p["partition"])
    if t is None or t.num_rows == 0:
        return
    nb = int(p["num_buckets"])
    pids = np.asarray(ops.partition_ids(t["key"], nb))
    for r in range(nb):
        idx = np.nonzero(pids == r)[0]
        if idx.size:
            ctx.put(p["dst"], r, t.take(jnp.asarray(idx)))


@register("broadcast_write")
def broadcast_write(ctx) -> None:
    """Publish a (small) build-side partition for broadcast consumption.

    Every join instance later reads *all* partitions of ``dst``; the store
    charges each remote read to this partition's home node, reproducing the
    sender-serialization broadcast cost of Fig. 4(c).
    """
    p = ctx.params
    t = ctx.get(p["src"], p["partition"])
    if t is not None:
        ctx.put(p["dst"], p["partition"], t)


PREFETCH_WINDOW = 2     # in-flight fetches per side (double buffering)


def _read_side(ctx, stage: str, parts, window: int = PREFETCH_WINDOW,
               writers=None):
    """Concatenate a join side's partitions in ONE multi-way concat per
    column (``Table.concat_all``) instead of the O(P²) pairwise chain.

    Under an active pipeline plan the reads are double-buffered: the first
    ``window`` partitions are prefetched up front and partition ``i+window``
    starts fetching before partition ``i`` is consumed — per-partition read
    *order* (and therefore the store's fault-hook match counts per stage)
    is exactly the barrier path's. A writer-restricted read (``writers``)
    skips the prefetch cache entirely: prefetched handles hold full
    partitions, not this invocation's shard.
    """
    if parts == "all":
        return ctx.get_all(stage)
    parts = list(parts)
    # a single-partition side has nothing to double-buffer: a prefetch
    # thread would only add a spawn + GIL handoff to a read we immediately
    # block on
    pipelined = ctx.plan in ("pipelined", "fused") and len(parts) > 1 \
        and writers is None
    if pipelined:
        for part in parts[:window]:
            ctx.prefetch(stage, part)
    got = []
    for i, part in enumerate(parts):
        if pipelined and i + window < len(parts):
            ctx.prefetch(stage, parts[i + window])
        t = ctx.get(stage, part, writers=writers)
        if t is not None and t.num_rows:
            got.append(t)
    return Table.concat_all(got) if got else None


def _mitigation_view(fact, p):
    """Apply the skew plan's fact-side restrictions before joining.

    ``row_lo``/``row_hi`` select one salted sub-range of a heavy bucket —
    the range indexes the deterministic writer-ordered concatenation a
    bucket read produces, so the planner's histogram-derived splits land on
    exactly the rows it counted. ``drop_keys`` removes the heavy-hitter
    keys a broadcast split routes elsewhere; ``keep_keys`` is the hot-probe
    side of the same split. Absent params leave the fact side untouched,
    so the unmitigated plan's execution is byte-identical to before."""
    if fact is None or fact.num_rows == 0:
        return fact
    lo = p.get("row_lo")
    if lo is not None:
        lo, hi = int(lo), min(int(p["row_hi"]), fact.num_rows)
        if hi <= lo:
            return None
        fact = fact.slice(lo, hi).materialize()
    drop = p.get("drop_keys")
    if drop:
        keep = ~np.isin(np.asarray(fact["key"]), list(drop))
        fact = fact.mask(jnp.asarray(keep))
    keep_keys = p.get("keep_keys")
    if keep_keys:
        keep = np.isin(np.asarray(fact["key"]), list(keep_keys))
        fact = fact.mask(jnp.asarray(keep))
    return fact


def _join_partition(ctx, method: str) -> None:
    p = ctx.params
    plan = ctx.plan
    if plan in ("pipelined", "fused"):
        # start the (small) build side streaming in while the fact side is
        # read — the cross-side half of the double buffering. A one-bucket
        # build side (co-partitioned merge join) is read directly: there is
        # no second fetch to overlap it with.
        dim_parts = list(ctx.partitions(p["dim_stage"])
                         if p["dim_partitions"] == "all"
                         else p["dim_partitions"])
        if len(dim_parts) > 1:
            for part in dim_parts:
                ctx.prefetch(p["dim_stage"], part)
    fact = _read_side(ctx, p["fact_stage"], p["fact_partitions"],
                      writers=p.get("fact_writers"))
    dim = _read_side(ctx, p["dim_stage"], p["dim_partitions"])
    fact = _mitigation_view(fact, p)
    if fact is None or fact.num_rows == 0 or dim is None or dim.num_rows == 0:
        ctx.put(p["dst"], p["partition"], _empty_joined())
        return
    if plan == "fused":
        # one dispatch replaces join -> where(found) -> mod: same output
        # encoding (non-matching rows carry group 0 / weight 0). Publish as
        # device arrays like the unfused path does, so the aggregation
        # stage reads the same array kind under either plan.
        group, weight = kops.fused_probe_groups(
            fact["key"], fact["v0"], fact["v1"], dim["key"], dim["cat"],
            int(p["num_groups"]))
        ctx.put(p["dst"], p["partition"],
                Table({"group": jnp.asarray(group),
                       "weight": jnp.asarray(weight)}))
        return
    joined = ops.join(fact, dim, method=method)
    found = joined["found"]
    weight = jnp.where(found, joined["v0"] * joined["v1"], 0.0)
    group = joined["cat"].astype(jnp.int32) % int(p["num_groups"])
    ctx.put(p["dst"], p["partition"],
            Table({"group": group, "weight": weight}))


@register("hash_join_partition")
def hash_join_partition(ctx) -> None:
    """Broadcast hash join: build over the dim side, probe the fact side."""
    _join_partition(ctx, "hash")


@register("merge_join_partition")
def merge_join_partition(ctx) -> None:
    """Shuffled sort-merge join over one co-partitioned bucket."""
    _join_partition(ctx, "merge")


@register("salted_join_partition")
def salted_join_partition(ctx) -> None:
    """One writer shard of a heavy shuffled bucket: sort-merge join of the
    ``fact_writers`` slices of the bucket against the bucket's dim side
    (replicated across the bucket's sub-joins), writing straight into an
    extra ``joined`` partition the aggregation folds like any other — no
    single invocation ever reads (or joins) the whole heavy bucket."""
    _join_partition(ctx, "merge")


@register("hot_filter_write")
def hot_filter_write(ctx) -> None:
    """Broadcast split, build side: collect the heavy-hitter keys' dim rows
    from the scan output and publish them as one replicated build partition
    for the hot probes. Writes nothing when no dim row matches (the hot
    joins then emit empty output — same result as an unmatched probe)."""
    p = ctx.params
    keys = [int(k) for k in p["keys"]]
    got = []
    for part in p["src_partitions"]:
        t = ctx.get(p["src"], part)
        if t is None or t.num_rows == 0:
            continue
        keep = np.isin(np.asarray(t["key"]), keys)
        if keep.any():
            got.append(t.mask(jnp.asarray(keep)))
    if got:
        ctx.put(p["dst"], 0, Table.concat_all(got))


@register("hot_join_partition")
def hot_join_partition(ctx) -> None:
    """Broadcast split, probe side: hash-join one fact scan partition's
    heavy-hitter rows (``keep_keys``) against the replicated hot build
    side — per-writer parallelism replacing the one straggler bucket."""
    _join_partition(ctx, "hash")


@register("partial_aggregate")
def partial_aggregate(ctx) -> None:
    """Per-partition grouped partial sums — one segment-sum dispatch."""
    p = ctx.params
    g = int(p["num_groups"])
    t = ctx.get(p["src"], p["partition"])
    if t is None or t.num_rows == 0:
        vec = jnp.zeros((g,), jnp.float32)
    else:
        vec = ops.groupby_sum(t["group"], t["weight"], g)
    ctx.put(p["dst"], p["partition"], Table({"sum": vec}))


@register("final_aggregate")
def final_aggregate(ctx) -> None:
    """Fold every partial vector in one pass (float64 accumulation for a
    deterministic, order-independent total)."""
    p = ctx.params
    g = int(p["num_groups"])
    vecs = [t["sum"] for t in (ctx.get(p["src"], part)
                               for part in ctx.partitions(p["src"]))
            if t is not None and t.num_rows]
    total = (np.stack([np.asarray(v, dtype=np.float64) for v in vecs])
             .sum(axis=0) if vecs else np.zeros(g, dtype=np.float64))
    ctx.put(p["dst"], 0, Table({"sum": jnp.asarray(total, jnp.float32)}))
