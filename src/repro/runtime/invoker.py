"""Pluggable function-invocation backends.

An ``Invocation`` names a registered function, the node it should run on and
its priority. The invoker is the runtime half of the paper's substrate: for
every invocation it claims one function slot through the real
``GlobalController`` (Omega-style optimistic commit), runs the function in a
stateless ``FnContext`` over the shuffle store, and releases the slot. If a
higher-priority application preempted the claim while the function ran, the
result is discarded and the invocation retried — safe precisely because
functions are stateless and every write lands in the store under the
invocation's own writer label (retry overwrites, never duplicates).

Batched map invocations: invocations carrying ``batchable=True`` (the
planner sets it on map-shaped stages — scans, shuffle writes, broadcast
writes, partial aggregates) that share a (stage, function, node) are
**coalesced** into one batched call: one slot claim serves the whole group,
whose members run back-to-back with their own ``FnContext``, metrics record
and fault-injection hooks — so a 32-partition scan is a handful of claims
and jitted calls, not 32 interpreter round trips, while the control plane
(decision sequences, per-stage record counts, lineage, fault match counts)
sees exactly what unbatched execution would produce. A batch that crashes
or loses its claim demotes the unfinished members to individual execution
with the full per-invocation retry machinery. ``batching=False`` disables
coalescing entirely (the differential baseline).

Two backends:

* ``InlineInvoker``     — sequential, deterministic (tests, oracles).
* ``ThreadPoolInvoker`` — real parallelism across function slots (batches
  from one stage run concurrently, one worker per group).
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Mapping, Sequence

from repro.core.controllers import GlobalController
from repro.obs.tracer import get_tracer
from repro.runtime.faults import InjectedCrashError
from repro.runtime.metrics import InvocationRecord, MetricsSink
from repro.runtime.store import PrefetchHandle, ShuffleStore


def _padding_snapshot() -> tuple[int, int]:
    from repro.kernels.ops import padding_counters
    return padding_counters()


class SlotGate:
    """Admission control over slot claims, consulted before the controller.

    A scheduler policy (e.g. weighted fair share, ``repro.runtime.scheduler``)
    installs a gate on the shared invoker; ``acquire`` blocks until the
    invocation's application may take one more function slot, ``release``
    returns the token. The default gate admits everything. A batched call
    holds exactly one token — it occupies one function slot.
    """

    def acquire(self, inv: "Invocation") -> None:  # pragma: no cover
        return None

    def release(self, inv: "Invocation") -> None:  # pragma: no cover
        return None


@dataclass(frozen=True)
class Invocation:
    """One stateless function instance of a stage.

    ``batchable`` marks map-shaped invocations (per-partition, no cross-
    partition reads) the invoker may coalesce with same-stage same-function
    same-node siblings into one slot claim; correctness never depends on it
    — it is purely a dispatch-overhead knob.
    """

    name: str                      # e.g. "query/join/3"
    app: str
    stage: str
    index: int
    func: str                      # key into the function registry
    node: int
    priority: int = 0
    params: Mapping[str, Any] = field(default_factory=dict)
    batchable: bool = False
    # producer invocation names whose commits make THIS invocation's inputs
    # complete — partition-granularity readiness for the pipelined executor
    # (empty: only whole-stage dependencies gate it, the barrier semantics)
    needs: tuple = ()


class FnContext:
    """What a function instance sees: namespaced store access + its params.

    All store traffic flows through here so the invoker can attribute
    bytes-in/out (and per-source read volumes) to the invocation —
    and so the time an invocation spends against the store
    (``store_seconds``) is split from its on-device compute in the
    invocation record (the compute-vs-transfer breakdown decision nodes
    read out of ``profile_feedback``).
    """

    def __init__(self, store: ShuffleStore, inv: Invocation,
                 honor_plan: bool = False):
        self._store = store
        self.app = inv.app
        self.node = inv.node
        self.index = inv.index
        self.params = dict(inv.params)
        self.writer = inv.name
        self.honor_plan = honor_plan
        self.bytes_in = 0
        self.bytes_out = 0
        self.store_seconds = 0.0
        self.rows_actual = 0
        self.rows_padded = 0
        # free-form per-invocation observations a function body emits for
        # profile_feedback (e.g. shuffle_write's per-bucket histogram and
        # heavy-hitter sketch); values must be picklable — the process
        # backend marshals them home with the worker metrics
        self.stats: dict[str, Any] = {}
        self.reads_by_node: dict[int, int] = {}
        self.writes: list[tuple[str, int]] = []   # lineage: (stage, part)
        self._prefetched: dict[tuple[str, int], PrefetchHandle] = {}
        self._pf_lock = threading.Lock()

    @property
    def plan(self) -> str:
        """The pipeline decision's mode for this invocation ("barrier" /
        "pipelined" / "fused") — reads as "barrier" unless the executor was
        launched with pipelining enabled, so the data-plane fast paths stay
        inert when the knob is off (the invisibility baseline)."""
        if not self.honor_plan:
            return "barrier"
        return str(self.params.get("plan", "barrier"))

    def prefetch(self, stage: str, partition: int) -> None:
        """Start fetching ``(stage, partition)`` on a background thread.

        A later ``get`` of the same key joins the handle and charges ONLY
        the blocked remainder to ``store_seconds`` — overlap between the
        fetch and the caller's compute is the pipelining win. Read-source
        and byte accounting happen exactly once (in the worker, merged at
        join time), so store traffic totals are identical to an unprefetched
        read; the store-side fault hook (``on_get``) fires from the worker
        with the same per-(app, stage) ordering a direct read would produce.
        Duplicate prefetches of a live key are no-ops.
        """
        key = (stage, int(partition))
        with self._pf_lock:
            if key in self._prefetched:
                return
            tr = get_tracer()
            parent = tr.current()     # the invocation span of the issuer
            store, app, node = self._store, self.app, self.node

            def fetch():
                # the fetch runs on a background thread whose span stack is
                # empty: adopt the issuing invocation's span so the store's
                # own get spans parent to it instead of landing orphaned
                with tr.adopt(parent):
                    sources = store.read_sources(app, stage, key[1], node)
                    t0 = time.perf_counter()
                    try:
                        t = store.get(app, stage, key[1], node)
                    finally:
                        tr.record(f"prefetch/{stage}/{key[1]}", "store", t0,
                                  trace=app, node=node, parent=parent,
                                  kind="prefetch")
                    return t, sources

            self._prefetched[key] = PrefetchHandle(fetch)

    def get(self, stage: str, partition: int, writers=None):
        # a writer-restricted read never consults the prefetch cache: a
        # prefetched handle holds the FULL partition, not the caller's shard
        with self._pf_lock:
            handle = None if writers is not None else \
                self._prefetched.pop((stage, int(partition)), None)
        if handle is not None:
            t0 = time.perf_counter()
            try:
                t, sources = handle.join()
            finally:
                # only the blocked tail counts: the overlapped fetch time
                # is exactly what pipelining saved
                self.store_seconds += time.perf_counter() - t0
            for src, b in sources.items():
                self.reads_by_node[src] = self.reads_by_node.get(src, 0) + b
            if t is not None:
                self.bytes_in += int(t.nbytes)
            return t
        for src, b in self._store.read_sources(
                self.app, stage, partition, self.node,
                writers=writers).items():
            self.reads_by_node[src] = self.reads_by_node.get(src, 0) + b
        t0 = time.perf_counter()
        try:
            t = self._store.get(self.app, stage, partition, self.node,
                                writers=writers)
        finally:
            self.store_seconds += time.perf_counter() - t0
        if t is not None:
            self.bytes_in += int(t.nbytes)
        return t

    def get_all(self, stage: str):
        from repro.analytics.table import Table
        got = [t for t in (self.get(stage, p)
                           for p in self.partitions(stage))
               if t is not None and t.num_rows]
        return Table.concat_all(got) if got else None

    @staticmethod
    def _force(table) -> None:
        # Externalizing state means materializing it: block on the columns
        # (for a TableSlice, its shared *parent* buffer — no copy) so each
        # invocation pays for its own compute before the blob is published
        # (otherwise jax's async dispatch defers whole-query work into
        # whichever downstream reader first forces a value, scrambling
        # per-stage metrics and stage overlap alike). This wait is charged
        # to compute, not store time — it is the invocation's own pending
        # device work draining.
        try:
            import jax
            cols = getattr(table, "parent_columns", None)
            if cols is None:
                cols = getattr(table, "columns", None)
            jax.block_until_ready(cols)
        except ImportError:  # pragma: no cover - jax is a hard dep elsewhere
            pass

    def put(self, stage: str, partition: int, table) -> None:
        self._force(table)
        t0 = time.perf_counter()
        try:
            self.bytes_out += self._store.put(
                self.app, stage, partition, table, self.node,
                writer=self.writer)
        finally:
            self.store_seconds += time.perf_counter() - t0
        self.writes.append((stage, partition))

    def put_many(self, stage: str, tables: Mapping[int, Any]) -> None:
        """Publish many partitions in one store round trip (the columnar
        shuffle path: every bucket a slice of one parent buffer)."""
        if not tables:
            return
        for table in tables.values():
            self._force(table)
        t0 = time.perf_counter()
        try:
            self.bytes_out += self._store.put_many(
                self.app, stage, tables, self.node, writer=self.writer)
        finally:
            self.store_seconds += time.perf_counter() - t0
        self.writes.extend((stage, int(p)) for p in sorted(tables))

    def partitions(self, stage: str) -> list[int]:
        return self._store.partitions(self.app, stage)


class InvocationError(RuntimeError):
    pass


class Invoker:
    """Shared claim/execute/release machinery; subclasses pick concurrency.

    ``intercept`` is a fault-injection hook (tests, chaos drills): it runs
    after the slot claim commits and before the function body, i.e. while the
    claim is live and preemptible.

    ``parallel`` advertises whether ``run_stage`` may be driven for several
    stages concurrently — the dependency-driven executor overlaps
    independent stages only on parallel backends.

    A failed claim blocks on the controller's release event (bounded per
    attempt by ``starve_wait``, default ``RELEASE_WAIT``) instead of busy
    spinning, so a starved invocation wakes the moment a slot frees and
    ``max_attempts`` bounds only genuinely stuck claims. ``gate`` is an
    optional ``SlotGate`` a scheduler installs to ration slots across
    applications; the gate token is held exactly while the claim is.

    ``batching`` enables coalescing of ``batchable`` invocations into
    per-(stage, function, node) groups of at most ``max_batch`` members;
    every member keeps its own metrics record and injector hook calls, so
    batching is invisible to the control plane.
    """

    parallel = False
    RELEASE_WAIT = 0.1      # max seconds blocked per attempt on the event

    def __init__(self, gc: GlobalController, store: ShuffleStore,
                 metrics: MetricsSink | None = None, max_attempts: int = 5,
                 starve_wait: float = 0.0,
                 intercept: Callable[[Invocation, int], None] | None = None,
                 gate: SlotGate | None = None, injector=None,
                 batching: bool = True, max_batch: int = 16):
        self.gc = gc
        self.store = store
        self.metrics = metrics or MetricsSink()
        self.max_attempts = max_attempts
        self.starve_wait = starve_wait
        self.intercept = intercept
        self.gate = gate
        self.injector = injector
        self.batching = batching
        self.max_batch = max_batch
        # set by the executor for pipelined runs: function bodies then honor
        # the planner's per-invocation "plan" parameter (prefetch / fused
        # kernel); off by default so direct invoker use stays barrier-exact
        self.honor_plan = False
        self.registry: Mapping[str, Callable[[FnContext], Any]] | None = None

    def _resolve(self, name: str) -> Callable[[FnContext], Any]:
        if self.registry is None:
            from repro.runtime.functions import FUNCTIONS
            self.registry = FUNCTIONS
        try:
            return self.registry[name]
        except KeyError:
            raise InvocationError(f"unregistered function {name!r}") from None

    # -- grouping -------------------------------------------------------------

    def _groups(self, invocations: Sequence[Invocation],
                ) -> list[list[Invocation]]:
        """Coalesce batchable invocations sharing (stage, func, node, app,
        priority) into groups of at most ``max_batch``, preserving
        first-appearance order; everything else stays a singleton.

        A non-batchable invocation is a sequencing point: it CLOSES every
        open group, so a later same-key batchable invocation can never be
        pulled back across it (a group held open across arbitrarily many
        interleaved non-batchable invocations would let a late member
        execute at the group's first-appearance position, an unbounded
        submission-vs-execution reorder). Residual reordering — a batchable
        invocation coalescing backwards past *batchable* siblings of other
        keys — is bounded per group by ``max_batch`` members and only ever
        occurs among map-shaped instances of one ``run_stage`` call, which
        carry no mutual ordering semantics.
        """
        groups: list[list[Invocation]] = []
        open_group: dict[tuple, int] = {}
        for inv in invocations:
            if not (self.batching and inv.batchable):
                open_group.clear()
                groups.append([inv])
                continue
            key = (inv.stage, inv.func, inv.node, inv.app, inv.priority)
            at = open_group.get(key)
            if at is not None and len(groups[at]) < self.max_batch:
                groups[at].append(inv)
            else:
                open_group[key] = len(groups)
                groups.append([inv])
        return groups

    # -- function-body execution hook -----------------------------------------

    def _invoke_body(self, fn: Callable[[FnContext], Any], inv: Invocation,
                     attempt: int) -> FnContext:
        """Run one function body and return its populated ``FnContext`` —
        the single extension point a worker-plane backend overrides.

        The default executes ``fn`` in-process. ``ProcessPoolInvoker``
        (``repro.runtime.workers``) instead ships the invocation to a
        worker subprocess and replays the worker's buffered writes into the
        host store before returning, so crash-after-write retry semantics
        are preserved. Implementations raise ``InjectedCrashError``
        subclasses (e.g. ``WorkerKilledError``) to surface a dead worker as
        a crashed attempt with the standard retry machinery.
        """
        ctx = FnContext(self.store, inv, honor_plan=self.honor_plan)
        pad0 = _padding_snapshot()
        fn(ctx)
        pad1 = _padding_snapshot()
        ctx.rows_actual = pad1[0] - pad0[0]
        ctx.rows_padded = pad1[1] - pad0[1]
        return ctx

    def _execute_group(self, group: list[Invocation],
                       deps: tuple[str, ...]) -> None:
        if len(group) == 1:
            self._execute_one(group[0], deps)
        else:
            self._execute_batch(group, deps)

    # -- single-invocation path -----------------------------------------------

    def _execute_one(self, inv: Invocation, deps: tuple[str, ...],
                     first_attempt: int = 0) -> None:
        """Claim → run → release for one invocation. ``first_attempt``
        offsets the attempt numbering for members demoted out of a crashed
        or preempted batch, so retry attempts (and the fault plan's
        ``attempt`` matching) continue where the batch left off — against
        the same total ``max_attempts`` budget, so an invocation that
        crashes on every attempt exhausts identically batched or not.

        The whole claim/execute/retry loop runs under one ``invoker`` span
        (parented to the executor's anchored stage span); each attempt adds
        a child attempt span, each blocked acquisition a child ``wait``
        span, and store traffic inside the function body nests via the
        thread-local span stack.
        """
        tr = get_tracer()
        if not tr.enabled:
            return self._execute_one_traced(inv, deps, first_attempt, tr,
                                            None)
        parent = tr.anchored(("stage", inv.app, inv.stage))
        kw = {} if parent is None else {"parent": parent}
        with tr.span(inv.name, "invoker", trace=inv.app, node=inv.node,
                     stage=inv.stage, func=inv.func, kind="invocation",
                     **kw) as sp:
            return self._execute_one_traced(inv, deps, first_attempt, tr, sp)

    def _execute_one_traced(self, inv: Invocation, deps: tuple[str, ...],
                            first_attempt: int, tr, sp) -> None:
        fn = self._resolve(inv.func)
        wait = self.starve_wait if self.starve_wait > 0 else self.RELEASE_WAIT
        for attempt in range(first_attempt, self.max_attempts):
            if self.gate is not None:
                tg = time.perf_counter()
                self.gate.acquire(inv)
                if sp is not None and time.perf_counter() - tg > 1e-4:
                    tr.record("gate_wait", "wait", tg, trace=inv.app,
                              node=inv.node, parent=sp, attempt=attempt)
            claim = None
            try:
                # Sample the node's release epoch *before* the attempt: if
                # the claim fails and a slot frees in between,
                # wait_for_release returns immediately — no lost wakeup.
                epoch = self.gc.release_epoch(inv.node)
                claim = self.gc.try_commit(inv.app, inv.priority, [inv.node],
                                           tag=inv.name)
            finally:
                # no claim taken (conflict, unknown node, a listener raising
                # mid-commit): the gate token must not leak
                if claim is None and self.gate is not None:
                    self.gate.release(inv)
            if claim is None:
                # every slot on the node is held by >=-priority work: block
                # until a claim on *this* node releases (unrelated nodes'
                # churn must not burn the retry budget), then retry
                tw = time.perf_counter()
                self.gc.wait_for_release(epoch, timeout=wait, node=inv.node)
                if sp is not None:
                    tr.record("slot_wait", "wait", tw, trace=inv.app,
                              node=inv.node, parent=sp, attempt=attempt)
                continue
            tr.count(f"slots/node{inv.node}", 1, delta=True)
            crashed = None
            # timed from claim commit: injected latency (stragglers) is part
            # of the invocation's observed duration, which is what the
            # speculation policy and the tail benchmarks reason about
            t0 = time.perf_counter()
            try:
                try:
                    if self.intercept is not None:
                        self.intercept(inv, attempt)
                    if self.injector is not None:
                        self.injector.before_body(inv, attempt)
                    ctx = self._invoke_body(fn, inv, attempt)
                    if self.injector is not None:
                        self.injector.after_body(inv, attempt)
                except InjectedCrashError as e:
                    # an injected function crash: release the slot, record
                    # the death, and retry on the next attempt (stateless
                    # functions + writer-label overwrite make a
                    # crash-after-write retry safe — it replaces, never
                    # duplicates)
                    crashed = e
                    self.gc.finish(claim)
                    tr.count(f"slots/node{inv.node}", -1, delta=True)
                except BaseException:
                    # any other failure while the claim is live — the
                    # registered function itself raising, the intercept
                    # hook, a StageLostError from the store — must release
                    # the slot, not leak it (a leaked slot deadlocks
                    # FairShareGate accounting)
                    self.gc.finish(claim)
                    tr.count(f"slots/node{inv.node}", -1, delta=True)
                    self.metrics.record(InvocationRecord(
                        inv.name, inv.app, inv.stage, inv.func, inv.node,
                        attempt, "error", t0, time.perf_counter(), deps=deps,
                        priority=inv.priority))
                    if sp is not None:
                        sp.attrs.update(status="error", attempts=attempt + 1)
                        tr.record(f"attempt/{attempt}", "invoker", t0,
                                  trace=inv.app, node=inv.node, parent=sp,
                                  kind="attempt", status="error")
                    raise
                if crashed is None:
                    t1 = time.perf_counter()
                    committed = self.gc.finish(claim)
                    tr.count(f"slots/node{inv.node}", -1, delta=True)
            finally:
                if self.gate is not None:
                    self.gate.release(inv)
            if crashed is not None:
                self.metrics.record(InvocationRecord(
                    inv.name, inv.app, inv.stage, inv.func, inv.node,
                    attempt, "crashed", t0, time.perf_counter(), deps=deps,
                    priority=inv.priority))
                if sp is not None:
                    tr.record(f"attempt/{attempt}", "invoker", t0,
                              trace=inv.app, node=inv.node, parent=sp,
                              kind="attempt", status="crashed")
                continue
            status = "ok" if committed else "preempted"
            self.metrics.record(InvocationRecord(
                inv.name, inv.app, inv.stage, inv.func, inv.node, attempt,
                status, t0, t1,
                bytes_in=ctx.bytes_in, bytes_out=ctx.bytes_out,
                store_seconds=ctx.store_seconds,
                reads_by_node=dict(ctx.reads_by_node), deps=deps,
                priority=inv.priority, writes=tuple(ctx.writes),
                rows_actual=ctx.rows_actual, rows_padded=ctx.rows_padded,
                stats=dict(ctx.stats)))
            if sp is not None:
                sp.attrs.update(status=status, attempts=attempt + 1)
                tr.record(f"attempt/{attempt}", "invoker", t0, end=t1,
                          trace=inv.app, node=inv.node, parent=sp,
                          kind="attempt", status=status)
            if committed:
                return
        self.metrics.record(InvocationRecord(
            inv.name, inv.app, inv.stage, inv.func, inv.node,
            self.max_attempts, "starved",
            time.perf_counter(), time.perf_counter(), deps=deps,
            priority=inv.priority))
        if sp is not None:
            sp.attrs.update(status="starved", attempts=self.max_attempts)
        raise InvocationError(
            f"{inv.name}: no slot committed after {self.max_attempts} "
            f"attempts (preempted/starved by higher-priority claims, or "
            f"repeatedly crashed)")

    # -- batched path ---------------------------------------------------------

    def _record_member(self, inv: Invocation, attempt: int, status: str,
                       t0: float, t1: float, deps: tuple[str, ...],
                       ctx: FnContext | None = None) -> None:
        self.metrics.record(InvocationRecord(
            inv.name, inv.app, inv.stage, inv.func, inv.node, attempt,
            status, t0, t1,
            bytes_in=ctx.bytes_in if ctx else 0,
            bytes_out=ctx.bytes_out if ctx else 0,
            store_seconds=ctx.store_seconds if ctx else 0.0,
            reads_by_node=dict(ctx.reads_by_node) if ctx else {},
            deps=deps, priority=inv.priority,
            writes=tuple(ctx.writes) if ctx else (),
            rows_actual=ctx.rows_actual if ctx else 0,
            rows_padded=ctx.rows_padded if ctx else 0,
            stats=dict(ctx.stats) if ctx else {}))

    def _execute_batch(self, invs: list[Invocation],
                       deps: tuple[str, ...]) -> None:
        """One slot claim serves the whole group; members run back-to-back
        under it, each with its own ``FnContext``, intercept/injector hook
        calls and metrics record (timed per member) — so match counts,
        lineage writes and per-partition metrics are exactly what
        invocation-at-a-time execution would produce.

        Failure demotion: a member crash releases the claim, records the
        crash, and re-executes the crashed member (next attempt number) and
        the never-started members (same attempt number) *individually* —
        the full per-invocation retry machinery. A claim preempted
        mid-batch discards and individually retries every member. Any
        other exception (a lost shuffle stage, the function raising)
        records completed members, releases the slot and propagates, which
        is what the executor's recovery loop expects.
        """
        tr = get_tracer()
        first = invs[0]
        if not tr.enabled:
            retry = self._execute_batch_traced(invs, deps, tr, None)
        else:
            parent = tr.anchored(("stage", first.app, first.stage))
            kw = {} if parent is None else {"parent": parent}
            with tr.span(f"batch/{first.stage}@{first.node}", "invoker",
                         trace=first.app, node=first.node, stage=first.stage,
                         func=first.func, kind="batch", members=len(invs),
                         **kw) as sp:
                if sp is not None:
                    sp.attrs["demoted"] = 0
                retry = self._execute_batch_traced(invs, deps, tr, sp)
                if sp is not None:
                    sp.attrs["demoted"] = len(retry)
        # demotion runs *outside* the batch span: the demoted members are no
        # longer under the batch claim and open their own invocation spans
        for inv, first_attempt in retry:
            self._execute_one(inv, deps, first_attempt=first_attempt)

    def _execute_batch_traced(self, invs: list[Invocation],
                              deps: tuple[str, ...], tr, sp,
                              ) -> list[tuple[Invocation, int]]:
        """The batch claim loop; returns the members to demote (empty when
        the whole batch committed)."""
        first = invs[0]
        # resolve before any claim: an unregistered function must raise
        # while no slot is held (all members share func by the grouping key)
        fn = self._resolve(first.func)
        wait = self.starve_wait if self.starve_wait > 0 else self.RELEASE_WAIT
        for attempt in range(self.max_attempts):
            if self.gate is not None:
                tg = time.perf_counter()
                self.gate.acquire(first)
                if sp is not None and time.perf_counter() - tg > 1e-4:
                    tr.record("gate_wait", "wait", tg, trace=first.app,
                              node=first.node, parent=sp, attempt=attempt)
            claim = None
            try:
                epoch = self.gc.release_epoch(first.node)
                claim = self.gc.try_commit(first.app, first.priority,
                                           [first.node],
                                           tag=f"{first.stage}*{len(invs)}")
            finally:
                if claim is None and self.gate is not None:
                    self.gate.release(first)
            if claim is None:
                tw = time.perf_counter()
                self.gc.wait_for_release(epoch, timeout=wait,
                                         node=first.node)
                if sp is not None:
                    tr.record("slot_wait", "wait", tw, trace=first.app,
                              node=first.node, parent=sp, attempt=attempt)
                continue
            tr.count(f"slots/node{first.node}", 1, delta=True)
            done: list[tuple[Invocation, FnContext, float, float]] = []
            member_spans: list = []
            crashed_at: int | None = None
            claim_alive = True
            try:
                for k, inv in enumerate(invs):
                    with tr.span(inv.name, "invoker", trace=inv.app,
                                 node=inv.node, parent=sp, stage=inv.stage,
                                 func=inv.func, kind="invocation",
                                 attempt=attempt) as msp:
                        t0 = time.perf_counter()
                        try:
                            if self.intercept is not None:
                                self.intercept(inv, attempt)
                            if self.injector is not None:
                                self.injector.before_body(inv, attempt)
                            ctx = self._invoke_body(fn, inv, attempt)
                            if self.injector is not None:
                                self.injector.after_body(inv, attempt)
                        except InjectedCrashError:
                            crashed_at = k
                            claim_alive = self.gc.finish(claim)
                            tr.count(f"slots/node{first.node}", -1,
                                     delta=True)
                            self._record_member(inv, attempt, "crashed", t0,
                                                time.perf_counter(), deps)
                            if msp is not None:
                                msp.attrs["status"] = "crashed"
                            break
                        except BaseException:
                            claim_alive = self.gc.finish(claim)
                            tr.count(f"slots/node{first.node}", -1,
                                     delta=True)
                            for v, vctx, v0, v1 in done:
                                self._record_member(
                                    v, attempt,
                                    "ok" if claim_alive else "preempted",
                                    v0, v1, deps, vctx)
                            for vsp in member_spans:
                                vsp.attrs["status"] = \
                                    "ok" if claim_alive else "preempted"
                            self._record_member(inv, attempt, "error", t0,
                                                time.perf_counter(), deps)
                            if msp is not None:
                                msp.attrs["status"] = "error"
                            raise
                        done.append((inv, ctx, t0, time.perf_counter()))
                        if msp is not None:
                            member_spans.append(msp)
                if crashed_at is None:
                    claim_alive = self.gc.finish(claim)
                    tr.count(f"slots/node{first.node}", -1, delta=True)
            finally:
                if self.gate is not None:
                    self.gate.release(first)
            status = "ok" if claim_alive else "preempted"
            for v, vctx, v0, v1 in done:
                self._record_member(v, attempt, status, v0, v1, deps, vctx)
            for vsp in member_spans:
                vsp.attrs["status"] = status
            if sp is not None:
                sp.attrs.update(status=status, attempts=attempt + 1)
            if crashed_at is None and claim_alive:
                return []
            # demote: crashed member + never-started members individually;
            # a dead claim additionally discards-and-retries the completed
            # members (their rewrites overwrite under the writer label)
            retry: list[tuple[Invocation, int]] = []
            if not claim_alive:
                retry += [(v, attempt + 1) for v, _, _, _ in done]
            if crashed_at is not None:
                retry.append((invs[crashed_at], attempt + 1))
                retry += [(iv, attempt) for iv in invs[crashed_at + 1:]]
            return retry
        # batch claim starved after the full max_attempts budget: surface
        # it exactly as the per-invocation path would — a fresh individual
        # retry round would double the budget (and the starvation-detection
        # latency) relative to unbatched execution
        now = time.perf_counter()
        for inv in invs:
            self.metrics.record(InvocationRecord(
                inv.name, inv.app, inv.stage, inv.func, inv.node,
                self.max_attempts, "starved", now, now, deps=deps,
                priority=inv.priority))
        raise InvocationError(
            f"{first.name} (+{len(invs) - 1} batched siblings): no slot "
            f"committed after {self.max_attempts} attempts "
            f"(preempted/starved by higher-priority claims)")

    def run_stage(self, invocations: Sequence[Invocation],
                  deps: tuple[str, ...] = ()) -> None:
        raise NotImplementedError


class InlineInvoker(Invoker):
    """Sequential execution in the caller's thread — deterministic."""

    def run_stage(self, invocations: Sequence[Invocation],
                  deps: tuple[str, ...] = ()) -> None:
        for group in self._groups(invocations):
            self._execute_group(group, deps)


class ThreadPoolInvoker(Invoker):
    """Real parallelism: one worker per in-flight batch or function instance.

    With a ``speculation`` policy installed (``SpeculationPolicy``,
    ``repro.runtime.faults``) the invoker polls in-flight invocations and
    feeds their elapsed times to the policy's failure-feedback decision
    node; stragglers get a backup launched on another node, first
    completion wins (both copies write under the same writer label, so the
    loser's identical output overwrites harmlessly), and ``run_stage``
    returns without waiting for the losers. ``drain()`` joins any such
    still-running losers — call it before asserting slot-leak invariants.
    Speculative stages run invocation-at-a-time (first-completion-wins
    needs per-member claims), so speculation and batching never mix within
    a stage.
    """

    parallel = True

    def __init__(self, gc: GlobalController, store: ShuffleStore,
                 metrics: MetricsSink | None = None, max_workers: int = 8,
                 max_attempts: int = 200, starve_wait: float = 0.0,
                 intercept: Callable[[Invocation, int], None] | None = None,
                 gate: SlotGate | None = None, injector=None,
                 speculation=None, batching: bool = True,
                 max_batch: int = 16):
        super().__init__(gc, store, metrics, max_attempts=max_attempts,
                         starve_wait=starve_wait, intercept=intercept,
                         gate=gate, injector=injector, batching=batching,
                         max_batch=max_batch)
        self.max_workers = max_workers
        self.speculation = speculation
        self.speculations: list[tuple[str, int, int, float]] = []
        self._pools: list[ThreadPoolExecutor] = []

    def run_stage(self, invocations: Sequence[Invocation],
                  deps: tuple[str, ...] = ()) -> None:
        if not invocations:
            return
        if self.speculation is not None and len(invocations) > 1:
            self._run_stage_speculative(list(invocations), deps)
            return
        groups = self._groups(invocations)
        with ThreadPoolExecutor(
                max_workers=min(self.max_workers, len(groups))) as pool:
            futures = [pool.submit(self._execute_group, group, deps)
                       for group in groups]
            for f in futures:
                f.result()    # propagate the first failure

    def _run_stage_speculative(self, invocations: list[Invocation],
                               deps: tuple[str, ...]) -> None:
        spec = self.speculation
        n = len(invocations)
        pool = ThreadPoolExecutor(
            max_workers=min(2 * self.max_workers, 2 * n))
        self._pools.append(pool)
        tr = get_tracer()
        stage_span = tr.anchored(
            ("stage", invocations[0].app, invocations[0].stage))

        def run_one(inv):
            # pool threads have empty span stacks and losers may outlive
            # the executor's stage anchor (drain() joins them after
            # run_stage returns): adopt the stage span captured at submit
            # time so invocation and store spans stay parented either way
            with tr.adopt(stage_span):
                self._execute_one(inv, deps)

        futs: dict = {}                       # future -> index
        copies = [1] * n                      # in-flight copies per index
        started = []
        for i, inv in enumerate(invocations):
            started.append(time.perf_counter())
            futs[pool.submit(run_one, inv)] = i
        finished: set[int] = set()
        backed: set[int] = set()
        done_s: list[float] = []
        errors: dict[int, BaseException] = {}
        try:
            while len(finished) < n:
                if not futs:
                    raise next(iter(errors.values()))
                done, _ = wait(set(futs), timeout=spec.interval,
                               return_when=FIRST_COMPLETED)
                now = time.perf_counter()
                for f in done:
                    i = futs.pop(f)
                    copies[i] -= 1
                    exc = f.exception()
                    if exc is None:
                        if i not in finished:
                            finished.add(i)
                            done_s.append(now - started[i])
                    else:
                        errors.setdefault(i, exc)
                        if i not in finished and copies[i] == 0:
                            raise exc   # no surviving copy: the stage fails
                status = None
                for i, inv in enumerate(invocations):
                    if i in finished or i in backed:
                        continue
                    if status is None:
                        status = self.gc.node_status()
                    node = spec.backup_node(inv, now - started[i], done_s,
                                            status)
                    if node is None:
                        continue
                    backed.add(i)
                    self.speculations.append(
                        (inv.name, inv.node, node, now - started[i]))
                    tr.record(f"speculate/{inv.name}", "invoker", now,
                              end=now, trace=inv.app, node=node,
                              parent=tr.anchored(
                                  ("stage", inv.app, inv.stage)),
                              kind="speculation", from_node=inv.node,
                              to_node=node, elapsed=now - started[i])
                    backup = replace(inv, node=node)
                    futs[pool.submit(run_one, backup)] = i
                    copies[i] += 1
        finally:
            # first-completion-wins: do NOT wait for losing copies — they
            # finish in the background (drain() joins them)
            pool.shutdown(wait=False)

    def drain(self) -> None:
        """Join speculation losers still running in the background."""
        for pool in self._pools:
            pool.shutdown(wait=True)
        self._pools.clear()
