"""Per-stage lineage + bounded recursive recovery planning.

Every physical stage the executor admits is recorded here: which decision
node produced it, which upstream stages it depends on, and — per invocation
— which *data* stage/partitions it writes (``params["dst"]`` up front,
refined by the partitions actually written once the invocation commits).
When a read hits a lost stage (``StageLostError``), ``recovery_plan``
computes the minimal bottom-up re-execution: the lost partitions' producer
invocations, plus — recursively — producers of any of *their* inputs that
are themselves gone (ephemeral GC, quota eviction, injected loss), stopping
at resident data. Re-executed invocations go back through the normal
invoker, so recovery honors slot fairness gates and store quotas exactly
like first-run work.

``expected_recovery`` is the simulator-side twin: it predicts the recovery
stage set from the *static* plan alone (residency derived from the
ephemeral-GC rule), which is what the simulator/runtime differential test
asserts against the runtime's actual recovery events.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

from repro.runtime.faults import RecoveryError

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.runtime.executor import RuntimeStage
    from repro.runtime.invoker import Invocation
    from repro.runtime.metrics import MetricsSink


@dataclass
class StageLineage:
    """What produced one physical stage (and thereby its data stage)."""

    name: str                          # RuntimeStage name, e.g. "shuffle_fact"
    app: str
    decision: str | None               # decision node that emitted the stage
    deps: tuple[str, ...]              # upstream RuntimeStage names
    invocations: list = field(default_factory=list)


@dataclass
class RecoveryEvent:
    """One healed loss: what was lost, what got recomputed."""

    app: str
    lost_stage: str                    # data stage name
    partitions: tuple[int, ...] | None
    recovered: tuple[str, ...]         # data stages recomputed, bottom-up
    invocations: int                   # producer invocations re-executed


def _inputs(inv: "Invocation") -> list[tuple[str, list[int] | None]]:
    """The data stages (and partitions; None = all) an invocation reads,
    parsed from the function library's parameter conventions."""
    p = inv.params
    out: list[tuple[str, list[int] | None]] = []
    if "src" in p:
        if "src_partitions" in p:
            # multi-partition readers (hot_filter_write):
            # "partition" is their *destination*, not a read
            out.append((p["src"], list(p["src_partitions"])))
        else:
            out.append((p["src"],
                        [p["partition"]] if "partition" in p else None))
    if "fact_stage" in p:
        fp = p.get("fact_partitions")
        out.append((p["fact_stage"], None if fp == "all" else list(fp)))
    if "dim_stage" in p:
        dp = p.get("dim_partitions")
        out.append((p["dim_stage"], None if dp == "all" else list(dp)))
    return out


class LineageLog:
    """Thread-safe record of which invocations produce which data stages."""

    def __init__(self):
        self._lock = threading.Lock()
        # (app, data_stage) -> producer invocations, in registration order
        self._producers: dict[tuple[str, str], list] = {}
        # (app, runtime_stage) -> StageLineage (docs, tests, dashboards)
        self.stages: dict[tuple[str, str], StageLineage] = {}

    def register_stage(self, st: "RuntimeStage") -> None:
        """Record a stage's producers. Re-registering a stage (the same app
        rerun on the same Runtime after a teardown) *replaces* its previous
        lineage — stale producers must not double recovery re-execution or
        inflate ``total_invocations``."""
        if not st.invocations:
            return
        app = st.invocations[0].app
        with self._lock:
            prev = self.stages.get((app, st.name))
            if prev is not None:
                stale = {iv.name for iv in prev.invocations}
                for key in [k for k in self._producers if k[0] == app]:
                    kept = [iv for iv in self._producers[key]
                            if iv.name not in stale]
                    if kept:
                        self._producers[key] = kept
                    else:
                        del self._producers[key]
            for inv in st.invocations:
                dst = inv.params.get("dst")
                if dst is None:
                    continue
                self._producers.setdefault((inv.app, dst), []).append(inv)
            self.stages[(app, st.name)] = StageLineage(
                st.name, app, getattr(st, "decision", None),
                tuple(st.deps), list(st.invocations))

    def producers(self, app: str, data_stage: str) -> list:
        with self._lock:
            return list(self._producers.get((app, data_stage), []))

    def total_invocations(self, app: str) -> int:
        with self._lock:
            return sum(len(sl.invocations) for (a, _), sl in
                       self.stages.items() if a == app)

    # -- recovery planning ---------------------------------------------------

    def _select(self, app: str, data_stage: str,
                parts: set[int] | None,
                writes: dict[str, set[tuple[str, int]]] | None) -> list:
        """Producer invocations of the lost partitions. With recorded writes
        the selection is partition-exact; without (invocation never ran, or
        no metrics) every producer is replayed — writer-label overwrite
        keeps that safe."""
        out = []
        for inv in self._producers.get((app, data_stage), []):
            if parts is not None and writes is not None:
                w = writes.get(inv.name)
                if w is not None and not any(
                        s == data_stage and p in parts for s, p in w):
                    continue
            out.append(inv)
        return out

    @staticmethod
    def _missing(app: str, data_stage: str, req: list[int] | None,
                 store) -> set[int] | None | str:
        """Which of the requested partitions are unavailable: a set (maybe
        empty), or ``"all"`` when the whole stage is gone."""
        written, lost = store.partition_state(app, data_stage)
        if lost == "all":
            return "all"
        if req is None:
            return set(lost)
        return {p for p in req if p in lost}

    def recovery_plan(self, app: str, data_stage: str,
                      partitions: Sequence[int] | None, store,
                      metrics: "MetricsSink | None" = None,
                      ) -> list[tuple[str, set[int] | None, list]] | None:
        """Bottom-up ``[(data_stage, partitions, invocations_to_rerun),
        ...]`` healing a loss of ``partitions`` (None = all) of
        ``data_stage``; ``None`` when the stage has no recorded lineage
        (e.g. seeded base inputs — only a whole-query rerun can restore
        those)."""
        writes = None
        if metrics is not None:
            writes = {}
            for r in metrics.records:
                if r.app == app and r.status == "ok" and r.writes:
                    writes[r.name] = set(r.writes)

        # pass 1: fixpoint of needed partitions per data stage
        need: dict[str, set[int] | None] = {
            data_stage: set(partitions) if partitions is not None else None}
        work = [data_stage]
        edges: dict[str, set[str]] = {}        # src -> consumers (in plan)
        seen_pairs: set[tuple[str, frozenset | None]] = set()
        while work:
            ds = work.pop()
            key = (ds, None if need[ds] is None else frozenset(need[ds]))
            if key in seen_pairs:
                continue
            seen_pairs.add(key)
            if (app, ds) not in self._producers:
                return None                    # no lineage: unrecoverable
            for inv in self._select(app, ds, need[ds], writes):
                for src, req in _inputs(inv):
                    miss = self._missing(app, src, req, store)
                    if miss != "all" and not miss:
                        continue
                    edges.setdefault(src, set()).add(ds)
                    prev = need.get(src, set())
                    new = None if (miss == "all" or prev is None) \
                        else prev | miss
                    if src not in need or new != prev:
                        need[src] = new
                        work.append(src)
            if len(seen_pairs) > 4 * max(1, len(self._producers)):
                raise RecoveryError(
                    f"recovery closure for {app!r}/{data_stage!r} did not "
                    f"converge (cyclic lineage?)")

        # pass 2: topological order, producers before consumers
        order: list[str] = []
        remaining = dict(need)
        while remaining:
            # a stage is ready once none of its still-unplaced inputs remain
            ready = [ds for ds in remaining
                     if not any(ds in cons and src in remaining
                                for src, cons in edges.items())]
            if not ready:
                raise RecoveryError(
                    f"cyclic recovery dependencies among {sorted(remaining)}")
            for ds in sorted(ready):
                order.append(ds)
                del remaining[ds]
        return [(ds, need[ds], self._select(app, ds, need[ds], writes))
                for ds in order]


class _StaticResidency:
    """Residency oracle for ``expected_recovery``: a data stage is gone iff
    the ephemeral-GC rule says a strict ancestor of the loss's consumer
    already reclaimed it (or it is the injected loss itself)."""

    def __init__(self, gone: dict[str, tuple[int, ...] | None]):
        self._gone = gone            # data stage -> lost partitions (None=all)

    def partition_state(self, app: str, stage: str):
        if stage in self._gone:
            parts = self._gone[stage]
            if parts is None:
                return set(), "all"
            return set(), set(parts)
        return {0}, set()            # resident (ids irrelevant: lost empty)


def expected_recovery(stages: Sequence["RuntimeStage"], lost_stage: str,
                      partitions: Sequence[int] | None = None,
                      ) -> list[str]:
    """Predict the recovery stage set for a loss of ``lost_stage`` from the
    static plan alone — no store, no execution.

    Residency is derived from the executor's GC rule: the consumer of the
    lost data stage only runs after its transitive dependencies finished,
    and a finishing stage reclaims its ``ephemeral_inputs``; so exactly the
    ephemeral inputs declared by strict ancestors of the consumer are gone
    at loss time, regardless of executor interleaving. This is the
    simulator-side twin of the runtime's actual recovery — the differential
    test asserts both compute the same set.
    """
    log = LineageLog()
    for st in stages:
        log.register_stage(st)
    if not stages or not stages[0].invocations:
        return []
    app = stages[0].invocations[0].app

    by_name = {st.name: st for st in stages}
    consumer = next(
        (st for st in stages
         if any(src == lost_stage
                for inv in st.invocations for src, _ in _inputs(inv))),
        None)
    ancestors: set[str] = set()
    frontier = list(consumer.deps) if consumer is not None else []
    while frontier:
        name = frontier.pop()
        if name in ancestors or name not in by_name:
            continue
        ancestors.add(name)
        frontier.extend(by_name[name].deps)

    gone: dict[str, tuple[int, ...] | None] = {}
    for st in stages:
        if st.name in ancestors:
            for ds in st.ephemeral_inputs:
                gone[ds] = None
    gone[lost_stage] = tuple(partitions) if partitions is not None else None

    plan = log.recovery_plan(app, lost_stage, partitions,
                             _StaticResidency(gone))
    if plan is None:
        raise RecoveryError(f"no lineage for {lost_stage!r} in static plan")
    return [ds for ds, _, _ in plan]
