"""Per-invocation timing/bytes records + feedback into decision workflows.

Every function invocation — including preempted attempts — leaves an
``InvocationRecord``. The sink aggregates them per stage, formats the
operator dashboards the examples print, folds profile feedback into
``DecisionContext.profile`` (paper Fig. 5 step 4), and can replay the whole
trace into ``ClusterSim`` so the simulated benchmarks and the real data
plane share one plan.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Mapping

from repro.core.decisions import merge_hot_keys, partition_skew


@dataclass
class InvocationRecord:
    name: str
    app: str
    stage: str
    func: str
    node: int
    attempt: int
    status: str          # "ok" | "preempted" | "starved" | "crashed" | "error"
    started: float
    finished: float
    bytes_in: int = 0
    bytes_out: int = 0
    # wall time the invocation spent against the shuffle store (reads +
    # writes, including emulated transfer); ``seconds - store_seconds`` is
    # its on-device compute — the split that lets decision nodes see *why*
    # a stage is slow (data movement vs work)
    store_seconds: float = 0.0
    reads_by_node: Mapping[int, int] = field(default_factory=dict)
    deps: tuple[str, ...] = ()
    priority: int = 0
    # (data_stage, partition) pairs the invocation wrote — the lineage
    # refinement that lets recovery replay only the lost partitions' actual
    # producers instead of every registered one
    writes: tuple = ()
    # shape-class padding tally across this invocation's kernel dispatches:
    # padded minus actual rows is wasted work the power-of-two quantizer
    # added (surfaced as ``padding_overhead`` in profile feedback)
    rows_actual: int = 0
    rows_padded: int = 0
    # free-form per-invocation observations the function body emitted via
    # ``ctx.stats`` (e.g. shuffle_write's per-bucket histogram and
    # heavy-hitter sketch — the skew node's observed distribution)
    stats: Mapping = field(default_factory=dict)

    @property
    def seconds(self) -> float:
        return max(0.0, self.finished - self.started)

    @property
    def compute_seconds(self) -> float:
        return max(0.0, self.seconds - self.store_seconds)


@dataclass
class StageMetrics:
    invocations: int = 0
    ok: int = 0
    preempted: int = 0
    crashed: int = 0
    starved: int = 0               # retry budget exhausted, no slot committed
    error: int = 0                 # function body / hook raised
    seconds: float = 0.0
    store_seconds: float = 0.0     # time against the store (transfer)
    compute_seconds: float = 0.0   # seconds - store_seconds, per record
    bytes_in: int = 0
    bytes_out: int = 0
    rows_actual: int = 0
    rows_padded: int = 0
    # per-bucket histograms summed elementwise over the stage's writers
    # (first ok record per invocation name — retries and speculation
    # duplicates never double-count), plus their heavy-hitter sketches
    partition_rows: tuple = ()
    partition_bytes: tuple = ()
    hot_sketches: tuple = ()

    @property
    def padding_overhead(self) -> float:
        """Fraction of kernel-dispatched rows that were padding
        (0.0 when nothing was padded or nothing was dispatched)."""
        if self.rows_padded <= self.rows_actual:
            return 0.0
        return (self.rows_padded - self.rows_actual) / self.rows_padded

    @property
    def max_partition_bytes(self) -> int:
        return max(self.partition_bytes, default=0)

    @property
    def mean_partition_bytes(self) -> float:
        if not self.partition_bytes:
            return 0.0
        return sum(self.partition_bytes) / len(self.partition_bytes)

    @property
    def partition_skew(self) -> float:
        """max/mean per-bucket rows — the lopsidedness figure the skew
        decision node thresholds on."""
        return partition_skew(self.partition_rows)

    @property
    def hot_keys(self) -> tuple:
        """Merged top-k heavy hitters across the stage's writers."""
        return merge_hot_keys(self.hot_sketches)


def _tuple_add(a: tuple, b) -> tuple:
    """Elementwise sum of two int tuples, right-padding the shorter with
    zeros (writers all emit ``num_buckets`` entries, but a stage mixing
    histogram and non-histogram records must still merge cleanly)."""
    a, b = tuple(a), tuple(b)
    if not b:
        return a
    if not a:
        return tuple(int(x) for x in b)
    if len(a) < len(b):
        a = a + (0,) * (len(b) - len(a))
    elif len(b) < len(a):
        b = b + (0,) * (len(a) - len(b))
    return tuple(int(x) + int(y) for x, y in zip(a, b))


class MetricsSink:
    """Thread-safe accumulator of invocation records."""

    def __init__(self):
        self._lock = threading.Lock()
        self.records: list[InvocationRecord] = []
        self._listeners: list = []

    def subscribe(self, fn) -> None:
        """Call ``fn(record)`` after every appended record — the pipelined
        executor's partition-readiness signal (commits, not stage barriers,
        wake waiting consumers)."""
        with self._lock:
            self._listeners.append(fn)

    def unsubscribe(self, fn) -> None:
        with self._lock:
            if fn in self._listeners:
                self._listeners.remove(fn)

    def record(self, rec: InvocationRecord) -> None:
        with self._lock:
            self.records.append(rec)
            listeners = list(self._listeners)
        for fn in listeners:       # outside the lock: listeners may re-enter
            fn(rec)

    def for_app(self, app: str) -> list[InvocationRecord]:
        with self._lock:
            return [r for r in self.records if r.app == app]

    def clear(self, app: str | None = None) -> int:
        """Drop records (one app's, or all) — the compaction hook that keeps
        a long-running/service-mode sink bounded. Returns the number
        dropped. Note that ``replay_into`` only covers records still held.
        """
        with self._lock:
            before = len(self.records)
            self.records = [] if app is None \
                else [r for r in self.records if r.app != app]
            return before - len(self.records)

    # -- aggregation -----------------------------------------------------------

    def by_stage(self, app: str | None = None) -> dict[str, StageMetrics]:
        out: dict[str, StageMetrics] = {}
        stat_seen: dict[str, set[str]] = {}
        with self._lock:
            records = list(self.records)
        for r in records:
            if app is not None and r.app != app:
                continue
            m = out.setdefault(r.stage, StageMetrics())
            m.invocations += 1
            m.ok += r.status == "ok"
            m.preempted += r.status == "preempted"
            m.crashed += r.status == "crashed"
            m.starved += r.status == "starved"
            m.error += r.status == "error"
            m.seconds += r.seconds
            m.store_seconds += r.store_seconds
            m.compute_seconds += r.compute_seconds
            m.bytes_in += r.bytes_in
            m.bytes_out += r.bytes_out
            m.rows_actual += r.rows_actual
            m.rows_padded += r.rows_padded
            if r.status == "ok" and r.stats:
                # only the first committed record per invocation name feeds
                # the stage histograms: a retried or speculated writer
                # recomputes the identical stats, and summing them twice
                # would fake skew the data doesn't have
                seen = stat_seen.setdefault(r.stage, set())
                if r.name not in seen:
                    seen.add(r.name)
                    m.partition_rows = _tuple_add(
                        m.partition_rows, r.stats.get("partition_rows", ()))
                    m.partition_bytes = _tuple_add(
                        m.partition_bytes, r.stats.get("partition_bytes", ()))
                    hot = tuple(r.stats.get("hot_keys", ()))
                    if hot:
                        m.hot_sketches = m.hot_sketches + (hot,)
        return out

    def stage_spans(self, app: str | None = None,
                    ) -> dict[str, tuple[float, float]]:
        """Wall-clock ``(first_start, last_finish)`` per stage — makes
        cross-stage overlap visible (the dependency-driven executor runs
        independent stages concurrently; under the barrier executor spans
        never intersect)."""
        out: dict[str, tuple[float, float]] = {}
        with self._lock:
            records = list(self.records)
        for r in records:
            if app is not None and r.app != app:
                continue
            lo, hi = out.get(r.stage, (r.started, r.finished))
            out[r.stage] = (min(lo, r.started), max(hi, r.finished))
        return out

    def profile_feedback(self, app: str, stage: str | None = None) -> dict:
        """Flat ``{"<stage>.<metric>": value}`` dict ready to merge into
        ``DecisionContext.profile`` via ``PrivateController.record_profile``.
        """
        out: dict[str, object] = {}
        for name, m in self.by_stage(app).items():
            if stage is not None and name != stage:
                continue
            out[f"{name}.seconds"] = m.seconds
            out[f"{name}.store_seconds"] = m.store_seconds
            out[f"{name}.compute_seconds"] = m.compute_seconds
            out[f"{name}.invocations"] = m.invocations
            out[f"{name}.bytes_in"] = m.bytes_in
            out[f"{name}.bytes_out"] = m.bytes_out
            out[f"{name}.preempted"] = m.preempted
            out[f"{name}.crashed"] = m.crashed
            out[f"{name}.starved"] = m.starved
            out[f"{name}.error"] = m.error
            out[f"{name}.padding_overhead"] = m.padding_overhead
            if m.partition_rows:
                out[f"{name}.partition_rows"] = m.partition_rows
                out[f"{name}.partition_bytes"] = m.partition_bytes
                out[f"{name}.partition_skew"] = m.partition_skew
                out[f"{name}.max_partition_bytes"] = m.max_partition_bytes
                out[f"{name}.mean_partition_bytes"] = m.mean_partition_bytes
                out[f"{name}.hot_keys"] = m.hot_keys
        return out

    def format_table(self, app: str) -> str:
        """Per-stage invocation/bytes dashboard (printed by the examples).

        Rows are sorted by each stage's first invocation start — the table
        reads in execution order, not dict-insertion order — and a TOTAL
        row closes it off.
        """
        lines = [f"{'stage':16s} {'inv':>4s} {'pre':>4s} {'stv':>4s} "
                 f"{'err':>4s} {'seconds':>9s} "
                 f"{'store_s':>9s} {'bytes_in':>10s} {'bytes_out':>10s} "
                 f"{'pad%':>5s} {'skew':>5s} {'hot':>4s}"]
        stages = self.by_stage(app)
        spans = self.stage_spans(app)
        total = StageMetrics()
        for name in sorted(stages,
                           key=lambda s: spans.get(s, (float("inf"), 0))[0]):
            m = stages[name]
            skew = f"{m.partition_skew:5.1f}" if m.partition_rows \
                else f"{'-':>5s}"
            lines.append(f"{name:16s} {m.invocations:4d} {m.preempted:4d} "
                         f"{m.starved:4d} {m.error:4d} "
                         f"{m.seconds:9.4f} {m.store_seconds:9.4f} "
                         f"{m.bytes_in:10d} {m.bytes_out:10d} "
                         f"{100 * m.padding_overhead:5.1f} "
                         f"{skew} {len(m.hot_keys):4d}")
            total.invocations += m.invocations
            total.preempted += m.preempted
            total.starved += m.starved
            total.error += m.error
            total.seconds += m.seconds
            total.store_seconds += m.store_seconds
            total.bytes_in += m.bytes_in
            total.bytes_out += m.bytes_out
            total.rows_actual += m.rows_actual
            total.rows_padded += m.rows_padded
            total.partition_rows = _tuple_add(total.partition_rows,
                                              m.partition_rows)
            total.partition_bytes = _tuple_add(total.partition_bytes,
                                               m.partition_bytes)
            total.hot_sketches = total.hot_sketches + m.hot_sketches
        m = total
        skew = f"{m.partition_skew:5.1f}" if m.partition_rows \
            else f"{'-':>5s}"
        lines.append(f"{'TOTAL':16s} {m.invocations:4d} {m.preempted:4d} "
                     f"{m.starved:4d} {m.error:4d} "
                     f"{m.seconds:9.4f} {m.store_seconds:9.4f} "
                     f"{m.bytes_in:10d} {m.bytes_out:10d} "
                     f"{100 * m.padding_overhead:5.1f} "
                     f"{skew} {len(m.hot_keys):4d}")
        return "\n".join(lines)

    # -- trace replay into the simulator ---------------------------------------

    def replay_into(self, sim, app: str | None = None,
                    rates: Mapping[str, float] | None = None) -> int:
        """Submit the successful invocation trace as SimTasks.

        The real runtime and the simulator then share one plan: same task
        names, dependency edges, placements and transfer volumes; durations
        come from calibrated per-operator rates applied to the *measured*
        bytes (or measured wall time when no rate covers the function).
        Returns the number of tasks submitted; caller runs ``sim.run()``.
        """
        from repro.analytics.simulator import SimTask
        n = 0
        with self._lock:
            records = list(self.records)
        ok = {r.name for r in records if r.status == "ok"}
        for r in records:
            if r.status != "ok" or (app is not None and r.app != app):
                continue
            rate = (rates or {}).get(r.func)
            duration = (r.bytes_in / rate) if rate and r.bytes_in \
                else r.seconds
            sim.submit(SimTask(
                r.name, r.app, duration, node=r.node, priority=r.priority,
                deps=tuple(d for d in r.deps if d in ok),
                transfers={s: int(b) for s, b in r.reads_by_node.items()
                           if s != r.node}))
            n += 1
        return n
