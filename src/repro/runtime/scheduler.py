"""Multi-query scheduler: fair-share admission over the shared substrate.

The paper's second headline claim is *fine-grained resource sharing across
diverse applications*: many queries contending for one pool of function
slots (``GlobalController``) and one ephemeral shuffle store. This module
makes that concurrency a first-class citizen. A ``QueryScheduler`` admits N
queries — each with its **own** ``DecisionWorkflow`` and DAG executor run —
against one shared ``Runtime``, under a pluggable policy:

* ``fifo``       — queries run one at a time in arrival order (the
                   baseline a naive job queue gives you),
* ``priority``   — one at a time, highest priority first (strict,
                   non-preemptive across queries),
* ``fair_share`` — all queries run concurrently; a ``FairShareGate``
                   rations the *function slots* by weighted max-min
                   fairness, so a heavy low-priority query cannot crowd
                   out a light high-priority one, yet idle entitlement is
                   work-conservingly redistributed.

Invocations still claim real slots through the controller, so priorities
keep their Omega-style preemption semantics underneath the gate; the gate
only decides *who may ask next*. Per-job store quotas (``QueryJob.quota``)
bound each tenant's live shuffle footprint through the store's
eviction/backpressure machinery.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any

from repro.core.decisions import worker_pool_target
from repro.obs.tracer import get_tracer
from repro.runtime.invoker import Invocation, SlotGate

POLICIES = ("fifo", "priority", "fair_share")


def default_weight(priority: int) -> float:
    """Default priority→fair-share-weight mapping, shared by ``QueryJob``
    and the gate's auto-registration of unmanaged apps."""
    return 1.0 + max(0, priority)


class GateTimeoutError(RuntimeError):
    """A fair-share gate acquisition did not succeed within the timeout."""


@dataclass
class QueryJob:
    """One query submitted to the scheduler.

    ``weight`` is the fair-share weight over function slots; by default it
    tracks priority (``1 + max(0, priority)``) so higher-priority tenants
    hold proportionally more slots. ``quota`` caps the app's live bytes in
    the shared shuffle store (see ``ShuffleStore.set_quota``).
    """

    app: str
    fact: Any                      # DistTable
    dim: Any                       # DistTable
    strategy: Any                  # QueryStrategy | strategy name
    priority: int = 0
    weight: float | None = None
    num_groups: int = 64
    quota: int | None = None
    workflow: Any = None           # optional pre-built DecisionWorkflow

    def fair_weight(self) -> float:
        return self.weight if self.weight is not None \
            else default_weight(self.priority)


@dataclass
class QueryResult:
    """Outcome + closed-loop timing of one scheduled query."""

    app: str
    priority: int = 0
    sums: Any = None
    error: BaseException | None = None
    submitted: float = 0.0         # monotonic, at submit()
    started: float = 0.0           # admission (execution begin)
    finished: float = 0.0
    decisions: list = field(default_factory=list)   # (stage, Decision) seq
    recoveries: list = field(default_factory=list)  # RecoveryEvents healed
    stages: dict = field(default_factory=dict)      # {stage: StageMetrics}

    @property
    def ok(self) -> bool:
        return self.error is None and self.finished > 0

    @property
    def latency(self) -> float:
        """Closed-loop latency: submission -> completion (includes queueing)."""
        return self.finished - self.submitted

    @property
    def queue_wait(self) -> float:
        return self.started - self.submitted

    @property
    def run_seconds(self) -> float:
        return self.finished - self.started


class FairShareGate(SlotGate):
    """Weighted max-min fair rationing of function slots across apps.

    Each registered app is entitled to ``weight / Σ weights × total_slots``
    slots (floored, min 1 — so every admitted query keeps making progress).
    An app under its entitlement may always take a slot; an app at or over
    it may take one only work-conservingly: when free slots remain *and* no
    other app with blocked demand is still under-served. Invokers hold a
    gate token exactly while they hold the controller claim, and give it
    back while blocked on the controller's release event, so the gate never
    deadlocks against per-node contention.
    """

    def __init__(self, total_slots: int, timeout: float = 60.0):
        self._cond = threading.Condition()
        self.total = int(total_slots)
        self.timeout = timeout
        self.weights: dict[str, float] = {}
        self.in_use: dict[str, int] = {}
        self._waiting: dict[str, int] = {}

    # -- membership ----------------------------------------------------------

    def register(self, app: str, weight: float = 1.0) -> None:
        with self._cond:
            self.weights[app] = max(1e-6, float(weight))
            self.in_use.setdefault(app, 0)
            self._waiting.setdefault(app, 0)
            self._cond.notify_all()

    def unregister(self, app: str) -> None:
        """Drop a finished app; its entitlement redistributes immediately."""
        with self._cond:
            self.weights.pop(app, None)
            self.in_use.pop(app, None)
            self._waiting.pop(app, None)
            self._cond.notify_all()

    # -- arithmetic (caller holds the condition) -----------------------------

    def entitlement(self, app: str) -> int:
        total_w = sum(self.weights.values())
        if not total_w or app not in self.weights:
            return self.total
        return max(1, int(self.weights[app] / total_w * self.total))

    def _may_take(self, app: str) -> bool:
        if sum(self.in_use.values()) >= self.total:
            return False
        if self.in_use.get(app, 0) < self.entitlement(app):
            return True
        # over entitlement: only while no under-served app has blocked demand
        for other, n_wait in self._waiting.items():
            if other == app or not n_wait:
                continue
            if self.in_use.get(other, 0) < self.entitlement(other):
                return False
        return True

    # -- SlotGate ------------------------------------------------------------

    def acquire(self, inv: Invocation) -> None:
        app = inv.app
        deadline = time.monotonic() + self.timeout
        with self._cond:
            if app not in self.weights:   # unmanaged app: default weight
                self.register(app, default_weight(inv.priority))
            self._waiting[app] += 1
            try:
                while not self._may_take(app):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise GateTimeoutError(
                            f"{inv.name}: no fair-share slot for {app!r} "
                            f"within {self.timeout}s "
                            f"(in_use={dict(self.in_use)})")
                    self._cond.wait(remaining)
                self.in_use[app] = self.in_use.get(app, 0) + 1
            finally:
                self._waiting[app] -= 1
                # this app's demand being served (or withdrawn) can make
                # work-conserving admission legal for an over-entitled
                # waiter — wake them to re-check
                self._cond.notify_all()

    def release(self, inv: Invocation) -> None:
        with self._cond:
            if self.in_use.get(inv.app, 0) > 0:
                self.in_use[inv.app] -= 1
            self._cond.notify_all()


class QueryScheduler:
    """Admits and drives N concurrent queries over one shared ``Runtime``.

    Usage::

        sched = QueryScheduler(runtime, policy="fair_share")
        sched.submit(QueryJob("etl_hi", fact, dim, "dynamic", priority=10))
        sched.submit(QueryJob("adhoc_lo", fact2, dim2, "static_hash"))
        results = sched.run()          # {app: QueryResult}

    ``fifo``/``priority`` admit one query at a time (``max_concurrent``
    widens the window while preserving admission order); ``fair_share``
    admits every query and installs a ``FairShareGate`` on the runtime's
    invoker. ``release_stores=True`` tears down each app's shuffle state as
    its result is captured (long workload mixes stay bounded).
    """

    def __init__(self, runtime, policy: str = "fair_share",
                 max_concurrent: int | None = None,
                 gate_timeout: float = 60.0, release_stores: bool = False,
                 recovery="lineage", max_recoveries: int = 8,
                 compact_metrics: bool = False):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; pick from {POLICIES}")
        self.runtime = runtime
        self.policy = policy
        self.max_concurrent = max_concurrent
        self.release_stores = release_stores
        # service-mode compaction: snapshot each query's per-stage metrics
        # into its QueryResult, then drop the raw records from the shared
        # sink so a long workload mix stays bounded
        self.compact_metrics = compact_metrics
        # failure-handling policy shared by every admitted query: lineage
        # recompute (default), whole-query rerun, or a recovery DecisionNode
        self.recovery = recovery
        self.max_recoveries = max_recoveries
        self.jobs: list[QueryJob] = []
        self.results: dict[str, QueryResult] = {}
        self.gate: FairShareGate | None = None
        if policy == "fair_share":
            total = sum(runtime.gc.total.values())
            self.gate = FairShareGate(total, timeout=gate_timeout)

    # -- submission ----------------------------------------------------------

    def submit(self, job: QueryJob) -> QueryResult:
        if job.app in self.results:
            raise ValueError(f"duplicate app {job.app!r}")
        self.jobs.append(job)
        res = QueryResult(job.app, priority=job.priority,
                          submitted=time.monotonic())
        self.results[job.app] = res
        return res

    # -- execution -----------------------------------------------------------

    def _ordered(self) -> list[QueryJob]:
        if self.policy == "priority":
            # stable: ties keep arrival order
            return sorted(self.jobs, key=lambda j: -j.priority)
        return list(self.jobs)

    def _window(self) -> int:
        if self.max_concurrent is not None:
            return max(1, self.max_concurrent)
        return len(self.jobs) if self.policy == "fair_share" else 1

    def run(self) -> dict[str, QueryResult]:
        """Drive every submitted query to completion; returns the results.

        Admission order and window follow the policy; each admitted query
        runs its own ``AdaptiveQueryPlan`` through the shared runtime's DAG
        executor in a dedicated driver thread.
        """
        prev_gate = self.runtime.invoker.gate
        if self.gate is not None:
            self.runtime.invoker.gate = self.gate
        self._grow_for_queue()
        try:
            window = threading.BoundedSemaphore(self._window())
            threads = []
            for job in self._ordered():
                window.acquire()       # blocks: strict admission order
                t = threading.Thread(target=self._run_job,
                                     args=(job, window),
                                     name=f"query-{job.app}")
                t.start()
                threads.append(t)
            for t in threads:
                t.join()
        finally:
            if self.gate is not None:
                self.runtime.invoker.gate = prev_gate
        return dict(self.results)

    # admission-time demand estimate: each admitted query immediately fans
    # out a scan wave at least this many invocations wide
    QUEUE_TASKS_PER_QUERY = 4

    def _grow_for_queue(self) -> None:
        """Queue-depth elasticity — the scheduler's half of the elastic
        control loop (the planner's ``elastic`` decision node is the
        per-stage half). Before the drivers start, a process-backed
        invoker is pre-grown for the admission backlog, so the first scan
        waves lease warm workers instead of paying one cold start each on
        the queries' critical paths. Sized by the shared
        ``worker_pool_target`` rule; backends without a pool are left
        alone. Scale-in is not forced here: the pool's idle reaper (and
        the per-stage elastic decision) shrink it once the burst drains.
        """
        resize = getattr(self.runtime.invoker, "resize", None)
        pool_size = getattr(self.runtime.invoker, "pool_size", None)
        if not (callable(resize) and callable(pool_size)) or not self.jobs:
            return
        depth = min(self._window(), len(self.jobs))
        target = worker_pool_target(
            depth * self.QUEUE_TASKS_PER_QUERY, pool_size(),
            tasks_per_worker=self.QUEUE_TASKS_PER_QUERY)
        if target > pool_size():
            resize(target)

    def _run_job(self, job: QueryJob, window: threading.Semaphore) -> None:
        from repro.analytics.query import QueryStrategy, prepare_query_plan

        res = self.results[job.app]
        strategy = job.strategy if not isinstance(job.strategy, str) \
            else QueryStrategy(job.strategy)
        if job.quota is not None:
            self.runtime.store.set_quota(job.app, job.quota)
        if self.gate is not None:
            self.gate.register(job.app, job.fair_weight())
        res.started = time.monotonic()
        # query root span: every stage/invocation/store span of this app
        # parents (transitively) to it via the ("query", app) anchor; the
        # admission wait (submit -> driver start) is recorded retroactively
        tr = get_tracer()
        root = tr.start(f"query/{job.app}", "scheduler", trace=job.app,
                        parent=None, policy=self.policy,
                        priority=job.priority)
        tr.anchor(("query", job.app), root)
        admit_wait = res.started - res.submitted
        if admit_wait > 1e-4:
            now = time.perf_counter()
            tr.record("admission_wait", "wait", now - admit_wait, end=now,
                      trace=job.app, parent=root, policy=self.policy)
        try:
            plan, pc = prepare_query_plan(
                self.runtime, job.fact, job.dim, strategy, app=job.app,
                priority=job.priority, num_groups=job.num_groups,
                workflow=job.workflow)
            self.runtime.execute(plan.initial_stages(), pc=pc, planner=plan,
                                 recovery=self.recovery,
                                 max_recoveries=self.max_recoveries)
            res.sums = self.runtime.result(job.app)
            res.decisions = list(plan.run.sequence)
        except BaseException as e:  # noqa: BLE001 - surfaced via QueryResult
            res.error = e
        finally:
            res.recoveries = [ev for ev in self.runtime.recoveries
                              if ev.app == job.app]
            res.finished = time.monotonic()
            res.stages = self.runtime.metrics.by_stage(job.app)
            if self.compact_metrics:
                self.runtime.metrics.clear(job.app)
            tr.release_anchor(("query", job.app))
            tr.end(root, status="error" if res.error is not None else "ok")
            if self.gate is not None:
                self.gate.unregister(job.app)
            if job.quota is not None:
                # parity with the quota-less path once the query is done:
                # sealed (consumed-ephemeral) stages are garbage, and the
                # quota must not bind a future app reusing the name
                self.runtime.store.drop_sealed(job.app)
                self.runtime.store.set_quota(job.app, None)
            if self.release_stores:
                self.runtime.release(job.app)
            window.release()

    # -- workload summaries --------------------------------------------------

    def makespan(self) -> float:
        done = [r for r in self.results.values() if r.finished]
        if not done:
            return 0.0
        return max(r.finished for r in done) - \
            min(r.submitted for r in done)

    def latencies(self, min_priority: int | None = None) -> list[float]:
        return sorted(r.latency for r in self.results.values()
                      if r.ok and (min_priority is None
                                   or r.priority >= min_priority))
