"""Pluggable storage backends for the ephemeral shuffle store.

The store's byte-accounting and lifecycle logic is medium-agnostic; this
package supplies the media. The interface is lithops-style — a flat
key/value bytes API (``put``/``get``/``delete``/``list``) — plus an
object-level convenience layer (``put_table``/``get_table``) so the
memory backend can keep today's zero-copy behavior while disk and the
emulated object store round-trip through real serialization.

Three implementations:

- ``MemoryBackend`` — host RAM, zero-copy object storage (the seed
  behavior; a ``Table`` put is the same object on get).
- ``DiskBackend`` — real files under a tempdir, numpy column
  serialization. Local-SSD spill: cheaper than recompute, no emulated
  latency (the file IO is real).
- ``ObjectStoreBackend`` — an emulated S3/GCS tier: in-memory bytes with
  a configurable first-byte latency, bandwidth, and per-request +
  per-GB dollar cost, billed into per-app cost accounting the same way
  the worker pool bills function-seconds.

Each backend exposes ``spec()`` — tier name, ordering (colder = higher),
bandwidths, latency, and cost knobs — which is exactly what the tiering
decision node consumes to price spill-vs-evict-vs-recompute, on the
runtime and the simulator alike.
"""

from __future__ import annotations

import hashlib
import io
import pickle
import shutil
import tempfile
import threading
from pathlib import Path

import numpy as np

_NPZ_MAGIC = b"RNPZ"
_PKL_MAGIC = b"RPKL"


def serialize_table(table) -> bytes:
    """Encode a table as bytes: numpy columns via ``np.savez`` when the
    object is columnar (``Table``/``TableSlice``), pickle otherwise (the
    duck-typed fakes the property suites use)."""
    mat = getattr(table, "materialize", None)
    if callable(mat):
        table = mat()
    cols = getattr(table, "columns", None)
    if isinstance(cols, dict):
        buf = io.BytesIO()
        np.savez(buf, **{k: np.asarray(v) for k, v in cols.items()})
        return _NPZ_MAGIC + buf.getvalue()
    return _PKL_MAGIC + pickle.dumps(table)


def deserialize_table(data: bytes):
    magic, payload = data[:4], data[4:]
    if magic == _NPZ_MAGIC:
        from repro.analytics.table import Table
        with np.load(io.BytesIO(payload)) as z:
            return Table({k: z[k] for k in z.files})
    if magic == _PKL_MAGIC:
        return pickle.loads(payload)
    raise ValueError(f"unknown serialization magic {magic!r}")


class StorageBackend:
    """Flat key/value bytes store (lithops ``Storage`` shape).

    ``tier`` names the backend; ``order`` ranks temperature (0 = hottest).
    ``io_seconds``/``request_cost`` price an *emulated* medium — real media
    (memory, local disk) return 0 and let wall-clock speak for itself. The
    shuffle store sleeps emulated seconds outside its lock and bills
    dollars into per-app cost accounting.
    """

    tier = "backend"
    order = 0
    zero_copy = False

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        """Return the stored bytes; raises ``KeyError`` if absent."""
        raise NotImplementedError

    def delete(self, key: str) -> None:
        """Remove a key; missing keys are a no-op (idempotent teardown)."""
        raise NotImplementedError

    def list(self, prefix: str = "") -> list[str]:
        raise NotImplementedError

    # -- object-level layer (the store speaks tables, not bytes) ----------

    def put_table(self, key: str, table) -> int:
        data = serialize_table(table)
        self.put(key, data)
        return len(data)

    def get_table(self, key: str):
        return deserialize_table(self.get(key))

    # -- pricing ----------------------------------------------------------

    def spec(self) -> dict:
        return {"tier": self.tier, "order": self.order,
                "read_bw": None, "write_bw": None, "latency_s": 0.0,
                "cost_per_request": 0.0, "cost_per_gb": 0.0}

    def io_seconds(self, nbytes: int, op: str = "get") -> float:
        """Emulated seconds one ``op`` of ``nbytes`` takes (0 for real
        media — their IO cost is actual wall time)."""
        return 0.0

    def request_cost(self, nbytes: int) -> float:
        """Dollars one request of ``nbytes`` costs (0 for free media)."""
        return 0.0

    def close(self) -> None:
        """Release held resources (tempdirs, buffers)."""


class MemoryBackend(StorageBackend):
    """Host-RAM tier: ``put_table`` keeps the object itself, so a read
    returns the very slice the writer published — the zero-copy seed
    behavior of the shuffle path."""

    tier = "memory"
    order = 0
    zero_copy = True

    def __init__(self):
        self._data: dict[str, object] = {}
        self._lock = threading.Lock()

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._data[key] = data

    def get(self, key: str) -> bytes:
        with self._lock:
            return self._data[key]

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)

    def list(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))

    def put_table(self, key: str, table) -> int:
        with self._lock:
            self._data[key] = table
        return int(getattr(table, "nbytes", 0))

    def get_table(self, key: str):
        with self._lock:
            v = self._data[key]
        return deserialize_table(v) if isinstance(v, bytes) else v

    def spec(self) -> dict:
        return {"tier": self.tier, "order": self.order,
                "read_bw": None, "write_bw": None, "latency_s": 0.0,
                "cost_per_request": 0.0, "cost_per_gb": 0.0}


class DiskBackend(StorageBackend):
    """Local-disk spill tier: real files in a tempdir. The advertised
    bandwidths exist only for the tiering decision's cost model — actual
    reads/writes cost whatever the filesystem costs."""

    tier = "disk"
    order = 1

    def __init__(self, root: str | Path | None = None,
                 read_bw: float = 500e6, write_bw: float = 500e6):
        self._own_root = root is None
        self.root = Path(root) if root is not None \
            else Path(tempfile.mkdtemp(prefix="repro-spill-"))
        self.root.mkdir(parents=True, exist_ok=True)
        self.read_bw = read_bw
        self.write_bw = write_bw
        self._paths: dict[str, Path] = {}
        self._lock = threading.Lock()

    def _path(self, key: str) -> Path:
        digest = hashlib.sha1(key.encode()).hexdigest()[:24]
        return self.root / f"{digest}.bin"

    def put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        path.write_bytes(data)
        with self._lock:
            self._paths[key] = path

    def get(self, key: str) -> bytes:
        with self._lock:
            path = self._paths[key]     # KeyError if absent
        return path.read_bytes()

    def delete(self, key: str) -> None:
        with self._lock:
            path = self._paths.pop(key, None)
        if path is not None:
            path.unlink(missing_ok=True)

    def list(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(k for k in self._paths if k.startswith(prefix))

    def spec(self) -> dict:
        return {"tier": self.tier, "order": self.order,
                "read_bw": self.read_bw, "write_bw": self.write_bw,
                "latency_s": 1e-4, "cost_per_request": 0.0,
                "cost_per_gb": 0.0}

    def close(self) -> None:
        with self._lock:
            self._paths.clear()
        if self._own_root:
            shutil.rmtree(self.root, ignore_errors=True)


class ObjectStoreBackend(StorageBackend):
    """Emulated S3-style tier: durable-ish in-memory bytes behind a
    latency + bandwidth + dollars cost model. Defaults are S3-ish
    (10 ms first byte, 100 MB/s per stream, $4e-7/request + $0.01/GB
    moved); tests pass zeros to keep runs instantaneous."""

    tier = "object"
    order = 2

    def __init__(self, latency_s: float = 0.01, bw: float | None = 100e6,
                 cost_per_request: float = 4e-7,
                 cost_per_gb: float = 0.01):
        self.latency_s = latency_s
        self.bw = bw
        self.cost_per_request = cost_per_request
        self.cost_per_gb = cost_per_gb
        self._data: dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._data[key] = data

    def get(self, key: str) -> bytes:
        with self._lock:
            return self._data[key]

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)

    def list(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))

    def spec(self) -> dict:
        return {"tier": self.tier, "order": self.order,
                "read_bw": self.bw, "write_bw": self.bw,
                "latency_s": self.latency_s,
                "cost_per_request": self.cost_per_request,
                "cost_per_gb": self.cost_per_gb}

    def io_seconds(self, nbytes: int, op: str = "get") -> float:
        s = self.latency_s
        if self.bw:
            s += nbytes / self.bw
        return s

    def request_cost(self, nbytes: int) -> float:
        return self.cost_per_request + nbytes * self.cost_per_gb / 1e9

    def close(self) -> None:
        with self._lock:
            self._data.clear()


_BUILTIN = {"memory": MemoryBackend, "disk": DiskBackend,
            "object": ObjectStoreBackend}


def make_backend(spec) -> StorageBackend:
    """Resolve a backend: an instance passes through, a name constructs
    the builtin with defaults."""
    if isinstance(spec, StorageBackend):
        return spec
    try:
        return _BUILTIN[spec]()
    except KeyError:
        raise ValueError(
            f"unknown storage backend {spec!r} "
            f"(expected one of {sorted(_BUILTIN)})") from None


__all__ = ["StorageBackend", "MemoryBackend", "DiskBackend",
           "ObjectStoreBackend", "make_backend", "serialize_table",
           "deserialize_table"]
