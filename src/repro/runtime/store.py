"""Ephemeral object store for externalized intermediate state.

Serverless functions are stateless; every byte exchanged between stages goes
through an external store (the Lambada/Pocket model adopted by the paper's
substrate). Blobs are keyed ``(app, stage, partition)``; multiple writers may
append slices to the same partition (that *is* the shuffle), each under its
own writer label so a retried (preempted) invocation overwrites its previous
slice instead of duplicating it.

The store keeps per-node byte accounting — bytes resident per home node,
bytes served cross-node per source, bytes read per reader — so shuffle
volumes feed straight back into ``DataDist`` for the decision workflows
(paper Fig. 5 step 4: runtime knowledge flows back into decision nodes).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Mapping

from repro.core.decisions import DataDist, partition_skew


@dataclass
class Blob:
    """One written slice of a partition: the payload plus its home node."""

    table: object            # repro.analytics.table.Table (duck-typed)
    node: int
    nbytes: int
    rows: int


class ShuffleStore:
    """Thread-safe ephemeral blob store with per-node byte accounting.

    Lifecycle is per-(app, stage): ``delete_stage`` reclaims a stage as soon
    as its consumers finish, ``clear_app`` tears down a whole query's state.

    ``net_bw`` (bytes/s) optionally emulates the transfer cost: cross-node
    reads block for ``bytes / net_bw`` seconds *outside* the store lock, so
    under a parallel invoker transfers overlap with other stages' compute —
    the first-order cost the discrete-event simulator prices with its NIC
    contention model. With ``disaggregated=True`` the store behaves like the
    fully external storage tier of Lambada/Pocket: *every* read and write is
    charged at ``net_bw``, node-locality earns no discount. ``None``
    (default) keeps all store traffic instantaneous.
    """

    def __init__(self, net_bw: float | None = None,
                 disaggregated: bool = False):
        self._lock = threading.RLock()
        self.net_bw = net_bw
        self.disaggregated = disaggregated
        # (app, stage) -> partition -> writer -> Blob
        self._stages: dict[tuple[str, str], dict[int, dict[str, Blob]]] = {}
        self.resident_bytes: dict[int, int] = {}   # node -> live blob bytes
        self.written_bytes: dict[int, int] = {}    # node -> cumulative writes
        self.read_bytes: dict[int, int] = {}       # reader node -> bytes read
        self.sent_bytes: dict[int, int] = {}       # source node -> remote reads
        self.cross_node_bytes = 0                  # total shuffle traffic

    # -- writes ---------------------------------------------------------------

    def put(self, app: str, stage: str, partition: int, table, node: int,
            writer: str = "") -> int:
        """Write (or, on retry, replace) one writer's slice of a partition.

        Returns the bytes written.
        """
        nbytes, rows = int(table.nbytes), int(table.num_rows)
        if self.disaggregated and self.net_bw and writer != "seed":
            time.sleep(nbytes / self.net_bw)
        with self._lock:
            parts = self._stages.setdefault((app, stage), {})
            blobs = parts.setdefault(partition, {})
            old = blobs.get(writer)
            if old is not None:   # preempted attempt being re-done: retract it
                self.resident_bytes[old.node] = \
                    self.resident_bytes.get(old.node, 0) - old.nbytes
            blobs[writer] = Blob(table, node, nbytes, rows)
            self.resident_bytes[node] = self.resident_bytes.get(node, 0) + nbytes
            self.written_bytes[node] = self.written_bytes.get(node, 0) + nbytes
        return nbytes

    def ingest(self, app: str, stage: str, partitions: Mapping[int, object],
               ) -> list[tuple[int, int]]:
        """Seed base data: one partition per home node (node -> table).

        Returns ``[(partition_index, home_node), ...]`` in index order — the
        planner's view of where the input lives.
        """
        layout = []
        for idx, (node, table) in enumerate(sorted(partitions.items())):
            self.put(app, stage, idx, table, node, writer="seed")
            layout.append((idx, node))
        return layout

    # -- reads ----------------------------------------------------------------

    def get(self, app: str, stage: str, partition: int, node: int,
            account: bool = True):
        """Concatenate every writer's slice of a partition (writer-sorted, so
        content is deterministic under concurrent invokers). Remote reads are
        charged to the blob's home node — this is the shuffle/broadcast
        traffic the simulator's NIC model prices. Returns None if absent."""
        remote = 0
        with self._lock:
            blobs = self._stages.get((app, stage), {}).get(partition)
            if not blobs:
                return None
            ordered = [blobs[w] for w in sorted(blobs)]
            if account:
                for blob in ordered:
                    self.read_bytes[node] = \
                        self.read_bytes.get(node, 0) + blob.nbytes
                    if blob.node != node:
                        remote += blob.nbytes
                        self.sent_bytes[blob.node] = \
                            self.sent_bytes.get(blob.node, 0) + blob.nbytes
                        self.cross_node_bytes += blob.nbytes
        charged = sum(b.nbytes for b in ordered) if self.disaggregated \
            else remote
        if account and charged and self.net_bw:
            time.sleep(charged / self.net_bw)
        out = ordered[0].table
        for blob in ordered[1:]:
            out = out.concat(blob.table)
        return out

    def partitions(self, app: str, stage: str) -> list[int]:
        with self._lock:
            return sorted(self._stages.get((app, stage), {}))

    # -- accounting views ------------------------------------------------------

    def stage_bytes(self, app: str, stage: str) -> int:
        with self._lock:
            return sum(b.nbytes
                       for part in self._stages.get((app, stage), {}).values()
                       for b in part.values())

    def read_sources(self, app: str, stage: str, partition: int,
                     reader: int) -> dict[int, int]:
        """Bytes this partition would pull per remote source node (for trace
        replay into the simulator's transfer model). Does not account."""
        with self._lock:
            blobs = self._stages.get((app, stage), {}).get(partition, {})
            out: dict[int, int] = {}
            for b in blobs.values():
                if b.node != reader:
                    out[b.node] = out.get(b.node, 0) + b.nbytes
            return out

    def data_dist(self, app: str, stage: str, name: str | None = None,
                  ) -> DataDist:
        """The stage's output distribution, ready for a DecisionContext."""
        with self._lock:
            parts = self._stages.get((app, stage), {})
            per_node: dict[int, int] = {}
            rows_per_part = []
            total_rows = 0
            for blobs in parts.values():
                rows_per_part.append(sum(b.rows for b in blobs.values()))
                for b in blobs.values():
                    per_node[b.node] = per_node.get(b.node, 0) + b.nbytes
                    total_rows += b.rows
        return DataDist(name or f"{app}/{stage}", per_node,
                        rows=total_rows, skew=partition_skew(rows_per_part))

    # -- lifecycle -------------------------------------------------------------

    def delete_stage(self, app: str, stage: str) -> int:
        """Drop a stage's blobs; returns bytes reclaimed (ephemerality is the
        point: shuffle state outlives only its consumers)."""
        with self._lock:
            parts = self._stages.pop((app, stage), {})
            freed = 0
            for blobs in parts.values():
                for b in blobs.values():
                    self.resident_bytes[b.node] = \
                        self.resident_bytes.get(b.node, 0) - b.nbytes
                    freed += b.nbytes
            return freed

    def clear_app(self, app: str) -> int:
        freed = 0
        with self._lock:
            for key in [k for k in self._stages if k[0] == app]:
                freed += self.delete_stage(*key)
        return freed
