"""Ephemeral object store for externalized intermediate state.

Serverless functions are stateless; every byte exchanged between stages goes
through an external store (the Lambada/Pocket model adopted by the paper's
substrate). Blobs are keyed ``(app, stage, partition)``; multiple writers may
append slices to the same partition (that *is* the shuffle), each under its
own writer label so a retried (preempted) invocation overwrites its previous
slice instead of duplicating it.

The store keeps per-node byte accounting — bytes resident per home node,
bytes served cross-node per source, bytes read per reader — so shuffle
volumes feed straight back into ``DataDist`` for the decision workflows
(paper Fig. 5 step 4: runtime knowledge flows back into decision nodes).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Mapping, Sequence

from repro.core.decisions import DataDist, partition_skew
from repro.obs.tracer import get_tracer


@dataclass
class Blob:
    """One written slice of a partition: the payload plus its home node."""

    table: object            # repro.analytics.table.Table (duck-typed)
    node: int
    nbytes: int
    rows: int


class QuotaExceededError(RuntimeError):
    """A write could not be admitted under the application's store quota."""


class PrefetchHandle:
    """A fetch running on a background thread (double-buffered reads).

    ``join`` blocks until the thunk finishes and returns its result,
    re-raising whatever it raised — so a lost-stage tombstone surfaces to
    the consumer at join time exactly as a direct read would. The worker is
    a daemon: a handle abandoned by a crashed invocation never blocks
    shutdown, and its store accounting already happened in the worker (a
    retry's own reads come on top, same as a retried direct read).
    """

    def __init__(self, fn):
        self._result = None
        self._exc: BaseException | None = None
        self._thread = threading.Thread(target=self._run, args=(fn,),
                                        daemon=True)
        self._thread.start()

    def _run(self, fn) -> None:
        try:
            self._result = fn()
        except BaseException as e:   # re-raised at join()
            self._exc = e

    def done(self) -> bool:
        return not self._thread.is_alive()

    def join(self):
        self._thread.join()
        if self._exc is not None:
            raise self._exc
        return self._result


class StageLostError(RuntimeError):
    """A read hit shuffle data that *was* written but has since been lost.

    Ephemeral storage may evict consumed stages (quota pressure), the
    executor reclaims ephemeral inputs, and a fault plan may kill stage data
    outright — in all three cases the store leaves a *lost tombstone* per
    evicted partition, so a later reader sees a typed error instead of a
    silent ``None`` (which would corrupt the query). The executor catches
    this error and triggers lineage-based recompute of the lost partitions'
    producer invocations (``repro.runtime.lineage``).
    """

    def __init__(self, app: str, stage: str, partitions=None):
        self.app = app
        self.stage = stage
        self.partitions = tuple(partitions) if partitions is not None \
            else None
        which = "all partitions" if self.partitions is None \
            else f"partitions {list(self.partitions)}"
        super().__init__(
            f"stage {app!r}/{stage!r}: {which} lost (evicted or failed) "
            f"after being written")


class ShuffleStore:
    """Thread-safe ephemeral blob store with per-node byte accounting.

    Lifecycle is per-(app, stage): ``delete_stage`` reclaims a stage as soon
    as its consumers finish, ``clear_app`` tears down a whole query's state.

    Multi-tenant sharing: ``quotas`` caps each application's live footprint.
    An over-quota write first evicts the app's own *sealed* stages
    (consumed-ephemeral state the executor hands back via
    ``reclaim_stage``), then blocks awaiting concurrent frees — admission
    backpressure — and finally raises ``QuotaExceededError`` after
    ``quota_timeout`` seconds. ``app_bytes``/``peak_bytes`` expose per-app
    live/high-water footprints to schedulers and benchmarks.

    ``net_bw`` (bytes/s) optionally emulates the transfer cost: cross-node
    reads block for ``bytes / net_bw`` seconds *outside* the store lock, so
    under a parallel invoker transfers overlap with other stages' compute —
    the first-order cost the discrete-event simulator prices with its NIC
    contention model. With ``disaggregated=True`` the store behaves like the
    fully external storage tier of Lambada/Pocket: *every* read and write is
    charged at ``net_bw``, node-locality earns no discount. ``None``
    (default) keeps all store traffic instantaneous.
    """

    def __init__(self, net_bw: float | None = None,
                 disaggregated: bool = False,
                 quotas: Mapping[str, int] | None = None,
                 quota_timeout: float = 10.0):
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self.net_bw = net_bw
        self.disaggregated = disaggregated
        # (app, stage) -> partition -> writer -> Blob
        self._stages: dict[tuple[str, str], dict[int, dict[str, Blob]]] = {}
        self.resident_bytes: dict[int, int] = {}   # node -> live blob bytes
        self.written_bytes: dict[int, int] = {}    # node -> cumulative writes
        self.read_bytes: dict[int, int] = {}       # reader node -> bytes read
        self.sent_bytes: dict[int, int] = {}       # source node -> remote reads
        self.cross_node_bytes = 0                  # total shuffle traffic
        # -- per-application memory quotas (multi-tenant sharing) ------------
        self._quotas: dict[str, int] = dict(quotas or {})
        self.quota_timeout = quota_timeout
        self.app_bytes: dict[str, int] = {}        # app -> live blob bytes
        self.peak_bytes: dict[str, int] = {}       # app -> high-water mark
        # sealed stages: consumed-ephemeral state, readable until quota
        # pressure reclaims it (insertion order == LRU eviction order)
        self._sealed: dict[tuple[str, str], bool] = {}
        self.evictions: list[tuple[str, str, int]] = []
        # lost tombstones: (app, stage) -> partition ids whose written data
        # was evicted/killed; reads raise StageLostError until a producer
        # rewrites the partition (or recovery clears the marker)
        self._lost: dict[tuple[str, str], set[int]] = {}
        # fault-injection hook: consulted at the top of every ``get`` so a
        # FaultPlan can lose a stage deterministically on its k-th read
        self.injector = None

    # -- quotas ---------------------------------------------------------------

    def set_quota(self, app: str, limit: int | None) -> None:
        """Cap an application's live store footprint at ``limit`` bytes
        (``None`` removes the cap). Writes over the cap first reclaim the
        app's own sealed stages, then block awaiting concurrent frees, then
        raise ``QuotaExceededError`` after ``quota_timeout`` seconds."""
        with self._cond:
            if limit is None:
                self._quotas.pop(app, None)
            else:
                self._quotas[app] = int(limit)
            self._cond.notify_all()

    def quota(self, app: str) -> int | None:
        with self._lock:
            return self._quotas.get(app)

    def _evict_one(self, app: str) -> bool:
        """Reclaim the app's least-recently-sealed stage; caller holds the
        lock. Returns True if anything was freed. The evicted stage leaves a
        lost tombstone: a later reader gets ``StageLostError`` (recoverable
        via lineage), never silently-empty data."""
        for key in self._sealed:
            if key[0] != app:
                continue
            freed = self.lose_stage(*key)
            self.evictions.append((key[0], key[1], freed))
            return True
        return False

    def _admit(self, app: str, stage: str, partition: int, writer: str,
               nbytes: int) -> None:
        """Block (under the lock, via the condition) until ``nbytes`` fits
        the app's quota, evicting sealed stages first. Caller holds the
        lock."""
        deadline = None
        while True:
            limit = self._quotas.get(app)
            if limit is None:
                return
            old = self._stages.get((app, stage), {}) \
                .get(partition, {}).get(writer)
            delta = nbytes - (old.nbytes if old is not None else 0)
            if self.app_bytes.get(app, 0) + delta <= limit:
                return
            if delta > limit:
                # permanently unsatisfiable: even with every other byte of
                # the app freed this one write cannot fit — fail fast
                # instead of pinning the slot for quota_timeout
                raise QuotaExceededError(
                    f"app {app!r}: single write of {nbytes} bytes to stage "
                    f"{stage!r} can never fit quota {limit}")
            if self._evict_one(app):
                continue
            now = time.monotonic()
            if deadline is None:
                deadline = now + self.quota_timeout
            if now >= deadline:
                raise QuotaExceededError(
                    f"app {app!r}: write of {nbytes} bytes to stage "
                    f"{stage!r} exceeds quota {limit} "
                    f"(live {self.app_bytes.get(app, 0)} bytes, nothing "
                    f"sealed to evict, no free within "
                    f"{self.quota_timeout}s)")
            self._cond.wait(deadline - now)

    # -- writes ---------------------------------------------------------------

    def _put_locked(self, app: str, stage: str, partition: int, table,
                    node: int, writer: str, nbytes: int, rows: int) -> None:
        """Admission + insert of one writer slice; caller holds the lock
        (``_admit`` may block on the condition, releasing it while waiting).
        """
        self._admit(app, stage, partition, writer, nbytes)
        lost = self._lost.get((app, stage))
        if lost is not None:
            # a producer (retry, speculation backup, lineage recompute)
            # rewriting a lost partition heals it
            lost.discard(partition)
            if not lost:
                del self._lost[(app, stage)]
        parts = self._stages.setdefault((app, stage), {})
        blobs = parts.setdefault(partition, {})
        old = blobs.get(writer)
        if old is not None:   # preempted attempt being re-done: retract it
            self.resident_bytes[old.node] = \
                self.resident_bytes.get(old.node, 0) - old.nbytes
            self.app_bytes[app] = \
                self.app_bytes.get(app, 0) - old.nbytes
        blobs[writer] = Blob(table, node, nbytes, rows)
        self.resident_bytes[node] = self.resident_bytes.get(node, 0) + nbytes
        self.written_bytes[node] = self.written_bytes.get(node, 0) + nbytes
        self.app_bytes[app] = self.app_bytes.get(app, 0) + nbytes
        self.peak_bytes[app] = max(self.peak_bytes.get(app, 0),
                                   self.app_bytes[app])
        get_tracer().count(f"store_bytes/{app}", self.app_bytes[app])

    def put(self, app: str, stage: str, partition: int, table, node: int,
            writer: str = "") -> int:
        """Write (or, on retry, replace) one writer's slice of a partition.

        Returns the bytes written.
        """
        tr = get_tracer()
        t0 = time.perf_counter() if tr.enabled else 0.0
        nbytes, rows = int(table.nbytes), int(table.num_rows)
        with self._cond:
            self._put_locked(app, stage, partition, table, node, writer,
                             nbytes, rows)
        # the emulated disaggregated transfer is charged only AFTER quota
        # admission succeeds: a write rejected by the quota (or blocked on
        # eviction) must not pay the transfer once per failed attempt, which
        # would inflate store_seconds and the critical-path store split
        if self.disaggregated and self.net_bw and writer != "seed":
            time.sleep(nbytes / self.net_bw)
        if tr.enabled:
            tr.record(f"put/{stage}", "store", t0, trace=app, node=node,
                      partition=partition, bytes=nbytes)
        return nbytes

    def put_many(self, app: str, stage: str, tables: Mapping[int, object],
                 node: int, writer: str = "") -> int:
        """Write one writer's slices of *many* partitions in a single store
        round trip — the columnar-slice shuffle path: the producer computes
        every bucket in one device pass and publishes them all at once
        (typically ``TableSlice`` views sharing one parent buffer).

        Per-partition byte accounting, quota admission, and lost-tombstone
        healing are identical to ``partition``-at-a-time ``put``; the
        disaggregated transfer charge is one sleep for the *total* bytes
        (one flow, not P serialized ones). Returns total bytes written.
        """
        tr = get_tracer()
        t0 = time.perf_counter() if tr.enabled else 0.0
        sized = [(int(p), t, int(t.nbytes), int(t.num_rows))
                 for p, t in sorted(tables.items())]
        total = sum(nb for _, _, nb, _ in sized)
        with self._cond:
            for partition, table, nbytes, rows in sized:
                self._put_locked(app, stage, partition, table, node, writer,
                                 nbytes, rows)
        # transfer charged after admission (see ``put``): a quota rejection
        # mid-batch pays nothing for the flow it never completed
        if self.disaggregated and self.net_bw and writer != "seed" and total:
            time.sleep(total / self.net_bw)
        if tr.enabled:
            tr.record(f"put_many/{stage}", "store", t0, trace=app, node=node,
                      partitions=len(sized), bytes=total)
        return total

    def ingest(self, app: str, stage: str, partitions,
               ) -> list[tuple[int, int]]:
        """Seed base data: a ``{node: table}`` mapping (one partition per
        home node, the classic layout) or a ``[(node, table), ...]``
        sequence (several partitions per node — the fine-grained layout the
        batched map path coalesces).

        Returns ``[(partition_index, home_node), ...]`` in index order — the
        planner's view of where the input lives.
        """
        pairs = sorted(partitions.items()) if hasattr(partitions, "items") \
            else list(partitions)
        layout = []
        for idx, (node, table) in enumerate(pairs):
            self.put(app, stage, idx, table, node, writer="seed")
            layout.append((idx, node))
        return layout

    # -- reads ----------------------------------------------------------------

    def get(self, app: str, stage: str, partition: int, node: int,
            account: bool = True):
        """Concatenate every writer's slice of a partition (writer-sorted, so
        content is deterministic under concurrent invokers). Remote reads are
        charged to the blob's home node — this is the shuffle/broadcast
        traffic the simulator's NIC model prices. Returns None if absent;
        raises ``StageLostError`` if the partition was written and then
        evicted/killed (the reader must never see silently-missing data)."""
        tr = get_tracer()
        if not tr.enabled:
            return self._get_impl(app, stage, partition, node, account)
        t0 = time.perf_counter()
        try:
            t = self._get_impl(app, stage, partition, node, account)
        except StageLostError:
            tr.record(f"get/{stage}", "store", t0, trace=app, node=node,
                      partition=partition, status="lost")
            raise
        tr.record(f"get/{stage}", "store", t0, trace=app, node=node,
                  partition=partition,
                  bytes=int(t.nbytes) if t is not None else 0,
                  status="ok" if t is not None else "miss")
        return t

    def get_async(self, app: str, stage: str, partition: int, node: int,
                  account: bool = True) -> PrefetchHandle:
        """``get`` on a background thread — the double-buffered read used by
        the pipelined data plane (fetch bucket k+1 while probing bucket k).
        Accounting and fault hooks run in the worker, once."""
        return PrefetchHandle(
            lambda: self.get(app, stage, partition, node, account))

    def _get_impl(self, app: str, stage: str, partition: int, node: int,
                  account: bool = True):
        remote = 0
        with self._lock:
            if self.injector is not None:
                # fault-injection: a plan may lose this stage right now (the
                # k-th read) — the lost check below then raises
                self.injector.on_get(app, stage, partition, node)
            blobs = self._stages.get((app, stage), {}).get(partition)
            if not blobs:
                lost = self._lost.get((app, stage))
                if lost and partition in lost:
                    raise StageLostError(app, stage, (partition,))
                return None
            ordered = [blobs[w] for w in sorted(blobs)]
            if account:
                for blob in ordered:
                    self.read_bytes[node] = \
                        self.read_bytes.get(node, 0) + blob.nbytes
                    if blob.node != node:
                        remote += blob.nbytes
                        self.sent_bytes[blob.node] = \
                            self.sent_bytes.get(blob.node, 0) + blob.nbytes
                        self.cross_node_bytes += blob.nbytes
        charged = sum(b.nbytes for b in ordered) if self.disaggregated \
            else remote
        if account and charged and self.net_bw:
            time.sleep(charged / self.net_bw)
        from repro.analytics.table import Table
        return Table.concat_all([b.table for b in ordered])

    def partitions(self, app: str, stage: str) -> list[int]:
        """Known partition ids: written ∪ lost. Lost ids are included so an
        all-partitions reader (``FnContext.get_all``) hits the tombstone and
        raises instead of silently skipping evicted data."""
        with self._lock:
            return sorted(set(self._stages.get((app, stage), {})) |
                          self._lost.get((app, stage), set()))

    def partition_state(self, app: str, stage: str,
                        ) -> tuple[set[int], set[int]]:
        """``(written, lost)`` partition-id sets — the residency view the
        lineage recovery planner consults."""
        with self._lock:
            return (set(self._stages.get((app, stage), {})),
                    set(self._lost.get((app, stage), set())))

    # -- accounting views ------------------------------------------------------

    def stage_bytes(self, app: str, stage: str) -> int:
        with self._lock:
            return sum(b.nbytes
                       for part in self._stages.get((app, stage), {}).values()
                       for b in part.values())

    def read_sources(self, app: str, stage: str, partition: int,
                     reader: int) -> dict[int, int]:
        """Bytes this partition would pull per remote source node (for trace
        replay into the simulator's transfer model). Does not account."""
        with self._lock:
            blobs = self._stages.get((app, stage), {}).get(partition, {})
            out: dict[int, int] = {}
            for b in blobs.values():
                if b.node != reader:
                    out[b.node] = out.get(b.node, 0) + b.nbytes
            return out

    def data_dist(self, app: str, stage: str, name: str | None = None,
                  ) -> DataDist:
        """The stage's output distribution, ready for a DecisionContext."""
        with self._lock:
            parts = self._stages.get((app, stage), {})
            per_node: dict[int, int] = {}
            rows_per_part = []
            total_rows = 0
            for blobs in parts.values():
                rows_per_part.append(sum(b.rows for b in blobs.values()))
                for b in blobs.values():
                    per_node[b.node] = per_node.get(b.node, 0) + b.nbytes
                    total_rows += b.rows
        return DataDist(name or f"{app}/{stage}", per_node,
                        rows=total_rows, skew=partition_skew(rows_per_part))

    # -- lifecycle -------------------------------------------------------------

    def seal(self, app: str, stage: str) -> None:
        """Mark a stage reclaimable: its consumers are done, reads still
        work, and quota pressure may evict it (LRU by seal order)."""
        with self._cond:
            if (app, stage) in self._stages:
                self._sealed[(app, stage)] = True
                self._cond.notify_all()     # blocked writers can now evict

    def drop_sealed(self, app: str) -> int:
        """Drop every sealed stage of an app — end-of-query GC parity with
        the quota-less eager-delete path. Returns bytes freed."""
        with self._cond:
            freed = 0
            for key in [k for k in self._sealed if k[0] == app]:
                freed += self.delete_stage(*key)
            return freed

    def reclaim_stage(self, app: str, stage: str) -> int:
        """Ephemeral-input GC entry point for the executor: under a quota the
        stage is sealed (lazily evicted when the app needs headroom),
        otherwise dropped immediately — leaving a lost tombstone, so a
        late reader (speculation loser, recovery replay) gets a typed
        ``StageLostError`` rather than silently-empty data. Returns bytes
        freed now."""
        with self._cond:
            if self._quotas.get(app) is not None:
                self.seal(app, stage)
                return 0
            return self.lose_stage(app, stage)

    def lose_stage(self, app: str, stage: str,
                   partitions: Sequence[int] | None = None) -> int:
        """Evict written shuffle data (all partitions, or just
        ``partitions``) and leave lost tombstones: later reads of the
        evicted partitions raise ``StageLostError`` until a producer
        rewrites them. This is the store half of the fault model — stage
        loss of disaggregated ephemeral storage (ServerMix's core tension)
        — and of ephemeral-input GC. Returns bytes freed."""
        with self._cond:
            key = (app, stage)
            parts = self._stages.get(key)
            if not parts:
                return 0
            targets = sorted(parts) if partitions is None else \
                [p for p in partitions if p in parts]
            lost = self._lost.setdefault(key, set())
            freed = 0
            for p in targets:
                for b in parts.pop(p).values():
                    self.resident_bytes[b.node] = \
                        self.resident_bytes.get(b.node, 0) - b.nbytes
                    freed += b.nbytes
                lost.add(p)
            if not lost:
                del self._lost[key]
            if not parts:
                del self._stages[key]
                self._sealed.pop(key, None)
            if freed:
                self.app_bytes[app] = self.app_bytes.get(app, 0) - freed
                get_tracer().count(f"store_bytes/{app}", self.app_bytes[app])
                self._cond.notify_all()     # wake quota-blocked writers
            return freed

    def clear_lost(self, app: str, stage: str,
                   partitions: Sequence[int] | None = None) -> None:
        """Drop lost tombstones after recovery re-executed the producers:
        any partition still absent is now *genuinely* empty (its producers
        wrote nothing), not missing."""
        with self._lock:
            key = (app, stage)
            lost = self._lost.get(key)
            if lost is None:
                return
            if partitions is None:
                del self._lost[key]
                return
            lost.difference_update(partitions)
            if not lost:
                del self._lost[key]

    def lost_partitions(self, app: str, stage: str) -> set[int]:
        with self._lock:
            return set(self._lost.get((app, stage), set()))

    def delete_stage(self, app: str, stage: str) -> int:
        """Drop a stage's blobs *and* its lost tombstones — intentional
        teardown, not failure; returns bytes reclaimed (ephemerality is the
        point: shuffle state outlives only its consumers)."""
        with self._cond:
            parts = self._stages.pop((app, stage), {})
            self._sealed.pop((app, stage), None)
            self._lost.pop((app, stage), None)
            freed = 0
            for blobs in parts.values():
                for b in blobs.values():
                    self.resident_bytes[b.node] = \
                        self.resident_bytes.get(b.node, 0) - b.nbytes
                    freed += b.nbytes
            if freed:
                self.app_bytes[app] = self.app_bytes.get(app, 0) - freed
                get_tracer().count(f"store_bytes/{app}", self.app_bytes[app])
                self._cond.notify_all()     # wake quota-blocked writers
            return freed

    def clear_app(self, app: str) -> int:
        freed = 0
        with self._cond:
            for key in [k for k in self._stages if k[0] == app]:
                freed += self.delete_stage(*key)
            for key in [k for k in self._lost if k[0] == app]:
                del self._lost[key]    # fully-lost stages have no blobs left
        return freed
