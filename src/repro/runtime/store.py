"""Ephemeral object store for externalized intermediate state.

Serverless functions are stateless; every byte exchanged between stages goes
through an external store (the Lambada/Pocket model adopted by the paper's
substrate). Blobs are keyed ``(app, stage, partition)``; multiple writers may
append slices to the same partition (that *is* the shuffle), each under its
own writer label so a retried (preempted) invocation overwrites its previous
slice instead of duplicating it.

The store keeps per-node byte accounting — bytes resident per home node,
bytes served cross-node per source, bytes read per reader — so shuffle
volumes feed straight back into ``DataDist`` for the decision workflows
(paper Fig. 5 step 4: runtime knowledge flows back into decision nodes).

Storage is tiered (``repro.runtime.storage``): a *primary* backend holds
hot writes (memory by default — zero-copy, today's behavior; disk or the
emulated object store can serve as primary for cold-path testing), and
optional colder *spill* backends hold demoted stages. Under quota pressure
a sealed stage with a spill policy is demoted — serialized into the colder
tier, hot bytes freed, still readable — instead of tombstoned; reads of
demoted blobs go through the backend (latency/bandwidth emulated outside
the lock, dollar cost billed per app) and transparently promote back into
memory when quota headroom allows.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Mapping, Sequence

from repro.core.decisions import DataDist, partition_skew
from repro.obs.tracer import get_tracer
from repro.runtime.storage import make_backend


@dataclass
class Blob:
    """One written slice of a partition: payload (or backend key) plus its
    home node. Hot zero-copy blobs hold ``table``; spilled / keyed blobs
    hold ``key`` into ``tier``'s backend and ``table is None``."""

    table: object            # repro.analytics.table.Table (duck-typed)
    node: int
    nbytes: int
    rows: int
    tier: str = "memory"
    key: str | None = None


class QuotaExceededError(RuntimeError):
    """A write could not be admitted under the application's store quota."""


class PrefetchHandle:
    """A fetch running on a background thread (double-buffered reads).

    ``join`` blocks until the thunk finishes and returns its result,
    re-raising whatever it raised — so a lost-stage tombstone surfaces to
    the consumer at join time exactly as a direct read would. The worker is
    a daemon: a handle abandoned by a crashed invocation never blocks
    shutdown, and its store accounting already happened in the worker (a
    retry's own reads come on top, same as a retried direct read).
    """

    def __init__(self, fn):
        self._result = None
        self._exc: BaseException | None = None
        self._thread = threading.Thread(target=self._run, args=(fn,),
                                        daemon=True)
        self._thread.start()

    def _run(self, fn) -> None:
        try:
            self._result = fn()
        except BaseException as e:   # re-raised at join()
            self._exc = e

    def done(self) -> bool:
        return not self._thread.is_alive()

    def join(self):
        self._thread.join()
        if self._exc is not None:
            raise self._exc
        return self._result


class StageLostError(RuntimeError):
    """A read hit shuffle data that *was* written but has since been lost.

    Ephemeral storage may evict consumed stages (quota pressure), the
    executor reclaims ephemeral inputs, and a fault plan may kill stage data
    outright — in all three cases the store leaves a *lost tombstone* per
    evicted partition, so a later reader sees a typed error instead of a
    silent ``None`` (which would corrupt the query). The executor catches
    this error and triggers lineage-based recompute of the lost partitions'
    producer invocations (``repro.runtime.lineage``).
    """

    def __init__(self, app: str, stage: str, partitions=None):
        self.app = app
        self.stage = stage
        self.partitions = tuple(partitions) if partitions is not None \
            else None
        which = "all partitions" if self.partitions is None \
            else f"partitions {list(self.partitions)}"
        super().__init__(
            f"stage {app!r}/{stage!r}: {which} lost (evicted or failed) "
            f"after being written")


class ShuffleStore:
    """Thread-safe ephemeral blob store with per-node byte accounting.

    Lifecycle is per-(app, stage): ``delete_stage`` reclaims a stage as soon
    as its consumers finish, ``clear_app`` tears down a whole query's state.

    Multi-tenant sharing: ``quotas`` caps each application's live footprint
    in the *primary* tier. An over-quota write first reclaims the app's own
    *sealed* stages (consumed-ephemeral state the executor hands back via
    ``reclaim_stage``) — demoting them to a colder backend when a spill
    policy names one, tombstoning them otherwise — then blocks awaiting
    concurrent frees, and finally raises ``QuotaExceededError`` after
    ``quota_timeout`` seconds. ``app_bytes``/``peak_bytes`` expose per-app
    hot live/high-water footprints; ``tier_bytes`` the demoted footprint
    per cold tier; ``storage_cost`` the per-app dollars billed by priced
    backends (the emulated object store).

    ``net_bw`` (bytes/s) optionally emulates the transfer cost: cross-node
    reads block for ``bytes / net_bw`` seconds *outside* the store lock, so
    under a parallel invoker transfers overlap with other stages' compute —
    the first-order cost the discrete-event simulator prices with its NIC
    contention model. With ``disaggregated=True`` the store behaves like the
    fully external storage tier of Lambada/Pocket: *every* read and write is
    charged at ``net_bw``, node-locality earns no discount. ``None``
    (default) keeps all store traffic instantaneous.
    """

    def __init__(self, net_bw: float | None = None,
                 disaggregated: bool = False,
                 quotas: Mapping[str, int] | None = None,
                 quota_timeout: float = 10.0,
                 backend="memory",
                 spill_backends: Sequence | None = None):
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self.net_bw = net_bw
        self.disaggregated = disaggregated
        # (app, stage) -> partition -> writer -> Blob
        self._stages: dict[tuple[str, str], dict[int, dict[str, Blob]]] = {}
        self.resident_bytes: dict[int, int] = {}   # node -> hot blob bytes
        self.written_bytes: dict[int, int] = {}    # node -> cumulative writes
        self.read_bytes: dict[int, int] = {}       # reader node -> bytes read
        self.sent_bytes: dict[int, int] = {}       # source node -> remote reads
        self.cross_node_bytes = 0                  # total shuffle traffic
        # -- storage tiers ---------------------------------------------------
        self._hot = make_backend(backend)
        self._backends = {self._hot.tier: self._hot}
        for b in (spill_backends or ()):
            cold = make_backend(b)
            self._backends[cold.tier] = cold
        self.tier_bytes: dict[str, dict[str, int]] = {}  # tier -> app -> bytes
        self.storage_cost: dict[str, float] = {}         # app -> dollars
        self.demotions: list[tuple[str, str, str, int]] = []
        self.promotions: list[tuple[str, str, int, str, int]] = []
        # app -> {data_stage: cold tier} — the tiering decision's output;
        # reclaim/evict demote these stages instead of tombstoning them
        self._spill: dict[str, dict[str, str]] = {}
        # -- per-application memory quotas (multi-tenant sharing) ------------
        self._quotas: dict[str, int] = dict(quotas or {})
        self.quota_timeout = quota_timeout
        self.app_bytes: dict[str, int] = {}        # app -> hot live bytes
        self.peak_bytes: dict[str, int] = {}       # app -> high-water mark
        # sealed stages: consumed-ephemeral state, readable until quota
        # pressure reclaims it (insertion order == LRU eviction order)
        self._sealed: dict[tuple[str, str], bool] = {}
        self.evictions: list[tuple[str, str, int]] = []
        # lost tombstones: (app, stage) -> {partition id: writer labels
        # still owed}. The owed set is snapshotted at loss time so a
        # partition only heals once EVERY writer that had contributed a
        # slice has re-written it — healing on the first re-write would let
        # a concurrent reader see a partial (subset-of-writers) concat
        # mid-recovery. Reads raise StageLostError until the partition
        # heals or recovery clears the marker.
        self._lost: dict[tuple[str, str], dict[int, set[str]]] = {}
        # fault-injection hook: consulted at the top of every ``get`` so a
        # FaultPlan can lose a stage deterministically on its k-th read
        self.injector = None

    # -- tiers ----------------------------------------------------------------

    @staticmethod
    def _key(app: str, stage: str, partition: int, writer: str) -> str:
        return f"{app}/{stage}/{partition}/{writer}"

    def storage_spec(self) -> dict[str, dict]:
        """Spec of every tier colder than the primary — the cost model the
        tiering decision node prices (on runtime and simulator alike)."""
        return {name: b.spec() for name, b in self._backends.items()
                if b.order > self._hot.order}

    def set_spill_policy(self, app: str,
                         plan: Mapping[str, str] | None) -> None:
        """Install the tiering decision's per-stage plan: entries naming a
        colder backend make ``reclaim_stage``/eviction demote that stage;
        ``"evict"``/``"keep"``/unknown tiers fall back to today's
        tombstone behavior."""
        with self._lock:
            tiers = {s: t for s, t in dict(plan or {}).items()
                     if t in self._backends
                     and self._backends[t].order > self._hot.order}
            if tiers:
                self._spill[app] = tiers
            else:
                self._spill.pop(app, None)

    def spill_policy(self, app: str) -> dict[str, str]:
        with self._lock:
            return dict(self._spill.get(app, {}))

    def app_tier_bytes(self, app: str) -> dict[str, int]:
        """Live bytes per tier for one app (primary tier under its own
        name), for benchmarks and tests."""
        with self._lock:
            out = {self._hot.tier: self.app_bytes.get(app, 0)}
            for tier, per_app in self.tier_bytes.items():
                out[tier] = out.get(tier, 0) + per_app.get(app, 0)
            return out

    def close(self) -> None:
        """Release backend resources (spill tempdirs, emulated buffers)."""
        for b in self._backends.values():
            b.close()

    # -- quotas ---------------------------------------------------------------

    def set_quota(self, app: str, limit: int | None) -> None:
        """Cap an application's hot live footprint at ``limit`` bytes
        (``None`` removes the cap). Writes over the cap first reclaim the
        app's own sealed stages, then block awaiting concurrent frees, then
        raise ``QuotaExceededError`` after ``quota_timeout`` seconds."""
        with self._cond:
            if limit is None:
                self._quotas.pop(app, None)
            else:
                self._quotas[app] = int(limit)
            self._cond.notify_all()

    def quota(self, app: str) -> int | None:
        with self._lock:
            return self._quotas.get(app)

    def _evict_one(self, app: str,
                   exclude: str | None = None) -> tuple[int, float]:
        """Reclaim the app's least-recently-sealed stage; caller holds the
        lock. ``exclude`` names the in-flight write's destination stage,
        which must never evict itself (it would tombstone peer writers'
        committed partitions just to admit one more slice). Stages with a
        spill policy demote to their cold tier; others leave lost
        tombstones (recoverable via lineage), never silently-empty data.
        Returns (bytes freed, emulated backend seconds to pay outside the
        lock)."""
        for key in list(self._sealed):
            if key[0] != app:
                continue
            if exclude is not None and key[1] == exclude:
                continue
            tier = self._spill.get(app, {}).get(key[1])
            if tier is not None and tier in self._backends \
                    and self._backends[tier].order > self._hot.order:
                freed, pending = self._demote_stage_locked(key[0], key[1],
                                                           tier)
                if freed == 0:
                    continue     # already cold: no hot progress, next stage
                self.demotions.append((key[0], key[1], tier, freed))
                return freed, pending
            freed = self.lose_stage(*key)
            self.evictions.append((key[0], key[1], freed))
            return freed, 0.0
        return 0, 0.0

    def _admit(self, app: str, stage: str,
               items: Sequence[tuple[int, str, int]]) -> float:
        """Block (under the lock, via the condition) until the whole batch
        of ``(partition, writer, nbytes)`` slices fits the app's quota,
        reclaiming sealed stages first. Admission is all-or-nothing: a
        refused batch leaves accounting untouched (no partial commits).
        Caller holds the lock. Returns emulated backend seconds incurred
        by admission-path demotions, to pay outside the lock."""
        pending = 0.0
        deadline = None
        while True:
            limit = self._quotas.get(app)
            if limit is None:
                return pending
            parts = self._stages.get((app, stage), {})
            delta = 0
            total = 0
            for partition, writer, nbytes in items:
                old = parts.get(partition, {}).get(writer)
                # only a replaced *hot* slice returns quota headroom; a
                # demoted old slice holds no hot bytes to retract
                if old is not None and old.tier == self._hot.tier:
                    delta += nbytes - old.nbytes
                else:
                    delta += nbytes
                total += nbytes
            if delta <= 0:
                # replacing with a smaller footprint always shrinks hot
                # pressure — admit even if the app is already over quota
                # (e.g. the cap was lowered after the original write)
                return pending
            if self.app_bytes.get(app, 0) + delta <= limit:
                return pending
            if delta > limit:
                # permanently unsatisfiable: even with every other byte of
                # the app freed this batch cannot fit — fail fast instead
                # of pinning the slot for quota_timeout. Report the raw
                # write size AND the net delta: on the replace path the
                # delta (after retracting the replaced slices) is what the
                # quota actually refused.
                raise QuotaExceededError(
                    f"app {app!r}: write of {total} bytes "
                    f"({len(items)} slice(s), net delta {delta} after "
                    f"retracting replaced slices) to stage {stage!r} "
                    f"can never fit quota {limit}")
            freed, sleep = self._evict_one(app, exclude=stage)
            pending += sleep
            if freed:
                continue
            now = time.monotonic()
            if deadline is None:
                deadline = now + self.quota_timeout
            if now >= deadline:
                raise QuotaExceededError(
                    f"app {app!r}: write of {total} bytes "
                    f"(net delta {delta}) to stage {stage!r} exceeds "
                    f"quota {limit} "
                    f"(live {self.app_bytes.get(app, 0)} bytes, nothing "
                    f"sealed to evict, no free within "
                    f"{self.quota_timeout}s)")
            self._cond.wait(deadline - now)

    # -- writes ---------------------------------------------------------------

    def _retract_locked(self, app: str, old: Blob) -> tuple[int, int]:
        """Remove one blob's accounting and backend payload; caller holds
        the lock. Returns ``(hot_bytes, cold_bytes)`` freed."""
        hot = old.tier == self._hot.tier
        if hot:
            self.resident_bytes[old.node] = \
                self.resident_bytes.get(old.node, 0) - old.nbytes
            self.app_bytes[app] = \
                self.app_bytes.get(app, 0) - old.nbytes
        else:
            tb = self.tier_bytes.setdefault(old.tier, {})
            tb[app] = tb.get(app, 0) - old.nbytes
        if old.key is not None:
            self._backends[old.tier].delete(old.key)
        return (old.nbytes, 0) if hot else (0, old.nbytes)

    def _insert_locked(self, app: str, stage: str, partition: int, table,
                       node: int, writer: str, nbytes: int, rows: int,
                       tier: str | None = None) -> float:
        """Insert one already-admitted writer slice; caller holds the lock.
        ``tier`` routes the payload to a cold backend directly (seeded
        cold data, never counted against the hot quota); ``None`` writes
        to the primary. Returns emulated backend seconds to pay outside
        the lock."""
        lost = self._lost.get((app, stage))
        if lost is not None and partition in lost:
            # a producer (retry, speculation backup, lineage recompute)
            # rewriting a lost partition heals it — but only once every
            # writer whose slice was lost has re-written, else a reader
            # racing the recovery sees a partial concat
            owed = lost[partition]
            owed.discard(writer)
            if not owed:
                del lost[partition]
                if not lost:
                    del self._lost[(app, stage)]
        parts = self._stages.setdefault((app, stage), {})
        blobs = parts.setdefault(partition, {})
        old = blobs.get(writer)
        if old is not None:   # preempted attempt being re-done: retract it
            self._retract_locked(app, old)
        target = self._hot if tier is None or tier == self._hot.tier \
            else self._backends[tier]
        pending = 0.0
        blob = Blob(None, node, nbytes, rows, tier=target.tier)
        if target.zero_copy and target is self._hot:
            blob.table = table
        else:
            blob.key = self._key(app, stage, partition, writer)
            target.put_table(blob.key, table)
            if writer != "seed":   # seeded data pre-exists: no write bill
                cost = target.request_cost(nbytes)
                if cost:
                    self.storage_cost[app] = \
                        self.storage_cost.get(app, 0.0) + cost
                pending += target.io_seconds(nbytes, "put")
        blobs[writer] = blob
        self.written_bytes[node] = self.written_bytes.get(node, 0) + nbytes
        if target is self._hot:
            self.resident_bytes[node] = \
                self.resident_bytes.get(node, 0) + nbytes
            self.app_bytes[app] = self.app_bytes.get(app, 0) + nbytes
            self.peak_bytes[app] = max(self.peak_bytes.get(app, 0),
                                       self.app_bytes[app])
            get_tracer().count(f"store_bytes/{app}", self.app_bytes[app])
        else:
            tb = self.tier_bytes.setdefault(target.tier, {})
            tb[app] = tb.get(app, 0) + nbytes
        return pending

    def _put_locked(self, app: str, stage: str, partition: int, table,
                    node: int, writer: str, nbytes: int, rows: int,
                    tier: str | None = None) -> float:
        """Admission + insert of one writer slice; caller holds the lock
        (``_admit`` may block on the condition, releasing it while waiting).
        Returns emulated backend seconds to pay outside the lock."""
        pending = 0.0
        if tier is None or tier == self._hot.tier:
            pending += self._admit(app, stage, [(partition, writer, nbytes)])
            tier = None
        return pending + self._insert_locked(app, stage, partition, table,
                                             node, writer, nbytes, rows,
                                             tier=tier)

    def put(self, app: str, stage: str, partition: int, table, node: int,
            writer: str = "", tier: str | None = None) -> int:
        """Write (or, on retry, replace) one writer's slice of a partition.
        ``tier`` names a cold backend to seed directly (bypasses the hot
        quota — the data never occupies memory). Returns the bytes written.
        """
        tr = get_tracer()
        t0 = time.perf_counter() if tr.enabled else 0.0
        nbytes, rows = int(table.nbytes), int(table.num_rows)
        with self._cond:
            pending = self._put_locked(app, stage, partition, table, node,
                                       writer, nbytes, rows, tier=tier)
        if pending:
            time.sleep(pending)
        # the emulated disaggregated transfer is charged only AFTER quota
        # admission succeeds: a write rejected by the quota (or blocked on
        # eviction) must not pay the transfer once per failed attempt, which
        # would inflate store_seconds and the critical-path store split
        if self.disaggregated and self.net_bw and writer != "seed":
            time.sleep(nbytes / self.net_bw)
        if tr.enabled:
            tr.record(f"put/{stage}", "store", t0, trace=app, node=node,
                      partition=partition, bytes=nbytes)
        return nbytes

    def put_many(self, app: str, stage: str, tables: Mapping[int, object],
                 node: int, writer: str = "") -> int:
        """Write one writer's slices of *many* partitions in a single store
        round trip — the columnar-slice shuffle path: the producer computes
        every bucket in one device pass and publishes them all at once
        (typically ``TableSlice`` views sharing one parent buffer).

        Quota admission covers the batch *total* up front, so a refused
        batch leaves no partial commits behind (accounting, tombstones, and
        the skipped transfer charge all stay untouched). Per-partition byte
        accounting and lost-tombstone healing are identical to
        ``partition``-at-a-time ``put``; the disaggregated transfer charge
        is one sleep for the total bytes (one flow, not P serialized ones).
        Returns total bytes written.
        """
        tr = get_tracer()
        t0 = time.perf_counter() if tr.enabled else 0.0
        sized = [(int(p), t, int(t.nbytes), int(t.num_rows))
                 for p, t in sorted(tables.items())]
        total = sum(nb for _, _, nb, _ in sized)
        with self._cond:
            pending = self._admit(app, stage,
                                  [(p, writer, nb) for p, _, nb, _ in sized])
            for partition, table, nbytes, rows in sized:
                pending += self._insert_locked(app, stage, partition, table,
                                               node, writer, nbytes, rows)
        if pending:
            time.sleep(pending)
        # transfer charged after admission (see ``put``): a quota rejection
        # mid-batch pays nothing for the flow it never completed
        if self.disaggregated and self.net_bw and writer != "seed" and total:
            time.sleep(total / self.net_bw)
        if tr.enabled:
            tr.record(f"put_many/{stage}", "store", t0, trace=app, node=node,
                      partitions=len(sized), bytes=total)
        return total

    def ingest(self, app: str, stage: str, partitions,
               tier: str | None = None) -> list[tuple[int, int]]:
        """Seed base data: a ``{node: table}`` mapping (one partition per
        home node, the classic layout) or a ``[(node, table), ...]``
        sequence (several partitions per node — the fine-grained layout the
        batched map path coalesces). ``tier`` seeds straight into a cold
        backend — the Lambada cold-data scenario: inputs start in the
        object store, first-touch scans read (and promote) through it.

        Returns ``[(partition_index, home_node), ...]`` in index order — the
        planner's view of where the input lives.
        """
        pairs = sorted(partitions.items()) if hasattr(partitions, "items") \
            else list(partitions)
        layout = []
        for idx, (node, table) in enumerate(pairs):
            self.put(app, stage, idx, table, node, writer="seed", tier=tier)
            layout.append((idx, node))
        return layout

    def stage_layout(self, app: str, stage: str) -> list[tuple[int, int]]:
        """``[(partition, home_node), ...]`` of a stage already in the
        store — lets a re-query reuse seeded inputs instead of
        re-ingesting them (the warm half of the cold-data scenario)."""
        with self._lock:
            parts = self._stages.get((app, stage), {})
            return [(p, next(iter(parts[p].values())).node)
                    for p in sorted(parts)]

    # -- reads ----------------------------------------------------------------

    def get(self, app: str, stage: str, partition: int, node: int,
            account: bool = True, writers: Sequence[str] | None = None):
        """Concatenate every writer's slice of a partition (writer-sorted, so
        content is deterministic under concurrent invokers). Remote reads are
        charged to the blob's home node — this is the shuffle/broadcast
        traffic the simulator's NIC model prices. Demoted slices read
        through their backend (emulated latency/bandwidth outside the lock,
        dollar cost billed) and transparently promote back into memory when
        quota headroom allows. ``writers`` restricts the read to that subset
        of writer labels (the skew node's writer-sharded sub-joins each pull
        only their share of a heavy bucket); only the fetched slices are
        accounted and charged. Returns None if absent; raises
        ``StageLostError`` if the partition was written and then
        evicted/killed (the reader must never see silently-missing data)."""
        tr = get_tracer()
        if not tr.enabled:
            return self._get_impl(app, stage, partition, node, account,
                                  writers)
        t0 = time.perf_counter()
        try:
            t = self._get_impl(app, stage, partition, node, account, writers)
        except StageLostError:
            tr.record(f"get/{stage}", "store", t0, trace=app, node=node,
                      partition=partition, status="lost")
            raise
        tr.record(f"get/{stage}", "store", t0, trace=app, node=node,
                  partition=partition,
                  bytes=int(t.nbytes) if t is not None else 0,
                  status="ok" if t is not None else "miss")
        return t

    def get_async(self, app: str, stage: str, partition: int, node: int,
                  account: bool = True,
                  writers: Sequence[str] | None = None) -> PrefetchHandle:
        """``get`` on a background thread — the double-buffered read used by
        the pipelined data plane (fetch bucket k+1 while probing bucket k).
        Accounting and fault hooks run in the worker, once."""
        return PrefetchHandle(
            lambda: self.get(app, stage, partition, node, account, writers))

    def _get_impl(self, app: str, stage: str, partition: int, node: int,
                  account: bool = True,
                  writers: Sequence[str] | None = None):
        remote = 0
        hot_tier = self._hot.tier
        with self._lock:
            if self.injector is not None:
                # fault-injection: a plan may lose this stage right now (the
                # k-th read) — the lost check below then raises
                self.injector.on_get(app, stage, partition, node)
            # the tombstone check must come *before* the presence check: a
            # recovering partition repopulates writer-by-writer, so blobs can
            # be non-empty (a partial subset) while still owed — reading it
            # would concat a subset of the writers' slices
            lost = self._lost.get((app, stage))
            if lost and partition in lost:
                raise StageLostError(app, stage, (partition,))
            blobs = self._stages.get((app, stage), {}).get(partition)
            if not blobs:
                return None
            names = sorted(blobs) if writers is None else \
                [w for w in sorted(blobs) if w in writers]
            if not names:
                return None
            # snapshot under the lock; backend fetches happen outside it
            snap = [(w, blobs[w], blobs[w].table, blobs[w].tier,
                     blobs[w].key, blobs[w].nbytes, blobs[w].node)
                    for w in names]
            if account:
                for _, _, _, tier, _, nb, home in snap:
                    self.read_bytes[node] = \
                        self.read_bytes.get(node, 0) + nb
                    # cold reads are backend traffic, not node-to-node
                    # shuffle: they pay the backend's cost model instead
                    if tier == hot_tier and home != node:
                        remote += nb
                        self.sent_bytes[home] = \
                            self.sent_bytes.get(home, 0) + nb
                        self.cross_node_bytes += nb
        backend_sleep = 0.0
        tables = []
        candidates = []      # cold blobs eligible for promotion
        for w, b, tbl, tier, key, nb, _ in snap:
            if tbl is not None:
                tables.append(tbl)
                continue
            backend = self._backends[tier]
            try:
                t = backend.get_table(key)
            except KeyError:
                # the payload vanished between snapshot and fetch
                # (concurrent loss/teardown): surface as a lost stage, the
                # same contract the chaos suites already hold reads to
                raise StageLostError(app, stage, (partition,)) from None
            if account:
                cost = backend.request_cost(nb)
                if cost:
                    with self._lock:
                        self.storage_cost[app] = \
                            self.storage_cost.get(app, 0.0) + cost
                backend_sleep += backend.io_seconds(nb, "get")
            tables.append(t)
            if account and tier != hot_tier and self._hot.zero_copy:
                candidates.append((w, b, t, tier, key, nb))
        promoted = 0
        for w, b, t, tier, key, nb in candidates:
            promoted += self._promote_one(app, stage, partition, w, b, t,
                                          tier, key, nb)
        if promoted:
            tr = get_tracer()
            if tr.enabled:
                tr.record(f"promote/{stage}", "store", time.perf_counter(),
                          trace=app, partition=partition, bytes=promoted)
        hot_bytes = sum(nb for _, _, _, tier, _, nb, _ in snap
                        if tier == hot_tier)
        charged = hot_bytes if self.disaggregated else remote
        delay = backend_sleep
        if account and charged and self.net_bw:
            delay += charged / self.net_bw
        if delay:
            time.sleep(delay)
        from repro.analytics.table import Table
        return Table.concat_all(tables)

    def _promote_one(self, app: str, stage: str, partition: int, writer: str,
                     blob: Blob, table, tier: str, key: str,
                     nbytes: int) -> int:
        """Best-effort promotion of one fetched cold blob back into the
        hot tier — only when it fits the quota without evicting anything
        (promotion must never steal headroom from live writes). Returns
        bytes promoted (0 if skipped)."""
        with self._cond:
            cur = self._stages.get((app, stage), {}) \
                .get(partition, {}).get(writer)
            if cur is not blob or cur.tier != tier:
                return 0       # replaced or already moved by a peer reader
            limit = self._quotas.get(app)
            if limit is not None \
                    and self.app_bytes.get(app, 0) + nbytes > limit:
                return 0
            self._backends[tier].delete(key)
            blob.table = table
            blob.key = None
            blob.tier = self._hot.tier
            tb = self.tier_bytes.setdefault(tier, {})
            tb[app] = tb.get(app, 0) - nbytes
            self.resident_bytes[blob.node] = \
                self.resident_bytes.get(blob.node, 0) + nbytes
            self.app_bytes[app] = self.app_bytes.get(app, 0) + nbytes
            self.peak_bytes[app] = max(self.peak_bytes.get(app, 0),
                                       self.app_bytes[app])
            self.promotions.append((app, stage, partition, tier, nbytes))
            get_tracer().count(f"store_bytes/{app}", self.app_bytes[app])
            return nbytes

    def partitions(self, app: str, stage: str) -> list[int]:
        """Known partition ids: written ∪ lost. Lost ids are included so an
        all-partitions reader (``FnContext.get_all``) hits the tombstone and
        raises instead of silently skipping evicted data."""
        with self._lock:
            return sorted(set(self._stages.get((app, stage), {})) |
                          set(self._lost.get((app, stage), set())))

    def partition_state(self, app: str, stage: str,
                        ) -> tuple[set[int], set[int]]:
        """``(written, lost)`` partition-id sets — the residency view the
        lineage recovery planner consults. Demoted partitions count as
        written: they are still readable (through their backend)."""
        with self._lock:
            return (set(self._stages.get((app, stage), {})),
                    set(self._lost.get((app, stage), set())))

    # -- accounting views ------------------------------------------------------

    def stage_bytes(self, app: str, stage: str) -> int:
        with self._lock:
            return sum(b.nbytes
                       for part in self._stages.get((app, stage), {}).values()
                       for b in part.values())

    def read_sources(self, app: str, stage: str, partition: int,
                     reader: int,
                     writers: Sequence[str] | None = None) -> dict[int, int]:
        """Bytes this partition would pull per remote source node (for trace
        replay into the simulator's transfer model). Demoted blobs are
        excluded — their reads are backend traffic, not node-to-node
        transfers. ``writers`` restricts to that subset of writer labels,
        mirroring a writer-sharded ``get``. Does not account."""
        with self._lock:
            blobs = self._stages.get((app, stage), {}).get(partition, {})
            out: dict[int, int] = {}
            for w, b in blobs.items():
                if writers is not None and w not in writers:
                    continue
                if b.tier != self._hot.tier or b.node == reader:
                    continue
                out[b.node] = out.get(b.node, 0) + b.nbytes
            return out

    def data_dist(self, app: str, stage: str, name: str | None = None,
                  ) -> DataDist:
        """The stage's output distribution, ready for a DecisionContext."""
        with self._lock:
            parts = self._stages.get((app, stage), {})
            per_node: dict[int, int] = {}
            rows_per_part = []
            total_rows = 0
            for blobs in parts.values():
                rows_per_part.append(sum(b.rows for b in blobs.values()))
                for b in blobs.values():
                    per_node[b.node] = per_node.get(b.node, 0) + b.nbytes
                    total_rows += b.rows
        return DataDist(name or f"{app}/{stage}", per_node,
                        rows=total_rows, skew=partition_skew(rows_per_part))

    # -- lifecycle -------------------------------------------------------------

    def seal(self, app: str, stage: str) -> None:
        """Mark a stage reclaimable: its consumers are done, reads still
        work, and quota pressure may evict it (LRU by seal order)."""
        with self._cond:
            if (app, stage) in self._stages:
                self._sealed[(app, stage)] = True
                self._cond.notify_all()     # blocked writers can now evict

    def drop_sealed(self, app: str) -> int:
        """Drop every sealed stage of an app — end-of-query GC parity with
        the quota-less eager-delete path. Returns bytes freed."""
        with self._cond:
            freed = 0
            for key in [k for k in self._sealed if k[0] == app]:
                freed += self.delete_stage(*key)
            return freed

    def _demote_stage_locked(self, app: str, stage: str,
                             tier: str) -> tuple[int, float]:
        """Move a stage's hot blobs into a colder backend: hot bytes are
        freed, the data stays readable (read-through + promote). Caller
        holds the lock; serialization happens under it (demotion runs on
        the reclaim/eviction path, never a hot read). Returns (hot bytes
        freed, emulated backend seconds to pay outside the lock)."""
        backend = self._backends[tier]
        t0 = time.perf_counter()
        freed = 0
        pending = 0.0
        moved = 0
        for partition, blobs in self._stages.get((app, stage), {}).items():
            for writer, b in blobs.items():
                if b.tier != self._hot.tier:
                    continue
                key = self._key(app, stage, partition, writer)
                payload = b.table if b.table is not None \
                    else self._hot.get_table(b.key)
                backend.put_table(key, payload)
                if b.key is not None:
                    self._hot.delete(b.key)
                b.table = None
                b.key = key
                b.tier = tier
                self.resident_bytes[b.node] = \
                    self.resident_bytes.get(b.node, 0) - b.nbytes
                self.app_bytes[app] = \
                    self.app_bytes.get(app, 0) - b.nbytes
                tb = self.tier_bytes.setdefault(tier, {})
                tb[app] = tb.get(app, 0) + b.nbytes
                cost = backend.request_cost(b.nbytes)
                if cost:
                    self.storage_cost[app] = \
                        self.storage_cost.get(app, 0.0) + cost
                pending += backend.io_seconds(b.nbytes, "put")
                freed += b.nbytes
                moved += 1
        if freed:
            tr = get_tracer()
            tr.count(f"store_bytes/{app}", self.app_bytes.get(app, 0))
            if tr.enabled:
                tr.record(f"spill/{stage}", "store", t0, trace=app,
                          tier=tier, bytes=freed, partitions=moved)
            self._cond.notify_all()     # wake quota-blocked writers
        return freed, pending

    def demote_stage(self, app: str, stage: str, tier: str) -> int:
        """Spill a stage's hot blobs to ``tier`` (see
        ``_demote_stage_locked``). Returns hot bytes freed."""
        with self._cond:
            freed, pending = self._demote_stage_locked(app, stage, tier)
            if freed:
                self.demotions.append((app, stage, tier, freed))
        if pending:
            time.sleep(pending)
        return freed

    def reclaim_stage(self, app: str, stage: str) -> int:
        """Ephemeral-input GC entry point for the executor. With a spill
        policy for this stage, its blobs demote to the chosen cold tier
        (readable, recoverable, zero hot bytes) and the stage is sealed
        for end-of-query GC. Otherwise: under a quota the stage is sealed
        (lazily evicted when the app needs headroom); without one it is
        dropped immediately — leaving a lost tombstone, so a late reader
        (speculation loser, recovery replay) gets a typed
        ``StageLostError`` rather than silently-empty data. Returns hot
        bytes freed now."""
        pending = 0.0
        with self._cond:
            choice = self._spill.get(app, {}).get(stage)
            if choice is not None and choice in self._backends \
                    and self._backends[choice].order > self._hot.order:
                freed, pending = self._demote_stage_locked(app, stage,
                                                           choice)
                if freed:
                    self.demotions.append((app, stage, choice, freed))
                self.seal(app, stage)
            elif self._quotas.get(app) is not None:
                self.seal(app, stage)
                freed = 0
            else:
                freed = self.lose_stage(app, stage)
        if pending:
            time.sleep(pending)
        return freed

    def lose_stage(self, app: str, stage: str,
                   partitions: Sequence[int] | None = None) -> int:
        """Evict written shuffle data (all partitions, or just
        ``partitions``) and leave lost tombstones: later reads of the
        evicted partitions raise ``StageLostError`` until a producer
        rewrites them. This is the store half of the fault model — stage
        loss of disaggregated ephemeral storage (ServerMix's core tension)
        — and of ephemeral-input GC. Demoted blobs lose their backend
        payload too (a lost spilled stage recovers via lineage like any
        other). Returns bytes freed."""
        with self._cond:
            key = (app, stage)
            parts = self._stages.get(key)
            if not parts:
                return 0
            targets = sorted(parts) if partitions is None else \
                [p for p in partitions if p in parts]
            lost = self._lost.setdefault(key, {})
            hot_freed = cold_freed = 0
            for p in targets:
                blobs = parts.pop(p)
                for b in blobs.values():
                    h, c = self._retract_locked(app, b)
                    hot_freed += h
                    cold_freed += c
                # remember which writers' slices vanished: the partition
                # only heals once all of them have re-written
                lost.setdefault(p, set()).update(blobs)
            if not lost:
                del self._lost[key]
            if not parts:
                del self._stages[key]
                self._sealed.pop(key, None)
            if hot_freed:
                get_tracer().count(f"store_bytes/{app}",
                                   self.app_bytes.get(app, 0))
            if hot_freed or cold_freed:
                self._cond.notify_all()     # wake quota-blocked writers
            return hot_freed + cold_freed

    def clear_lost(self, app: str, stage: str,
                   partitions: Sequence[int] | None = None) -> None:
        """Drop lost tombstones after recovery re-executed the producers:
        any partition still absent is now *genuinely* empty (its producers
        wrote nothing), not missing."""
        with self._lock:
            key = (app, stage)
            lost = self._lost.get(key)
            if lost is None:
                return
            if partitions is None:
                del self._lost[key]
                return
            for p in partitions:
                lost.pop(p, None)
            if not lost:
                del self._lost[key]

    def lost_partitions(self, app: str, stage: str) -> set[int]:
        with self._lock:
            return set(self._lost.get((app, stage), set()))

    def delete_stage(self, app: str, stage: str) -> int:
        """Drop a stage's blobs *and* its lost tombstones — intentional
        teardown, not failure; returns bytes reclaimed across all tiers
        (ephemerality is the point: shuffle state outlives only its
        consumers)."""
        with self._cond:
            parts = self._stages.pop((app, stage), {})
            self._sealed.pop((app, stage), None)
            self._lost.pop((app, stage), None)
            hot_freed = cold_freed = 0
            for blobs in parts.values():
                for b in blobs.values():
                    h, c = self._retract_locked(app, b)
                    hot_freed += h
                    cold_freed += c
            if hot_freed:
                get_tracer().count(f"store_bytes/{app}",
                                   self.app_bytes.get(app, 0))
            if hot_freed or cold_freed:
                self._cond.notify_all()     # wake quota-blocked writers
            return hot_freed + cold_freed

    def clear_app(self, app: str) -> int:
        freed = 0
        with self._cond:
            for key in [k for k in self._stages if k[0] == app]:
                freed += self.delete_stage(*key)
            for key in [k for k in self._lost if k[0] == app]:
                del self._lost[key]    # fully-lost stages have no blobs left
            self._spill.pop(app, None)
        return freed
