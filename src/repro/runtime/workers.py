"""The process-backed worker plane: real parallelism, cold-start economics.

Both in-process invokers (``InlineInvoker``, ``ThreadPoolInvoker``) run
function bodies under one GIL, so their "parallelism" is a concurrency
simulation for CPU-bound work. ``ProcessPoolInvoker`` executes bodies in
long-lived **worker subprocesses** — the lithops invoker/worker split, with
Lambada-style burst fan-out economics modeled explicitly:

* **Protocol.** Host and worker speak a pickle task protocol over a duplex
  pipe: the host sends ``("run", task)`` (function name + params + writer
  label), the worker answers with store RPCs (``get``/``partitions`` —
  serviced by the host *inside the invocation span*, so store reads are
  accounted and traced exactly like in-process execution), then
  ``("done", writes, metrics)``. ``Table``/``TableSlice`` payloads are
  serialized to plain numpy column dicts — jax arrays and zero-copy views
  do not cross process boundaries.
* **Buffered writes.** A worker never touches the shuffle store directly:
  its ``put``/``put_many`` calls are buffered worker-side and committed by
  the host only after the body completes — so a worker SIGKILLed
  mid-invocation leaves **no partial store writes**, and quota admission
  (with eviction/retry) stays a host-side concern. Commit happens before
  the injector's ``after_body`` hook, preserving crash-after-write retry
  semantics.
* **Cold starts.** ``WorkerPool`` provisions workers on demand: a cold
  start pays the real subprocess spawn + registry import plus a modeled
  ``provision_s`` floor (the serverless platform's container start). Warm
  idle workers are reused (LIFO — warmest first) and reaped after
  ``idle_reap_s``. The pool bills **function-seconds** (busy wall +
  provision charges) — the cost proxy the elastic benchmark reports.
* **Elasticity.** ``resize(n)`` pre-warms or shrinks the pool; the planner
  drives it from the ``elasticity_node`` decision
  (``repro.core.decisions``), whose twin lives in the cluster simulator so
  decision sequences stay plane-identical.
* **Faults.** A worker that dies mid-invocation (``WorkerKillFault``
  SIGKILL, OOM, a real crash) surfaces as ``WorkerKilledError`` — an
  ``InjectedCrashError`` subclass — so the invoker's existing machinery
  records a crashed attempt, releases the slot claim, and retries on a
  freshly provisioned worker.
"""

from __future__ import annotations

import os
import pickle
import signal
import threading
import time
from typing import Any, Callable, Mapping, Sequence

import multiprocessing as mp

from repro.obs.tracer import get_tracer
from repro.runtime.faults import WorkerKilledError
from repro.runtime.invoker import (FnContext, Invocation, InvocationError,
                                   ThreadPoolInvoker)
from repro.runtime.store import StageLostError


# ---------------------------------------------------------------------------
# Table serialization (host <-> worker)
# ---------------------------------------------------------------------------


def serialize_table(table) -> dict:
    """A ``Table`` / ``TableSlice`` as a plain numpy column dict — the only
    form that crosses the process boundary. Slices materialize first (the
    zero-copy view's parent buffer does not travel)."""
    import numpy as np
    if hasattr(table, "materialize"):
        table = table.materialize()
    return {k: np.asarray(v) for k, v in table.columns.items()}


def deserialize_table(cols: dict):
    from repro.analytics.table import Table
    return Table(dict(cols))


# ---------------------------------------------------------------------------
# Worker side (runs in the subprocess)
# ---------------------------------------------------------------------------


class _TaskAborted(BaseException):
    """Host-initiated abort of the running body (e.g. a store read hit a
    lost-stage tombstone host-side); unwinds the worker's function body
    without being catchable as a normal error."""


class _WorkerSideContext:
    """The ``FnContext`` the function body sees inside a worker: store reads
    are RPCs to the host, writes are buffered locally until the body
    completes. Mirrors the in-process context's interface exactly."""

    def __init__(self, conn, task: dict):
        self._conn = conn
        self.app = task["app"]
        self.node = task["node"]
        self.index = task["index"]
        self.params = dict(task["params"])
        self.writer = task["writer"]
        self.honor_plan = task["honor_plan"]
        self._kill = task.get("kill")
        self.rpc_seconds = 0.0
        self.writes: list = []           # buffered, committed host-side
        self.rows_actual = 0
        self.rows_padded = 0
        self.stats: dict = {}            # marshaled home with the metrics

    @property
    def plan(self) -> str:
        if not self.honor_plan:
            return "barrier"
        return str(self.params.get("plan", "barrier"))

    def _rpc(self, *msg):
        if self._kill == "body":
            # deterministic mid-invocation death: the claim is live, the
            # body has started, nothing has been written
            os.kill(os.getpid(), signal.SIGKILL)
        t0 = time.perf_counter()
        self._conn.send(msg)
        reply = self._conn.recv()
        self.rpc_seconds += time.perf_counter() - t0
        if reply[0] == "abort":
            raise _TaskAborted(reply[1])
        return reply[1]

    def get(self, stage: str, partition: int, writers=None):
        cols = self._rpc("get", str(stage), int(partition),
                         None if writers is None else tuple(writers))
        return None if cols is None else deserialize_table(cols)

    def get_all(self, stage: str):
        from repro.analytics.table import Table
        got = [t for t in (self.get(stage, p)
                           for p in self.partitions(stage))
               if t is not None and t.num_rows]
        return Table.concat_all(got) if got else None

    def partitions(self, stage: str) -> list[int]:
        return list(self._rpc("partitions", str(stage)))

    def prefetch(self, stage: str, partition: int) -> None:
        # double-buffering is a host-side-threads optimization; inside a
        # worker the read order (and thus fault-hook match counts) is
        # preserved by simply reading on demand
        return None

    def put(self, stage: str, partition: int, table) -> None:
        self.writes.append(("put", str(stage), int(partition),
                            serialize_table(table)))

    def put_many(self, stage: str, tables: Mapping[int, Any]) -> None:
        if not tables:
            return
        self.writes.append(("put_many", str(stage),
                            {int(p): serialize_table(t)
                             for p, t in tables.items()}))


def _safe_exc(exc: BaseException):
    """An exception in a pipe-safe form: pickled bytes when possible, else
    ``(type_name, repr)``."""
    try:
        return pickle.dumps(exc)
    except Exception:
        return (type(exc).__name__, repr(exc))


def worker_main(conn, modules: Sequence[str] = ()) -> None:
    """Subprocess entry point: import the function registry (the cold
    start), handshake, then serve tasks until told to stop."""
    for name in modules:
        __import__(name)
    from repro.kernels.ops import padding_counters
    from repro.runtime.functions import FUNCTIONS
    conn.send(("ready", os.getpid()))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return
        if msg[0] == "stop":
            conn.send(("bye",))
            conn.close()
            return
        task = msg[1]
        ctx = _WorkerSideContext(conn, task)
        t0 = time.perf_counter()
        pad0 = padding_counters()
        try:
            fn = FUNCTIONS[task["func"]]
            fn(ctx)
        except _TaskAborted:
            # the host aborted the body (it already has the real error);
            # ack so the pipe is clean for the next task
            conn.send(("aborted",))
            continue
        except BaseException as exc:
            conn.send(("error", _safe_exc(exc),
                       _worker_metrics(ctx, t0, pad0, padding_counters())))
            continue
        if ctx._kill:
            # "late": deterministic post-body death — every write sits in
            # the worker-side buffer and dies with the process (the
            # no-partial-writes invariant's strongest test point). Also the
            # backstop for a "body" kill whose function made no store RPC.
            os.kill(os.getpid(), signal.SIGKILL)
        conn.send(("done", ctx.writes,
                   _worker_metrics(ctx, t0, pad0, padding_counters())))


def _worker_metrics(ctx, t0: float, pad0, pad1) -> dict:
    return {"busy_s": time.perf_counter() - t0,
            "rpc_s": ctx.rpc_seconds,
            "rows_actual": pad1[0] - pad0[0],
            "rows_padded": pad1[1] - pad0[1],
            "stats": dict(ctx.stats),
            "pid": os.getpid()}


# ---------------------------------------------------------------------------
# Host side: the pool and its economics
# ---------------------------------------------------------------------------


class WorkerHandle:
    """One live worker subprocess plus its host-side pipe end."""

    def __init__(self, wid: int, proc, conn, provision_s: float):
        self.id = wid
        self.proc = proc
        self.conn = conn
        self.provision_s = provision_s     # billed cold-start seconds
        self.invocations = 0

    @property
    def pid(self) -> int | None:
        return self.proc.pid

    def stop(self, timeout: float = 2.0) -> None:
        """Graceful stop; escalates to SIGKILL."""
        try:
            self.conn.send(("stop",))
            if self.conn.poll(timeout):
                self.conn.recv()
        except (OSError, EOFError):
            pass
        self.proc.join(timeout)
        if self.proc.is_alive():
            self.proc.kill()
            self.proc.join(timeout)
        try:
            self.conn.close()
        except OSError:
            pass

    def kill(self) -> None:
        self.proc.kill()
        self.proc.join(2.0)
        try:
            self.conn.close()
        except OSError:
            pass


class WorkerPool:
    """Long-lived worker subprocesses with modeled cold-start economics.

    * ``provision_s`` — modeled cold-start floor: a provision that finishes
      faster than this sleeps the remainder (a real platform's container
      start dominates a local ``spawn``); the *measured* provision time is
      what gets billed.
    * ``idle_reap_s`` — workers idle longer than this are reaped (lazily,
      at the next pool interaction, plus explicitly via ``reap_idle``);
      ``None`` disables reaping. ``min_workers`` is the warm floor the
      reaper leaves.
    * ``resize(n)`` — pre-warm up to ``n`` workers (the elasticity
      decision's grow path) or retire idle ones down to ``n``.
    * Cost proxy: ``cost_function_seconds()`` = Σ busy wall + Σ provision
      charges, the figure the elastic benchmark compares warm-pool reuse
      against cold-start-every-time.

    Workers are started with the "spawn" method — fork is unsafe once jax
    has initialized XLA threads in the host.
    """

    def __init__(self, max_workers: int = 4, provision_s: float = 0.0,
                 idle_reap_s: float | None = None, min_workers: int = 0,
                 modules: Sequence[str] = (), start_method: str = "spawn"):
        self.max_workers = max(1, int(max_workers))
        self.provision_s = float(provision_s)
        self.idle_reap_s = idle_reap_s
        self.min_workers = int(min_workers)
        self.modules = tuple(modules)
        self._mp = mp.get_context(start_method)
        self._cond = threading.Condition()
        self._idle: list[tuple[WorkerHandle, float]] = []   # LIFO, (w, since)
        self._busy: set[WorkerHandle] = set()
        self._spawning = 0
        self._target = 0            # shrink marker set by resize()
        self._ids = 0
        self._closed = False
        # economics
        self.cold_starts = 0
        self.warm_hits = 0
        self.reaped = 0
        self.provision_seconds = 0.0
        self.busy_seconds = 0.0

    # -- provisioning ---------------------------------------------------------

    def _spawn_one(self) -> WorkerHandle:
        t0 = time.perf_counter()
        host, child = self._mp.Pipe()
        with self._cond:
            self._ids += 1
            wid = self._ids
        proc = self._mp.Process(target=worker_main, args=(child, self.modules),
                                daemon=True, name=f"repro-worker-{wid}")
        proc.start()
        child.close()
        if not host.poll(120):
            proc.kill()
            raise InvocationError(f"worker {wid} failed to start")
        try:
            ready = host.recv()
        except (EOFError, OSError) as e:
            proc.kill()
            raise InvocationError(
                f"worker {wid} died during startup (is the parent main "
                f"module spawn-safe?)") from e
        if ready[0] != "ready":   # pragma: no cover - handshake is fixed
            proc.kill()
            raise InvocationError(f"worker {wid}: bad handshake {ready[0]!r}")
        elapsed = time.perf_counter() - t0
        if elapsed < self.provision_s:
            # the modeled cold start is a floor on top of the real spawn
            time.sleep(self.provision_s - elapsed)
            elapsed = self.provision_s
        w = WorkerHandle(wid, proc, host, elapsed)
        with self._cond:
            self.cold_starts += 1
            self.provision_seconds += elapsed
        return w

    # -- lease/release --------------------------------------------------------

    def lease(self) -> tuple[WorkerHandle, bool]:
        """A worker to run one invocation on: the warmest idle worker
        (``(worker, cold=False)``), or a freshly provisioned one
        (``cold=True``). Blocks while the pool is at ``max_workers`` with
        nothing idle."""
        while True:
            with self._cond:
                if self._closed:
                    raise InvocationError("worker pool is shut down")
                self._reap_locked()
                if self._idle:
                    w, _ = self._idle.pop()
                    self._busy.add(w)
                    self.warm_hits += 1
                    return w, False
                if (len(self._busy) + len(self._idle) + self._spawning
                        < self.max_workers):
                    self._spawning += 1
                    break
                self._cond.wait(0.1)
        try:
            w = self._spawn_one()
        finally:
            with self._cond:
                self._spawning -= 1
                self._cond.notify_all()
        with self._cond:
            self._busy.add(w)
        return w, True

    def release(self, w: WorkerHandle, busy_s: float) -> None:
        """Return a worker after an invocation; it joins the warm pool
        unless a shrink target says retire it."""
        retire = False
        with self._cond:
            self._busy.discard(w)
            self.busy_seconds += busy_s
            w.invocations += 1
            if self._target and self.size() >= self._target:
                retire = True    # re-admitting would exceed the shrink target
            else:
                self._idle.append((w, time.monotonic()))
            self._reap_locked()
            self._cond.notify_all()
        if retire:
            w.stop()

    def retire(self, w: WorkerHandle, busy_s: float = 0.0) -> None:
        """Remove a dead/poisoned worker (killed mid-invocation: its pipe
        state is undefined, it can never be reused)."""
        with self._cond:
            self._busy.discard(w)
            self.busy_seconds += busy_s
            self._cond.notify_all()
        w.kill()

    # -- elasticity -----------------------------------------------------------

    def size(self) -> int:
        return len(self._busy) + len(self._idle) + self._spawning

    def resize(self, target: int) -> int:
        """Grow (pre-warm) or shrink the pool toward ``target`` workers;
        returns the resulting size. Growth provisions synchronously — the
        elasticity decision pays cold starts *before* the fan-out arrives,
        which is exactly the provision-latency-hiding it exists for.
        Shrinking retires idle workers now and busy ones as they release.
        """
        target = max(0, min(int(target), self.max_workers))
        with self._cond:
            self._target = target
            to_stop = []
            while self._idle and self.size() > target:
                to_stop.append(self._idle.pop(0)[0])   # oldest first
            need = target - self.size()
        for w in to_stop:
            w.stop()
        for _ in range(max(0, need)):
            with self._cond:
                if self._closed or self.size() >= target:
                    break
                self._spawning += 1
            try:
                w = self._spawn_one()
            finally:
                with self._cond:
                    self._spawning -= 1
            with self._cond:
                self._idle.append((w, time.monotonic()))
                self._cond.notify_all()
        return self.size()

    def _reap_locked(self) -> None:
        if self.idle_reap_s is None:
            return
        now = time.monotonic()
        keep_floor = max(self.min_workers, self._target)
        doomed = []
        # oldest idle first; never reap below the warm floor
        while self._idle and now - self._idle[0][1] > self.idle_reap_s \
                and self.size() > keep_floor:
            doomed.append(self._idle.pop(0)[0])
        for w in doomed:
            self.reaped += 1
            threading.Thread(target=w.stop, daemon=True).start()

    def reap_idle(self) -> None:
        with self._cond:
            self._reap_locked()

    # -- economics ------------------------------------------------------------

    def cost_function_seconds(self) -> float:
        """The serverless bill: busy function-seconds plus provision
        charges (a cold container's start time is billed, Lambada-style)."""
        with self._cond:
            return self.busy_seconds + self.provision_seconds

    def stats(self) -> dict:
        with self._cond:
            return {"size": self.size(), "cold_starts": self.cold_starts,
                    "warm_hits": self.warm_hits, "reaped": self.reaped,
                    "provision_seconds": round(self.provision_seconds, 6),
                    "busy_seconds": round(self.busy_seconds, 6),
                    "cost_function_seconds":
                        round(self.busy_seconds + self.provision_seconds, 6)}

    def shutdown(self) -> None:
        with self._cond:
            self._closed = True
            idle = [w for w, _ in self._idle]
            busy = list(self._busy)
            self._idle.clear()
            self._busy.clear()
            self._cond.notify_all()
        for w in idle:
            w.stop()
        for w in busy:
            w.kill()


# ---------------------------------------------------------------------------
# The invoker backend
# ---------------------------------------------------------------------------


class ProcessPoolInvoker(ThreadPoolInvoker):
    """Function bodies run in worker subprocesses; everything else — slot
    claims, retries, batching, speculation, metrics, tracing — is the
    shared invoker machinery (only ``_invoke_body`` is overridden).

    ``max_workers`` bounds both the host-side dispatch threads and the
    worker-process pool. ``prewarm`` provisions that many workers up
    front; ``provision_s``/``idle_reap_s``/``min_workers`` are the
    cold-start model (see ``WorkerPool``). ``modules`` are extra module
    names each worker imports at startup so their ``@register``-ed
    functions exist in the worker's registry.
    """

    parallel = True

    def __init__(self, gc, store, metrics=None, max_workers: int = 2,
                 provision_s: float = 0.0, idle_reap_s: float | None = None,
                 min_workers: int = 0, prewarm: int = 0,
                 modules: Sequence[str] = (), **kwargs):
        super().__init__(gc, store, metrics, max_workers=max_workers,
                         **kwargs)
        self.pool = WorkerPool(max_workers=max_workers,
                               provision_s=provision_s,
                               idle_reap_s=idle_reap_s,
                               min_workers=min_workers, modules=modules)
        if prewarm:
            self.pool.resize(prewarm)

    # -- elasticity surface ---------------------------------------------------

    def pool_size(self) -> int:
        return self.pool.size()

    def resize(self, target: int) -> int:
        return self.pool.resize(target)

    # -- the overridden body hook ---------------------------------------------

    def _invoke_body(self, fn: Callable, inv: Invocation,
                     attempt: int) -> FnContext:
        kill = None
        matcher = getattr(self.injector, "match_worker_kill", None)
        if matcher is not None:
            kill = matcher(inv, attempt)
        ctx = FnContext(self.store, inv, honor_plan=self.honor_plan)
        worker, cold = self.pool.lease()
        tr = get_tracer()
        t0 = time.perf_counter()
        ok = False
        try:
            task = {"func": inv.func, "app": inv.app, "node": inv.node,
                    "index": inv.index, "params": dict(inv.params),
                    "writer": inv.name, "honor_plan": self.honor_plan,
                    "kill": kill.when if kill is not None else None}
            try:
                worker.conn.send(("run", task))
                metrics = self._serve(worker, ctx, inv)
                ok = True
            except WorkerKilledError:
                raise
            except (EOFError, BrokenPipeError, ConnectionResetError,
                    OSError) as e:
                raise WorkerKilledError(
                    f"{inv.name}: worker {worker.id} (pid {worker.pid}) "
                    f"died mid-invocation") from e
            except BaseException:
                # the error arrived over a clean pipe (a worker-reported
                # function error, or a host-side store error after a clean
                # abort/commit) — the worker itself is healthy and reusable
                ok = True
                raise
        finally:
            busy = time.perf_counter() - t0
            if ok:
                self.pool.release(worker, busy)
            else:
                # a worker that died (or whose pipe is in an undefined
                # state) never returns to the warm pool
                self.pool.retire(worker, busy)
        ctx.rows_actual = int(metrics.get("rows_actual", 0))
        ctx.rows_padded = int(metrics.get("rows_padded", 0))
        ctx.stats = dict(metrics.get("stats") or {})
        if tr.enabled:
            # merge the worker's own timing into the host trace: a child
            # span of the invocation bracketing the remote body, with the
            # worker-measured breakdown in its attrs
            tr.record(f"worker/{worker.id}", "invoker", t0, trace=inv.app,
                      node=inv.node, kind="worker_body", worker=worker.id,
                      pid=metrics.get("pid"), cold=cold,
                      busy_s=round(metrics.get("busy_s", 0.0), 6),
                      rpc_s=round(metrics.get("rpc_s", 0.0), 6))
        return ctx

    def _serve(self, worker: WorkerHandle, ctx: FnContext,
               inv: Invocation) -> dict:
        """Service the worker's store RPCs until the body finishes; commit
        its buffered writes; return its metrics. Store access runs in the
        host thread, inside the invocation span — reads are accounted,
        traced, and fault-hooked exactly like in-process execution."""
        conn = worker.conn
        while True:
            msg = conn.recv()                    # EOF => worker died
            kind = msg[0]
            if kind == "get":
                try:
                    t = ctx.get(msg[1], msg[2], writers=msg[3])
                except StageLostError as e:
                    # abort the remote body and surface the typed error
                    # from the host (tombstones must reach lineage
                    # recovery, and exceptions do not pickle reliably)
                    conn.send(("abort", repr(e)))
                    ack = conn.recv()
                    if ack[0] != "aborted":   # pragma: no cover
                        raise WorkerKilledError(
                            f"{inv.name}: worker {worker.id} broke protocol "
                            f"during abort") from e
                    raise
                conn.send(("ok", None if t is None else serialize_table(t)))
            elif kind == "partitions":
                conn.send(("ok", ctx.partitions(msg[1])))
            elif kind == "done":
                for w in msg[1]:
                    if w[0] == "put":
                        ctx.put(w[1], w[2], deserialize_table(w[3]))
                    else:
                        ctx.put_many(w[1], {p: deserialize_table(c)
                                            for p, c in w[2].items()})
                return msg[2]
            elif kind == "error":
                payload = msg[1]
                if isinstance(payload, bytes):
                    try:
                        exc = pickle.loads(payload)
                    except Exception:
                        exc = None
                    if isinstance(exc, BaseException):
                        raise exc
                    raise InvocationError(
                        f"{inv.name}: worker raised an unpicklable error")
                raise InvocationError(
                    f"{inv.name}: worker raised {payload[0]}: {payload[1]}")
            else:   # pragma: no cover - protocol is fixed
                raise WorkerKilledError(
                    f"{inv.name}: unexpected worker message {kind!r}")

    def shutdown(self) -> None:
        self.drain()
        self.pool.shutdown()
