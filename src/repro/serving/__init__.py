"""Serving substrate: continuous batching engine + batching decision node."""

from repro.serving.engine import (  # noqa: F401
    Request,
    ServingEngine,
    batching_decision_node,
)
