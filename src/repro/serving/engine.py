"""Serving engine with control-plane-driven adaptive batching.

This is the paper's §7 machine-learning-inference use case built on the same
decision-workflow machinery: a *batching decision node* trades latency
against utilization (batch big when the queue is deep, small when
latency-bound), and slot claims go through the GlobalController so serving
co-exists with background jobs (Fig. 8 semantics at request granularity).

The engine runs lockstep continuous batching: one prefill program per
admitted wave (prompts padded to the wave max), one decode program per step
over the active batch. Compiled programs are cached per (batch, prompt_len)
bucket — the warm-container analogue.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from repro.core.controllers import GlobalController, PrivateController
from repro.core.decisions import (
    Decision,
    DecisionContext,
    DecisionNode,
    Schedule,
)
from repro.models.lm import decode_step, init_decode_state, prefill_step


@dataclass
class Request:
    req_id: int
    tokens: list[int]
    max_new_tokens: int = 16
    arrival: float = field(default_factory=time.monotonic)
    output: list[int] = field(default_factory=list)
    done: bool = False


def batching_decision(ctx: DecisionContext) -> Decision:
    """Adaptive batching (paper §7): large batches amortize weight reads,
    small batches bound latency. Inputs: queue depth, SLO, active load."""
    queue = ctx.app.get("queue_depth", 0)
    slo_ms = ctx.app.get("slo_ms", 200.0)
    per_seq_ms = ctx.profile.get("decode_ms_per_step", 5.0)
    max_batch = ctx.app.get("max_batch", 8)
    # admit up to max_batch, but only as many as keep est. step time in SLO
    affordable = max(1, int(slo_ms / max(per_seq_ms, 1e-3)))
    admit = max(1, min(queue, max_batch, affordable))
    nodes = tuple(ctx.node_status.total_slots) or (0,)
    return Decision("admit", admit, Schedule("packing", nodes),
                    extras=(("affordable", affordable),))


def batching_decision_node() -> DecisionNode:
    return DecisionNode("batching", batching_decision)


class ServingEngine:
    """Lockstep continuous-batching engine (CPU-runnable on smoke configs)."""

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 128, gc: GlobalController | None = None,
                 slo_ms: float = 200.0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.slo_ms = slo_ms
        self.gc = gc or GlobalController({0: max_batch})
        self.pc = PrivateController("serving", self.gc, priority=10)
        self.node = batching_decision_node()
        self.queue: list[Request] = []
        self.active: list[Request | None] = [None] * max_batch
        self.state = None
        self.metrics = {"steps": 0, "prefills": 0, "generated": 0,
                        "batch_occupancy": []}
        self._decode = jax.jit(partial(decode_step, cfg=cfg))
        self._prefill = jax.jit(partial(prefill_step, cfg=cfg,
                                        q_chunk=max_seq))
        self._claims = {}

    # -- API -----------------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_steps: int = 256) -> list[Request]:
        finished: list[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.active):
                break
            self._admit()
            finished.extend(self._step())
        return finished

    # -- internals -------------------------------------------------------------

    def _admit(self):
        free = [i for i, r in enumerate(self.active) if r is None]
        if not free or not self.queue:
            return
        ctx = self.pc.context(app_info={
            "queue_depth": len(self.queue),
            "slo_ms": self.slo_ms,
            "max_batch": len(free),
        })
        ctx.profile = dict(self.pc.profile)
        decision = self.node.decide(ctx)
        n = min(decision.scale, len(free), len(self.queue))
        if n == 0:
            return
        wave = [self.queue.pop(0) for _ in range(n)]
        self._prefill_wave(wave, free[:n])

    def _prefill_wave(self, wave: list[Request], slots: list[int]):
        # lockstep engine: (re)prefill the whole active set so every
        # sequence shares one state pytree (padded to max_seq)
        for req, slot in zip(wave, slots):
            self.active[slot] = req
            self._claims[req.req_id] = self.pc.enact(
                Decision("serve", 1, Schedule("packing", (0,))),
                tag=f"req{req.req_id}")
        self._replay_prefill()
        self.metrics["prefills"] += 1

    def _replay_prefill(self):
        b = self.max_batch
        prompt = np.zeros((b, self.max_seq), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            toks = (req.tokens + req.output)[-self.max_seq:]
            prompt[i, : len(toks)] = toks
            lengths[i] = len(toks)
        self.state = init_decode_state(self.cfg, b, self.max_seq)
        _, self.state = self._prefill(
            self.params, self.state, {"tokens": jnp.asarray(prompt)})
        # prefill advanced every row to max_seq (padded); rewind each row to
        # its last *real* token, which the next decode step re-feeds — it
        # rewrites the identical K/V at that slot and yields the true
        # next-token logits (the padded-position prefill logits are garbage)
        self.state["pos"] = jnp.asarray(np.maximum(lengths - 1, 0))

    def _step(self) -> list[Request]:
        if all(r is None for r in self.active):
            return []
        t0 = time.perf_counter()
        b = self.max_batch
        last = np.zeros((b, 1), np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            seq = req.tokens + req.output
            last[i, 0] = seq[-1]
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(last))
        jax.block_until_ready(logits)
        next_tokens = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        self.metrics["steps"] += 1
        self.metrics["batch_occupancy"].append(
            sum(r is not None for r in self.active) / b)
        self.pc.record_profile(
            decode_ms_per_step=(time.perf_counter() - t0) * 1e3)

        finished = []
        for i, req in enumerate(self.active):
            if req is None:
                continue
            req.output.append(int(next_tokens[i]))
            self.metrics["generated"] += 1
            total = len(req.tokens) + len(req.output)
            if len(req.output) >= req.max_new_tokens \
                    or total >= self.max_seq:
                req.done = True
                finished.append(req)
                self.active[i] = None
                claim = self._claims.pop(req.req_id, None)
                if claim is not None:
                    self.gc.release(claim)
        return finished
