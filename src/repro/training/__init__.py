"""Training substrate: optimizer, losses, train-step builder."""

from repro.training.optimizer import (  # noqa: F401
    apply_updates,
    init_opt_state,
    lr_schedule,
    opt_state_axes,
)
from repro.training.losses import chunked_cross_entropy  # noqa: F401
from repro.training.train_step import (  # noqa: F401
    init_train_state,
    make_eval_step,
    make_train_step,
)
