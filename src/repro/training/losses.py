"""Sequence-chunked cross-entropy fused with the unembedding.

At (B=256, S=4096, V=152k) the fp32 logits tensor is ~0.6 TB — it must never
materialize. We ``lax.map`` over sequence chunks, computing (chunk) logits,
log-sum-exp and the label term inside the chunk; peak memory is
O(B * chunk * V / tp).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.parallel.sharding import logical_shard


def chunked_cross_entropy(embed_params: dict, h: jax.Array,
                          labels: jax.Array, cfg: ModelConfig,
                          chunk: int = 512) -> tuple[jax.Array, jax.Array]:
    """h: (B, S, D) final hidden states; labels: (B, S) int32 (-1 = masked).

    Returns (mean_loss, token_count). Padded vocab columns never receive
    probability mass for real labels (labels < true vocab by construction),
    but they do enter the partition function; we mask them to -inf.
    """
    b, s, d = h.shape
    table = embed_params.get("unembed")
    if table is None:
        table = embed_params["table"].T
    vpad = table.shape[-1]
    vocab_mask = (jnp.arange(vpad) < cfg.vocab_size)

    chunk = min(chunk, s)
    while s % chunk:      # largest divisor of s not exceeding the request
        chunk -= 1        # (VLM text spans like 3840 are not 512-aligned)
    nc = s // chunk

    def one(args):
        hc, lc = args                       # (B, C, D), (B, C)
        logits = jnp.einsum("bcd,dv->bcv", hc, table).astype(jnp.float32)
        logits = logical_shard(logits, "batch", None, "vocab")
        logits = jnp.where(vocab_mask[None, None], logits, -1e9)
        lse = jax.nn.logsumexp(logits, axis=-1)
        label_logit = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((lse - label_logit) * mask), jnp.sum(mask)

    if nc == 1:
        total, count = one((h, labels))
    else:
        h_c = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
        l_c = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
        sums, counts = jax.lax.map(one, (h_c, l_c))
        total, count = jnp.sum(sums), jnp.sum(counts)
    return total / jnp.maximum(count, 1.0), count
