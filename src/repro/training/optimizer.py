"""AdamW with fp32 master weights, global-norm clipping, warmup+cosine LR.

Functional, dependency-free (no optax): ``init_opt_state`` mirrors the param
tree (so it inherits the params' shardings under pjit), ``apply_updates``
returns (new_params, new_state). Optimizer math runs in fp32 regardless of
param dtype; bf16 params are re-cast from the fp32 master copy each step.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.config import OptimizerConfig


def init_opt_state(params: Any) -> dict:
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def opt_state_axes(param_axes: Any) -> dict:
    """Logical axes for the optimizer state (same sharding as params)."""
    is_axes = lambda v: isinstance(v, tuple) and all(
        isinstance(a, (str, type(None))) for a in v)
    copy = lambda: jax.tree.map(lambda a: a, param_axes, is_leaf=is_axes)
    return {"step": (), "master": copy(), "m": copy(), "v": copy()}


def lr_schedule(cfg: OptimizerConfig, step: jax.Array,
                total_steps: int = 10000) -> jax.Array:
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step_f / max(1, cfg.warmup_steps))
    progress = jnp.clip((step_f - cfg.warmup_steps)
                        / max(1, total_steps - cfg.warmup_steps), 0.0, 1.0)
    cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return cfg.lr * warm * (0.1 + 0.9 * cosine)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params: Any, grads: Any, state: dict,
                  cfg: OptimizerConfig, total_steps: int = 10000,
                  ) -> tuple[Any, dict, dict]:
    """One AdamW step. grads may be bf16; math is fp32."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step, total_steps)

    gnorm = global_norm(grads)
    scale = jnp.where(gnorm > cfg.grad_clip, cfg.grad_clip / (gnorm + 1e-9),
                      1.0)

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(master, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if master.ndim >= 2 else 0.0
        master_new = master - lr * (update + wd * master)
        return master_new, m_new, v_new

    flat_master, treedef = jax.tree.flatten(state["master"])
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(ma, g, m, v)
           for ma, g, m, v in zip(flat_master, flat_g, flat_m, flat_v)]
    master = jax.tree.unflatten(treedef, [o[0] for o in out])
    m = jax.tree.unflatten(treedef, [o[1] for o in out])
    v = jax.tree.unflatten(treedef, [o[2] for o in out])

    new_params = jax.tree.map(lambda p, ma: ma.astype(p.dtype), params,
                              master)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"step": step, "master": master, "m": m, "v": v}, \
        metrics
