"""Train-step builder: microbatch gradient accumulation + remat + AdamW.

``make_train_step(cfg, shape, opt_cfg, pc)`` returns a pure function
``train_step(state, batch) -> (state, metrics)`` suitable for ``jax.jit``
with in/out shardings derived from the active ShardingRules. The microbatch
count is the *scale* element of the control-plane decision tuple (paper:
"scale ∝ data size"): global batch is split into ``pc.microbatches`` slices
scanned sequentially, bounding activation memory.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.config import (
    Frontend,
    ModelConfig,
    OptimizerConfig,
    ParallelConfig,
    ShapeConfig,
)
from repro.models.lm import forward_hidden
from repro.parallel.sharding import logical_shard
from repro.training.losses import chunked_cross_entropy
from repro.training.optimizer import apply_updates, init_opt_state

AUX_LOSS_WEIGHT = 0.01


def init_train_state(cfg: ModelConfig, params: Any) -> dict:
    return {"params": params, "opt": init_opt_state(params)}


def _loss_fn(params, batch, cfg: ModelConfig, pc: ParallelConfig,
             q_chunk: int, ssm_chunk: int):
    h, aux = forward_hidden(params, batch, cfg, remat=pc.remat,
                            q_chunk=q_chunk, ssm_chunk=ssm_chunk)
    if cfg.frontend == Frontend.VISION_STUB.value:
        h = h[:, cfg.stub_patches:]        # loss over text positions only
    ce, count = chunked_cross_entropy(params["embed"], h, batch["labels"],
                                      cfg)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux, "tokens": count}


def make_train_step(cfg: ModelConfig, shape: ShapeConfig,
                    opt_cfg: OptimizerConfig, pc: ParallelConfig,
                    total_steps: int = 10000, q_chunk: int = 1024,
                    ssm_chunk: int = 128, regather=None):
    """``regather`` (optional, with pc.zero2): wraps the loss so weights are
    re-constrained to a non-FSDP sharding inside differentiation — the
    constraint's transpose reduce-scatters the grads. NOTE: persisting
    gathered weights across the microbatch scan costs 2·N/tp bytes of HBM,
    which rules it out for the 72B cell on 16 GB chips (see EXPERIMENTS.md
    §Perf); it is a win on high-HBM parts, hence kept as an option."""
    mb = max(1, pc.microbatches)

    base_loss = partial(_loss_fn, cfg=cfg, pc=pc, q_chunk=q_chunk,
                        ssm_chunk=ssm_chunk)
    if regather is not None and pc.zero2:
        def loss_fn(params, mbatch):
            return base_loss(regather(params), mbatch)
    else:
        loss_fn = base_loss
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]

        if mb == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def slice_mb(t):
                b = t.shape[0]
                return jnp.moveaxis(
                    t.reshape(mb, b // mb, *t.shape[1:]), 0, 0)

            batch_mb = jax.tree.map(slice_mb, batch)

            def acc(carry, mb_batch):
                g_acc, loss_acc = carry
                (loss, metrics), grads = grad_fn(params, mb_batch)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / mb, g_acc, grads)
                return (g_acc, loss_acc + loss / mb), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), batch_mb)
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, state["opt"], opt_cfg, total_steps)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, pc: ParallelConfig,
                   q_chunk: int = 1024, ssm_chunk: int = 128):
    def eval_step(params, batch):
        loss, metrics = _loss_fn(params, batch, cfg, pc, q_chunk, ssm_chunk)
        return metrics
    return eval_step
