"""Optional-hypothesis shim so tier-1 collection works on bare environments.

When hypothesis is installed (the ``dev`` extra), re-exports the real
``given``/``settings``/``st``. When absent, provides stand-ins whose wrapped
tests ``pytest.importorskip("hypothesis")`` at call time — property-based
tests skip, everything else collects and runs.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:          # bare environment: skip, don't crash
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*a, **k):    # pragma: no cover - trivial
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Accepts any strategy constructor; values are never drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
