import os

import jax
import pytest

# Smoke tests and benches must see the real (1-device) CPU platform; the
# 512-device override belongs exclusively to repro.launch.dryrun.
jax.config.update("jax_platform_name", "cpu")

# Property-based suites run under a bounded profile: CI pins
# HYPOTHESIS_PROFILE=ci (fewer examples, no per-example deadline flakes);
# local runs get the broader dev profile. No-op on bare environments.
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", max_examples=40, deadline=None)
    _hyp_settings.register_profile("dev", max_examples=100, deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ModuleNotFoundError:          # pragma: no cover - shim covers tests
    pass


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
