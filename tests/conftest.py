import jax
import pytest

# Smoke tests and benches must see the real (1-device) CPU platform; the
# 512-device override belongs exclusively to repro.launch.dryrun.
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
