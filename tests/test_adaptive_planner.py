"""Workflow-driven adaptive planner + dependency-driven executor.

The tentpole behaviors: late-bound decisions that see runtime feedback
(join flip on observed post-filter distribution), one workflow shared by
both data planes (identical decision sequences), dependency-driven stage
scheduling (overlap, out-of-list-order execution), and preemption-retry of
whole queries under the threads invoker.
"""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics import (
    QueryStrategy,
    Table,
    build_query_workflow,
    estimate_scan_output,
    execute_query_runtime,
    make_cluster,
    plan_query_tasks,
    reference_query_numpy,
    synth_table,
)
from repro.analytics.decisions import T1, T2, join_decision
from repro.analytics.planner import AdaptiveQueryPlan
from repro.core.controllers import GlobalController, PrivateController
from repro.core.decisions import (
    Decision,
    DecisionContext,
    DecisionNode,
    DecisionWorkflow,
    LateBindingError,
    Schedule,
)
from repro.runtime import InlineInvoker, MetricsSink, Runtime, ShuffleStore


def make_dist_tables(rows=4096, keyspace=2048, dim_rows=512,
                     fact_nodes=4, dim_nodes=2, seed=1):
    from repro.analytics.table import distribute
    fact = synth_table("f", rows, keyspace, seed=seed)
    dimc = synth_table("d", dim_rows, keyspace, seed=seed + 1,
                       unique_keys=True)
    dim = Table({**dimc.columns,
                 "cat": jnp.arange(dim_rows, dtype=jnp.int32) % 64})
    ref = reference_query_numpy(fact, dim)
    return (distribute(fact, range(fact_nodes), "A"),
            distribute(dim, range(dim_nodes), "B"), ref)


# -- core: late-bound workflow evaluation -----------------------------------------


def _const_node(name, func="f"):
    return DecisionNode(
        name, lambda ctx: Decision(func, 1, Schedule("round-robin", (0,))))


def test_workflow_run_enforces_late_binding():
    wf = DecisionWorkflow("q")
    wf.add(_const_node("a")).add(_const_node("b"), depends_on=("a",))
    run = wf.start(DecisionContext())
    with pytest.raises(LateBindingError):
        run.decide("b")                      # upstream not decided/fed yet
    run.decide("a")
    with pytest.raises(LateBindingError):
        run.decide("b")                      # decided but feedback not folded
    run.feedback("a", {"a.seconds": 1.0})
    d = run.decide("b")
    assert d.func == "f"
    assert run.ctx.profile["a.seconds"] == 1.0
    assert run.complete()
    with pytest.raises(LateBindingError):
        run.decide("a")                      # no double-binding


def test_workflow_await_feedback_decouples_decision_order():
    """A stage may depend on an upstream *decision* while awaiting feedback
    from an earlier stage only (exchange-follows-join pattern)."""
    wf = DecisionWorkflow("q")
    wf.add(_const_node("scan"))
    wf.add(_const_node("join"), depends_on=("scan",))
    wf.add(_const_node("exchange"), depends_on=("join",),
           await_feedback=("scan",))
    run = wf.start(DecisionContext())
    run.decide("scan")
    run.feedback("scan")
    run.decide("join")
    # join's own feedback never arrives, yet exchange is ready:
    assert "exchange" in run.ready()
    run.decide("exchange")
    assert run.complete()


def test_decision_node_history_is_bounded():
    node = _const_node("n")
    for _ in range(200):
        node.decide(DecisionContext())
    assert len(node.history) == 64
    small = DecisionNode("s", lambda ctx: Decision("f", 1,
                                                   Schedule("round-robin", ())),
                         max_history=3)
    for _ in range(10):
        small.decide(DecisionContext())
    assert len(small.history) == 3


def test_decisions_visible_to_downstream_nodes():
    wf = DecisionWorkflow("q")
    wf.add(_const_node("a", func="hash_join"))
    seen = {}

    def fn(ctx):
        seen["a"] = ctx.decisions["a"].func
        return Decision("x", 1, Schedule("round-robin", (0,)))

    wf.add(DecisionNode("b", fn), depends_on=("a",))
    run = wf.start(DecisionContext())
    run.decide("a")
    run.feedback("a")
    run.decide("b")
    assert seen["a"] == "hash_join"


# -- the flip: a decision impossible under up-front planning ----------------------


def _selective_tables(rows=20000, dim_rows=1100, keyspace=4096,
                      fact_nodes=10, keep=0.05, seed=0):
    """Fact whose filter keeps ~``keep`` of rows, spread over many nodes:
    the raw size ratio is above T1 (up-front Fig. 6 says hash_join), the
    post-filter ratio is far below T1 on a >T2-node cluster (merge_join)."""
    from repro.analytics.table import distribute
    rng = np.random.default_rng(seed)
    fact = synth_table("f", rows, keyspace, seed=seed + 1)
    v0 = np.asarray(fact["v0"])
    v0 = np.where(rng.random(rows) < keep, np.abs(v0) + 0.1,
                  -np.abs(v0) - 0.1)
    fact = Table({**fact.columns, "v0": jnp.asarray(v0, jnp.float32)})
    dimc = synth_table("d", dim_rows, keyspace, seed=seed + 2,
                       unique_keys=True)
    dim = Table({**dimc.columns,
                 "cat": jnp.arange(dim_rows, dtype=jnp.int32) % 64})
    ref = reference_query_numpy(fact, dim)
    return (distribute(fact, range(fact_nodes), "A"),
            distribute(dim, range(2), "B"), ref)


def test_join_node_flips_on_observed_post_filter_distribution():
    fd, dd, ref = _selective_tables()
    gc = GlobalController({n: 8 for n in range(10)})

    # up-front planning (the old path): raw sizes say hash_join
    raw_ctx = DecisionContext(
        data_dist={"A": fd.data_dist(), "B": dd.data_dist()},
        node_status=gc.node_status())
    assert fd.nbytes / dd.nbytes >= T1 and len(fd.partitions) > T2
    assert join_decision(raw_ctx).func == "hash_join"

    # late-bound workflow: the join node sees the observed post-filter
    # distribution from the scan stage and flips to merge_join mid-query
    wf = build_query_workflow(QueryStrategy("dynamic_fig6"),
                              consolidate_threshold=0)
    got, runtime = execute_query_runtime(
        fd, dd, QueryStrategy("dynamic_fig6"), gc=gc, workflow=wf)
    run = wf.last_run
    assert run.decisions["join"].func == "merge_join"
    scanned = run.ctx.data_dist["A_scanned"]
    assert scanned.size < fd.nbytes / 5          # the filter really shrank A
    assert scanned.size / dd.nbytes < T1
    # and the adapted plan is still correct
    np.testing.assert_allclose(got, ref, atol=1e-3)
    # the decision sequence shows the full per-phase workflow
    assert [name for name, _ in run.sequence] == \
        ["scan", "join", "exchange", "skew", "aggregate", "pipeline",
             "elastic", "tiering"]
    assert run.decisions["exchange"].func == "shuffle"


def test_workflow_with_explicit_threshold_rejected():
    """The consolidation threshold is baked into the workflow at build
    time; passing both is a contradiction, not a merge."""
    fd, dd, _ = make_dist_tables()
    wf = build_query_workflow(QueryStrategy("dynamic_fig6"))
    with pytest.raises(ValueError, match="consolidate_threshold"):
        execute_query_runtime(fd, dd, QueryStrategy("dynamic_fig6"),
                              workflow=wf, consolidate_threshold=0)


def test_consolidated_sequence_matches_materialized_plan():
    """Under Fig. 7's consolidation the recorded decisions are exactly what
    runs: hash join packed onto the data-heaviest node, broadcast exchange
    — never a phantom merge/shuffle sequence."""
    fd, dd, ref = make_dist_tables()       # tiny input -> fig6 consolidates
    wf = build_query_workflow(QueryStrategy("dynamic_fig6"))
    got, rt = execute_query_runtime(fd, dd, QueryStrategy("dynamic_fig6"),
                                    workflow=wf)
    np.testing.assert_allclose(got, ref, atol=1e-3)
    run = wf.last_run
    join_d = run.decisions["join"]
    assert join_d.extra("consolidate") and join_d.func == "hash_join"
    assert join_d.schedule.policy == "packing"
    assert run.decisions["exchange"].func == "broadcast"
    target = join_d.schedule.nodes[0]
    recs = [r for r in rt.metrics.records
            if r.stage in ("join", "partial_agg", "final_agg")]
    assert recs and all(r.node == target for r in recs)


# -- one workflow, two data planes: identical decision sequences ------------------


def test_simulator_and_runtime_share_identical_decision_sequences():
    from repro.obs import get_audit_log

    audit = get_audit_log()
    audit.clear()
    fd, dd, ref = make_dist_tables()
    wf = build_query_workflow(QueryStrategy("dynamic_fig6"))

    gc_rt = GlobalController({n: 8 for n in range(4)})
    got, _ = execute_query_runtime(fd, dd, QueryStrategy("dynamic_fig6"),
                                   gc=gc_rt, workflow=wf)
    np.testing.assert_allclose(got, ref, atol=1e-3)
    seq_runtime = list(wf.last_run.sequence)
    nodes = [s for s, _ in seq_runtime]
    funcs_runtime = [(s, d.func) for s, d in seq_runtime]
    # the audit log recorded the runtime plane's bindings, in order
    assert audit.sequence("query", nodes=nodes) == funcs_runtime

    gc_sim, sim = make_cluster(4)
    pc = PrivateController("query", gc_sim, priority=10)
    plan_query_tasks(sim, pc, fd, dd, QueryStrategy("dynamic_fig6"),
                     workflow=wf)
    seq_sim = list(wf.last_run.sequence)
    out = sim.run()
    assert out["completion"]["query"] > 0

    # full Decision equality, stage by stage, in binding order
    assert seq_runtime == seq_sim
    # both runs flowed through the same nodes (bounded shared history)
    assert len(wf.stages["join"].node.history) == 2
    # the audit stream now holds both planes' bindings back to back, and
    # the runtime plane's audited sequence equals the simulator's
    assert audit.sequence("query", nodes=nodes) == \
        funcs_runtime + [(s, d.func) for s, d in seq_sim]


def test_estimated_scan_output_matches_observed_store_distribution():
    """The simulator's scan estimate is byte-for-byte the runtime's observed
    post-filter store state — that is what makes shared-workflow decision
    sequences identical across planes."""
    fd, dd, _ = make_dist_tables(seed=9)
    est = estimate_scan_output(fd)
    _, runtime = execute_query_runtime(fd, dd, QueryStrategy("static_hash"))
    obs = runtime.store.data_dist("query", "scan_fact", name="A_scanned")
    assert dict(est.bytes_per_node) == dict(obs.bytes_per_node)
    assert est.rows == obs.rows
    assert est.skew == pytest.approx(obs.skew)


# -- dependency-driven executor ---------------------------------------------------


def test_dependency_executor_runs_stages_out_of_list_order():
    """Stages given in scrambled order execute by dependency, not position
    (the barrier executor would refuse this list)."""
    from repro.analytics.planner import scan_stages, tail_stages
    fd, dd, ref = make_dist_tables(seed=3)
    gc = GlobalController({n: 8 for n in range(4)})
    runtime = Runtime(gc)
    fl = runtime.seed("query", "input/fact", fd.partitions)
    dl = runtime.seed("query", "input/dim", dd.partitions)
    decision = Decision("hash_join", 4,
                        Schedule("round-robin", (0, 1, 2, 3)))
    stages = scan_stages("query", fl, dl, 10) + tail_stages(
        "query", fl, dl, decision, fd.data_dist(), priority=10)
    scrambled = list(reversed(stages))
    with pytest.raises(ValueError, match="barrier mode"):
        runtime.execute(scrambled, barrier=True)
    gc2 = GlobalController({n: 8 for n in range(4)})
    runtime2 = Runtime(gc2)
    runtime2.seed("query", "input/fact", fd.partitions)
    runtime2.seed("query", "input/dim", dd.partitions)
    runtime2.execute(scrambled)
    np.testing.assert_allclose(runtime2.result("query"), ref, atol=1e-3)


def test_threads_executor_overlaps_independent_scan_stages():
    """scan_fact and scan_dim are independent: under the dependency-driven
    executor with the threads invoker their wall-clock spans intersect;
    the barrier executor strictly serializes them. The disaggregated store
    stretches each scan with (GIL-releasing) transfer time so the overlap
    is deterministic."""
    fd, dd, ref = make_dist_tables(rows=1 << 15, keyspace=1 << 14,
                                   dim_rows=1 << 12, seed=4)

    def run(barrier):
        gc = GlobalController({n: 8 for n in range(4)})
        rt = Runtime(gc, invoker="threads", net_bw=20e6, disaggregated=True)
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_hash"),
                                       runtime=rt, barrier=barrier)
        np.testing.assert_allclose(got, ref, atol=1e-3)
        return rt.metrics.stage_spans("query")

    spans = run(barrier=False)
    assert spans["scan_dim"][0] < spans["scan_fact"][1]
    spans2 = run(barrier=True)
    assert spans2["scan_dim"][0] >= spans2["scan_fact"][1]


@pytest.mark.parametrize("strat", ("static_merge", "static_hash",
                                   "dynamic", "dynamic_fig6"))
def test_adaptive_plan_threads_matches_oracle(strat):
    fd, dd, ref = make_dist_tables(seed=6)
    got, rt = execute_query_runtime(fd, dd, QueryStrategy(strat),
                                    invoker="threads")
    np.testing.assert_allclose(got, ref, atol=1e-3)
    assert sum(rt.gc.used.values()) == 0


def test_disaggregated_store_charges_all_traffic():
    store = ShuffleStore(net_bw=200e9, disaggregated=True)
    t = synth_table("t", 256, 512, seed=0)
    store.put("app", "s", 0, t, node=0, writer="w")
    assert store.get("app", "s", 0, node=0) is not None   # local read sleeps too
    fd, dd, ref = make_dist_tables(seed=8)
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, invoker="threads", net_bw=500e6, disaggregated=True)
    got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                   runtime=rt)
    np.testing.assert_allclose(got, ref, atol=1e-3)


# -- preemption-retry of a whole query under the threads invoker ------------------


def test_high_priority_query_preempts_low_priority_mid_stage_threads():
    """A high-priority query arriving mid-stage preempts in-flight
    low-priority invocations on the contended nodes; retries heal the
    low-priority query and both results stay oracle-correct."""
    from repro.runtime import ThreadPoolInvoker

    lo_fd, lo_dd, lo_ref = make_dist_tables(rows=2048, keyspace=1024,
                                            fact_nodes=2, dim_nodes=2,
                                            seed=11)
    hi_fd, hi_dd, hi_ref = make_dist_tables(rows=1024, keyspace=512,
                                            dim_rows=128, fact_nodes=2,
                                            dim_nodes=2, seed=12)
    # warm the hi query's kernel shapes on an uncontended cluster so the
    # contended run below is quick (bounds the lo query's retry budget)
    execute_query_runtime(hi_fd, hi_dd, QueryStrategy("static_hash"),
                          gc=GlobalController({0: 8, 1: 8}), app="hi")

    gc = GlobalController({0: 1, 1: 1})          # one slot per node
    fire_once = threading.Lock()
    hi_result = {}

    def urgent_arrival(inv, attempt):
        # first join invocation of the low-priority query: a high-priority
        # query arrives on the shared cluster and runs to completion,
        # preempting the in-flight low-priority claims
        if inv.stage == "join" and not hi_result and \
                fire_once.acquire(blocking=False):
            hi_rt = Runtime(gc, invoker="inline")
            got, _ = execute_query_runtime(
                hi_fd, hi_dd, QueryStrategy("static_hash"), runtime=hi_rt,
                app="hi", priority=99)
            hi_result["sums"] = got

    store, metrics = ShuffleStore(), MetricsSink()
    invoker = ThreadPoolInvoker(gc, store, metrics, max_workers=4,
                                max_attempts=2000,
                                intercept=urgent_arrival)
    lo_rt = Runtime(gc, invoker=invoker, store=store, metrics=metrics)
    lo_got, _ = execute_query_runtime(
        lo_fd, lo_dd, QueryStrategy("static_hash"), runtime=lo_rt,
        app="lo", priority=0)

    np.testing.assert_allclose(lo_got, lo_ref, atol=1e-3)   # retries healed
    np.testing.assert_allclose(hi_result["sums"], hi_ref, atol=1e-3)
    assert any(p.victim.priority == 0 and p.victim.app == "lo"
               for p in gc.preemptions)
    preempted = [r for r in metrics.records
                 if r.app == "lo" and r.status == "preempted"]
    assert preempted
    for rec in preempted:      # every preempted invocation later succeeded
        assert any(r.name == rec.name and r.status == "ok"
                   and r.attempt > rec.attempt for r in metrics.records)
    assert sum(gc.used.values()) == 0


# -- controller listener thread-safety --------------------------------------------


def test_subscribe_during_notification_is_safe():
    gc = GlobalController({0: 2})
    events = []

    def late(ev, claim):
        events.append(("late", ev))

    def listener(ev, claim):
        events.append(("first", ev))
        if ev == "commit":
            gc.subscribe(late)          # mutates listener list mid-notify

    gc.subscribe(listener)
    claim = gc.commit("app", 1, [0])
    gc.release(claim)
    assert ("first", "commit") in events
    assert ("late", "release") in events
