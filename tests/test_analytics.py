"""Analytics case study: operators vs numpy oracle, decision nodes,
simulator invariants, and paper-trend assertions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.analytics import (
    QueryStrategy,
    Table,
    execute_query_jax,
    make_cluster,
    plan_query_tasks,
    reference_query_numpy,
    synth_table,
)
from repro.analytics import operators as ops
from repro.analytics.decisions import (
    T1,
    T2,
    cost_model_join_decision,
    join_decision,
    scheduling_decision,
)
from repro.analytics.simulator import SimTask
from repro.analytics.table import distribute, phantom
from repro.core.controllers import GlobalController, PrivateController
from repro.core.decisions import DataDist, DecisionContext


def make_tables(rows=2048, keyspace=1024, dim_rows=256, seed=0):
    fact = synth_table("f", rows, keyspace, seed=seed)
    dimc = synth_table("d", dim_rows, keyspace, seed=seed + 1,
                       unique_keys=True)
    dim = Table({**dimc.columns,
                 "cat": jnp.arange(dim_rows, dtype=jnp.int32) % 64})
    return fact, dim


# -- operator correctness -------------------------------------------------------


@pytest.mark.parametrize("method", ["hash", "merge"])
def test_join_methods_agree_with_oracle(method):
    fact, dim = make_tables()
    got = np.asarray(execute_query_jax(fact, dim, method=method))
    ref = reference_query_numpy(fact, dim)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-3)


def test_joins_agree_with_each_other():
    fact, dim = make_tables(seed=7)
    a = np.asarray(execute_query_jax(fact, dim, method="hash"))
    b = np.asarray(execute_query_jax(fact, dim, method="merge"))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), rows=st.sampled_from([256, 1024]),
       dim_rows=st.sampled_from([32, 128]))
def test_hash_join_property(seed, rows, dim_rows):
    """Property: every probe row matching a build key is found with the
    right index; non-matching rows are not found."""
    rng = np.random.default_rng(seed)
    build = jnp.asarray(rng.permutation(10 * dim_rows)[:dim_rows],
                        jnp.int32)
    probe = jnp.asarray(rng.integers(0, 10 * dim_rows, rows), jnp.int32)
    slots = ops.build_hash_table(build)
    idx, found = ops.hash_join_indices(probe, build, slots)
    build_np, probe_np = np.asarray(build), np.asarray(probe)
    lookup = {int(k): i for i, k in enumerate(build_np)}
    for j in range(rows):
        if int(probe_np[j]) in lookup:
            assert bool(found[j]), j
            assert int(idx[j]) == lookup[int(probe_np[j])]
        else:
            assert not bool(found[j])


def test_partition_permutation_property():
    keys = jax.random.randint(jax.random.PRNGKey(0), (4096,), 0, 10_000,
                              jnp.int32)
    order, counts, pids = ops.partition_permutation(keys, 16)
    assert int(jnp.sum(counts)) == 4096
    sorted_pids = np.asarray(pids)[np.asarray(order)]
    assert (np.diff(sorted_pids) >= 0).all()     # grouped
    assert sorted(np.asarray(order).tolist()) == list(range(4096))


def test_groupby_sum_matches_numpy():
    gids = jax.random.randint(jax.random.PRNGKey(1), (512,), 0, 8, jnp.int32)
    vals = jax.random.normal(jax.random.PRNGKey(2), (512,))
    got = np.asarray(ops.groupby_sum(gids, vals, 8))
    ref = np.zeros(8)
    np.add.at(ref, np.asarray(gids), np.asarray(vals))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


# -- decision nodes (paper Fig. 6) ----------------------------------------------


def _ctx(size_a, size_b, nodes_a, nodes_b, cluster=12, slots=8):
    gc = GlobalController({n: slots for n in range(cluster)})
    return DecisionContext(
        data_dist={
            "A": DataDist("A", {n: size_a // len(nodes_a) for n in nodes_a}),
            "B": DataDist("B", {n: size_b // len(nodes_b) for n in nodes_b}),
        },
        node_status=gc.node_status())


def test_fig6_small_dim_table_picks_hash():
    ctx = _ctx(400 << 20, 10 << 20, range(12), range(2))
    d = join_decision(ctx)
    assert d.func == "hash_join"
    assert d.schedule.policy == "packing"


def test_fig6_comparable_tables_large_cluster_picks_merge():
    ctx = _ctx(400 << 20, 100 << 20, range(12), range(2))
    assert (400 / 100) < T1 and 12 > T2
    d = join_decision(ctx)
    assert d.func == "merge_join"
    assert d.schedule.policy == "round-robin"


def test_cost_model_broadcast_grows_with_cluster():
    """Fig. 4(c): hash join estimate grows with cluster size; merge's
    doesn't — so the decision flips on large clusters. Hermetic: fixed
    operator rates injected through the profiling feedback channel."""
    rates = {"merge_join": 60e6, "hash_build": 500e6, "hash_probe": 300e6,
             "scan": 2e9, "sort": 120e6, "agg": 2e9}
    ctx_small = _ctx(400 << 20, 80 << 20, range(4), range(2), cluster=4)
    ctx_small.profile = {"rates": rates}
    ctx_large = _ctx(400 << 20, 80 << 20, range(20), range(2), cluster=20)
    ctx_large.profile = {"rates": rates}
    small = cost_model_join_decision(ctx_small)
    large = cost_model_join_decision(ctx_large)
    assert small.func == "hash_join"
    assert large.func == "merge_join"


def test_scheduling_node_packs_under_skew():
    gc = GlobalController({n: 8 for n in range(8)})
    uniform = DecisionContext(
        data_dist={"A": DataDist("A", {n: 100 for n in range(8)},
                                 skew=1.0)},
        node_status=gc.node_status())
    skewed = DecisionContext(
        data_dist={"A": DataDist("A", {0: 700, 1: 50, 2: 50}, skew=4.0)},
        node_status=gc.node_status())
    assert scheduling_decision(uniform).schedule.policy == "round-robin"
    assert scheduling_decision(skewed).schedule.policy == "packing"


# -- simulator ----------------------------------------------------------------


def test_simulator_respects_dependencies_and_slots():
    gc, sim = make_cluster(2, slots=1)
    sim.submit(SimTask("a", "app", 1.0, node=0))
    sim.submit(SimTask("b", "app", 1.0, node=0, deps=("a",)))
    out = sim.run()
    assert sim.tasks["b"].started >= sim.tasks["a"].finished
    assert out["completion"]["app"] == pytest.approx(2.0, rel=1e-6)


def test_simulator_transfers_serialize_on_nic():
    gc, sim = make_cluster(3)
    # two transfers from the same source must serialize
    sim.submit(SimTask("x", "app", 0.0, node=1,
                       transfers={0: int(1.25e9)}))   # 1s at 1.25 GB/s
    sim.submit(SimTask("y", "app", 0.0, node=2,
                       transfers={0: int(1.25e9)}))
    out = sim.run()
    assert out["completion"]["app"] == pytest.approx(2.0, rel=0.01)


def test_simulator_allocation_rate_bounds():
    gc, sim = make_cluster(2, slots=2)
    for i in range(8):
        sim.submit(SimTask(f"t{i}", "app", 0.5))
    out = sim.run()
    rate = out["allocation"].allocation_rate()
    assert 0.0 < rate <= 1.0


def test_flexible_task_backfills_most_free_node():
    """Regression for the dead not-placed branch in _try_start: a flexible
    (node=None) task must land on the node with the most free slots."""
    gc, sim = make_cluster(2, slots=2)
    gc.commit("other", 5, [0])               # node 0: 1 free, node 1: 2 free
    placements = {}
    gc.subscribe(lambda ev, c: placements.setdefault(c.tag, c.placement)
                 if ev == "commit" else None)
    sim.submit(SimTask("flex", "app", 1.0))
    sim.run()
    assert placements["flex"] == (1,)


def test_background_tasks_backfill_idle_slots():
    """Fig. 8: low-priority tasks run in the gaps without delaying the
    high-priority app beyond its solo completion time."""
    def build(with_bg):
        gc, sim = make_cluster(2, slots=2)
        sim.submit(SimTask("hi/1", "query", 1.0, node=0, priority=10))
        sim.submit(SimTask("hi/2", "query", 1.0, node=0, priority=10,
                           deps=("hi/1",)))
        if with_bg:
            for i in range(6):
                sim.submit(SimTask(f"bg/{i}", "bg", 0.5, priority=0))
        return sim.run()

    solo = build(False)
    shared = build(True)
    assert shared["completion"]["query"] <= solo["completion"]["query"] + 1e-6
    assert shared["allocation"].allocation_rate() \
        > solo["allocation"].allocation_rate()


# -- end-to-end strategy comparison (paper Fig. 7 trend) -------------------------


def test_dynamic_strategy_never_worst():
    results = {}
    for strat in ("static_merge", "static_hash", "dynamic"):
        times = []
        for gb in (2, 6):
            gc, sim = make_cluster(6)
            pc = PrivateController("query", gc, priority=10)
            f = phantom("A", int(gb * 0.9 * 2 ** 30), range(6))
            d = phantom("B", int(gb * 0.05 * 2 ** 30), range(2))
            plan_query_tasks(sim, pc, f, d, QueryStrategy(strat))
            times.append(sim.run()["completion"]["query"])
        results[strat] = times
    for i in range(2):
        worst = max(r[i] for r in results.values())
        assert results["dynamic"][i] < worst * 1.001
