"""Checkpointing, supervisor fault injection, data pipeline determinism."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (
    AsyncCheckpointer,
    Supervisor,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.configs import get_config
from repro.core.config import ShapeConfig
from repro.data import MemmapSource, Prefetcher, SyntheticSource, \
    write_token_file


def make_state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x)},
            "opt": {"step": jnp.asarray(3, jnp.int32),
                    "m": jnp.ones((4, 4))}}


def test_checkpoint_roundtrip(tmp_path):
    state = make_state(2.5)
    save_checkpoint(tmp_path, 7, state, extra={"step": 7})
    restored, extra = load_checkpoint(tmp_path, like=state)
    assert extra["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert int(restored["opt"]["step"]) == 3


def test_checkpoint_keep_k(tmp_path):
    state = make_state()
    for step in range(6):
        save_checkpoint(tmp_path, step, state, keep=2)
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2
    assert latest_step(tmp_path) == 5


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(tmp_path, 1, make_state())
    assert not list(tmp_path.glob("*.tmp"))


def test_async_checkpointer(tmp_path):
    ckpt = AsyncCheckpointer(tmp_path, keep=3)
    for step in (1, 2, 3):
        ckpt.save(step, make_state(step))
    ckpt.wait()
    ckpt.close()
    restored, _ = load_checkpoint(tmp_path, like=make_state())
    assert float(restored["params"]["w"][0, 0]) == 3.0


def test_supervisor_restores_after_fault(tmp_path):
    """Inject a failure mid-run: the supervisor must restore the latest
    checkpoint and converge to the requested step count."""
    calls = {"n": 0}

    def step_fn(state, batch):
        return {"x": state["x"] + 1}, {"loss": 0.0}

    def batch_fn(step):
        return None

    faults = {"armed": True}

    def fault_hook(step):
        if step == 7 and faults["armed"]:
            faults["armed"] = False
            raise RuntimeError("simulated node failure")

    sup = Supervisor(step_fn, batch_fn, str(tmp_path), ckpt_every=2)
    state, final = sup.run({"x": jnp.asarray(0)}, 10, fault_hook=fault_hook)
    assert final == 10
    assert sup.restarts == 1
    # state must equal a clean 10-step run (restart resumed from step 6)
    assert int(state["x"]) == 10


def test_supervisor_straggler_detection(tmp_path):
    times = iter([0.01] * 10 + [0.5] + [0.01] * 5)

    def step_fn(state, batch):
        time.sleep(next(times, 0.0))
        return state, {}

    sup = Supervisor(step_fn, lambda s: None, str(tmp_path), ckpt_every=100,
                     straggler_factor=3.0)
    sup.run({"x": 0}, 16)
    assert len(sup.stragglers) >= 1


def test_elastic_restore_different_sharding(tmp_path):
    """Checkpoints are mesh-agnostic: restore onto a (trivially) different
    sharding layout works and preserves values."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    state = make_state(4.0)
    save_checkpoint(tmp_path, 1, state)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {
        "params": {"w": NamedSharding(mesh, P("data", None))},
        "opt": {"step": None, "m": NamedSharding(mesh, P(None, None))},
    }
    restored, _ = load_checkpoint(tmp_path, like=state, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


# -- data pipeline ----------------------------------------------------------------


def test_synthetic_source_deterministic_per_step():
    cfg = get_config("llama3.2-3b", smoke=True)
    shape = ShapeConfig("t", 16, 4, "train")
    a = SyntheticSource(cfg, shape, seed=5).batch(12)
    b = SyntheticSource(cfg, shape, seed=5).batch(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticSource(cfg, shape, seed=5).batch(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_synthetic_source_shards_disjoint():
    cfg = get_config("llama3.2-3b", smoke=True)
    shape = ShapeConfig("t", 16, 4, "train")
    s0 = SyntheticSource(cfg, shape, seed=5, shard=0, num_shards=2).batch(0)
    s1 = SyntheticSource(cfg, shape, seed=5, shard=1, num_shards=2).batch(0)
    assert s0["tokens"].shape[0] == 2
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_config("llama3.2-3b", smoke=True)
    shape = ShapeConfig("t", 16, 2, "train")
    b = SyntheticSource(cfg, shape, seed=1).batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_memmap_source(tmp_path):
    cfg = get_config("llama3.2-3b", smoke=True)
    path = tmp_path / "tokens.bin"
    write_token_file(path, 10_000, cfg.vocab_size, seed=0)
    shape = ShapeConfig("t", 16, 4, "train")
    src = MemmapSource(str(path), cfg, shape)
    b0, b1 = src.batch(0), src.batch(1)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    np.testing.assert_array_equal(src.batch(0)["tokens"], b0["tokens"])


def test_prefetcher_orders_batches():
    cfg = get_config("llama3.2-3b", smoke=True)
    shape = ShapeConfig("t", 8, 2, "train")
    src = SyntheticSource(cfg, shape, seed=2)
    pf = Prefetcher(src, start_step=5, depth=2)
    steps = [pf.next()[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]
