"""Gradient-compression collective: accuracy vs lax.psum.

Needs >1 device, so it runs in a subprocess with forced host devices (the
main pytest process must keep the 1-device CPU view).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.parallel.collectives import compressed_allreduce

    mesh = jax.make_mesh((8,), ("pod",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4096), jnp.float32)

    @partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
             check_vma=False)
    def compressed(xs):
        return compressed_allreduce(xs[0], "pod")[None]

    @partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
             check_vma=False)
    def exact(xs):
        return jax.lax.psum(xs, "pod")

    out, ref = compressed(x), exact(x)
    rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.02, rel
    # all shards must agree (it is an all-reduce)
    assert float(jnp.max(jnp.abs(out - out[:1]))) < 1e-6
    print("OK", rel)
""")


@pytest.mark.slow
def test_compressed_allreduce_subprocess():
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    # keep the parent's backend pin: without it jax probes for accelerator
    # plugins, which hangs on sandboxed hosts
    if "JAX_PLATFORMS" in os.environ:
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    result = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=300, env=env)
    assert result.returncode == 0, result.stderr[-2000:]
    assert "OK" in result.stdout
