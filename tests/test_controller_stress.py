"""Concurrency stress for the GlobalController claim path.

Many worker threads hammer ``try_commit``/``finish`` while a preemptor
thread lands high-priority claims that evict in-flight work. At quiesce:

  * no slot leaks: every node's ``used`` is back to zero, no claims remain
  * no lost listener notifications: every successful commit produced exactly
    one commit event and exactly one release event (via ``finish`` or via
    preemption — never both, never neither)
  * the release-event wait actually wakes starved claimants (the workers use
    it instead of spinning), so the run terminates without busy loops
"""

import threading
import time

from repro.core.controllers import GlobalController

N_WORKERS = 8
ITERS = 150


def test_controller_no_slot_leaks_and_no_lost_notifications():
    gc = GlobalController({0: 3, 1: 3, 2: 3})
    ev_lock = threading.Lock()
    events: dict[str, int] = {"commit": 0, "release": 0}

    def listener(event, claim):
        with ev_lock:
            events[event] = events.get(event, 0) + 1

    gc.subscribe(listener)
    committed = [0] * N_WORKERS
    preempted = [0] * N_WORKERS
    errors: list[BaseException] = []
    stop = threading.Event()

    def worker(i: int):
        import random
        rng = random.Random(i)
        try:
            for _ in range(ITERS):
                node = rng.randrange(3)
                epoch = gc.release_epoch()
                claim = gc.try_commit(f"w{i}", priority=i % 3, placement=[node])
                if claim is None:
                    # event-based wait: block until some claim releases
                    gc.wait_for_release(epoch, timeout=0.02)
                    continue
                committed[i] += 1
                if rng.random() < 0.3:
                    time.sleep(0.0005)     # hold the slot across a preemptor beat
                if not gc.finish(claim):
                    preempted[i] += 1
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors.append(e)

    hi_commits = [0]

    def preemptor():
        import random
        rng = random.Random(99)
        try:
            while not stop.is_set():
                claim = gc.try_commit("urgent", priority=50,
                                      placement=[rng.randrange(3)])
                if claim is not None:
                    hi_commits[0] += 1
                    gc.finish(claim)
                time.sleep(0.0002)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(N_WORKERS)]
    pt = threading.Thread(target=preemptor)
    pt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "worker wedged: event wait lost a wakeup?"
    stop.set()
    pt.join(timeout=10)
    assert not pt.is_alive()
    assert not errors, errors

    # -- no slot leaks at quiesce ---------------------------------------------
    assert gc.used == {0: 0, 1: 0, 2: 0}
    assert gc.claims == {}

    # -- no lost notifications ------------------------------------------------
    total_commits = sum(committed) + hi_commits[0]
    assert total_commits > 0
    assert events["commit"] == total_commits
    # every committed claim released exactly once: by finish() or by eviction
    assert events["release"] == total_commits
    # preemptions really happened (the arbitration path was exercised) and
    # each one is visible both to the victim (finish -> False) and the log
    assert sum(preempted) == len(
        [p for p in gc.preemptions if p.victim.app.startswith("w")])


def test_wait_for_release_wakes_on_preemption_eviction():
    """Eviction by a higher-priority commit is a release too: waiters wake."""
    gc = GlobalController({0: 1})
    low = gc.commit("low", 0, [0])
    woke = []

    def waiter():
        epoch = gc.release_epoch()
        woke.append(gc.wait_for_release(epoch, timeout=5.0))

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    hi = gc.commit("hi", 10, [0])          # evicts `low` -> release event
    t.join(timeout=5)
    assert not t.is_alive()
    assert woke == [True]
    assert not gc.is_active(low)
    gc.release(hi)


def test_wait_for_release_returns_immediately_on_stale_epoch():
    gc = GlobalController({0: 1})
    claim = gc.commit("app", 0, [0])
    epoch = gc.release_epoch()
    gc.release(claim)
    t0 = time.monotonic()
    assert gc.wait_for_release(epoch, timeout=5.0)
    assert time.monotonic() - t0 < 1.0     # no full-timeout sleep
