"""Vectorized columnar data plane: single-pass shuffle equivalence, the
columnar-slice store path (put_many / TableSlice / concat_all), invocation
batching that is invisible to the control plane, and the compute-vs-store
timing split.

The tentpole invariant under test: batching and the kernel-dispatched
single-pass shuffle change *how fast* the data plane runs, never *what the
control plane sees* — same decision sequences, same per-stage record
counts, same lineage recovery sets, same bytes.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.analytics import (
    QueryStrategy,
    Table,
    build_query_workflow,
    execute_query_runtime,
    synth_query_tables,
    synth_table,
)
from repro.analytics.table import TableSlice, distribute
from repro.core.controllers import GlobalController
from repro.runtime import (
    CrashFault,
    FaultInjector,
    FaultPlan,
    FnContext,
    InlineInvoker,
    Invocation,
    MetricsSink,
    Runtime,
    RuntimeStage,
    ShuffleStore,
    StageLossFault,
    ThreadPoolInvoker,
)

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")


@pytest.fixture(scope="module")
def tables():
    return synth_query_tables(4096, 512, seed=9)


# -- Table.concat_all and TableSlice ----------------------------------------------


def test_concat_all_matches_pairwise_chain():
    parts = [synth_table("t", n, 512, seed=i) for i, n in
             enumerate((64, 1, 128, 32))]
    multi = Table.concat_all(parts)
    chained = parts[0]
    for p in parts[1:]:
        chained = Table({k: jnp.concatenate([v, p.columns[k]])
                         for k, v in chained.columns.items()})
    assert multi.num_rows == sum(p.num_rows for p in parts)
    for k in parts[0].columns:
        np.testing.assert_array_equal(np.asarray(multi[k]),
                                      np.asarray(chained[k]))


def test_dist_table_gather_uses_multiway_concat():
    t = synth_table("t", 1024, 2048, seed=2)
    dt = distribute(t, range(8), "A")
    g = dt.gather()
    assert g.num_rows == 1024
    np.testing.assert_array_equal(
        np.sort(np.asarray(g["key"])), np.sort(np.asarray(t["key"])))


def test_table_slice_shares_parent_and_accounts_bytes():
    t = synth_table("t", 256, 512, seed=1)
    s = t.slice(32, 96)
    assert isinstance(s, TableSlice)
    assert s.num_rows == 64
    # byte accounting without materialization: rows * per-row bytes
    assert s.nbytes == t.nbytes * 64 // 256
    assert s._cache is None            # nothing materialized yet
    # the view shares the parent buffer object until first access
    assert s.parent_columns["key"] is t.columns["key"]
    np.testing.assert_array_equal(np.asarray(s["key"]),
                                  np.asarray(t["key"][32:96]))
    assert s._cache is not None        # materialized on first access
    # ...which drops the pin on the full-size parent buffer, so the real
    # device footprint matches the accounted nbytes
    assert s.parent_columns["key"] is not t.columns["key"]
    assert s.nbytes == t.nbytes * 64 // 256     # unchanged after materialize
    m = s.materialize()
    assert isinstance(m, Table) and m.num_rows == 64


# -- single-pass shuffle == per-bucket loop ---------------------------------------


def _shuffle_ctx(store, func, stage_out, nb):
    inv = Invocation(f"w/{func}", "app", "shuffle", 0, func, node=0,
                     params={"src": "in", "dst": stage_out, "partition": 0,
                             "num_buckets": nb})
    return FnContext(store, inv)


@pytest.mark.parametrize("nb", [1, 3, 8, 32])
def test_single_pass_shuffle_matches_loop_shuffle(nb):
    from repro.runtime.functions import shuffle_write, shuffle_write_loop

    t = synth_table("t", 999, 4096, seed=4)   # odd row count: padding path
    store = ShuffleStore()
    store.put("app", "in", 0, t, node=0, writer="seed")
    shuffle_write(_shuffle_ctx(store, "shuffle_write", "fast", nb))
    shuffle_write_loop(_shuffle_ctx(store, "shuffle_write_loop", "slow", nb))

    assert store.partitions("app", "fast") == store.partitions("app", "slow")
    for part in store.partitions("app", "fast"):
        a = store.get("app", "fast", part, node=0, account=False)
        b = store.get("app", "slow", part, node=0, account=False)
        assert a.num_rows == b.num_rows and a.nbytes == b.nbytes
        for k in ("key", "v0", "v1"):
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    fast = store.data_dist("app", "fast")
    slow = store.data_dist("app", "slow")
    assert fast.rows == slow.rows == 999
    assert fast.skew == pytest.approx(slow.skew)


def test_shuffle_write_empty_and_tiny_inputs():
    from repro.runtime.functions import shuffle_write

    store = ShuffleStore()
    empty = Table({"key": jnp.zeros((0,), jnp.int32),
                   "v0": jnp.zeros((0,), jnp.float32),
                   "v1": jnp.zeros((0,), jnp.float32)})
    store.put("app", "in", 0, empty, node=0, writer="seed")
    shuffle_write(_shuffle_ctx(store, "shuffle_write", "out", 4))
    assert store.partitions("app", "out") == []      # nothing written

    one = synth_table("t", 1, 16, seed=0)
    store.put("app", "in", 0, one, node=0, writer="seed")
    shuffle_write(_shuffle_ctx(store, "w2", "out2", 4))
    assert store.data_dist("app", "out2").rows == 1


# -- put_many: one round trip, identical accounting -------------------------------


def test_put_many_accounting_matches_individual_puts():
    t = synth_table("t", 256, 512, seed=3)
    slices = {r: t.slice(r * 64, (r + 1) * 64) for r in range(4)}
    a, b = ShuffleStore(), ShuffleStore()
    total = a.put_many("app", "s", slices, node=1, writer="w")
    for r, s in slices.items():
        b.put("app", "s", r, s, node=1, writer="w")
    assert total == t.nbytes
    assert a.app_bytes == b.app_bytes
    assert a.resident_bytes == b.resident_bytes
    assert a.written_bytes == b.written_bytes
    assert a.partitions("app", "s") == b.partitions("app", "s")
    # retry under the same writer label replaces, never duplicates
    a.put_many("app", "s", slices, node=1, writer="w")
    assert a.app_bytes["app"] == t.nbytes


def test_put_many_respects_quota():
    from repro.runtime import QuotaExceededError

    t = synth_table("t", 256, 512, seed=3)
    store = ShuffleStore(quota_timeout=0.05)
    store.set_quota("app", t.nbytes // 2)
    with pytest.raises(QuotaExceededError):
        store.put_many("app", "s", {0: t}, node=0, writer="w")


def test_put_many_heals_lost_tombstones():
    t = synth_table("t", 128, 512, seed=5)
    store = ShuffleStore()
    store.put_many("app", "s", {0: t.slice(0, 64), 1: t.slice(64, 128)},
                   node=0, writer="w")
    store.lose_stage("app", "s")
    from repro.runtime import StageLostError
    with pytest.raises(StageLostError):
        store.get("app", "s", 0, node=0)
    store.put_many("app", "s", {0: t.slice(0, 64), 1: t.slice(64, 128)},
                   node=0, writer="w")
    assert store.get("app", "s", 0, node=0).num_rows == 64
    assert store.lost_partitions("app", "s") == set()


# -- batching is invisible to the control plane -----------------------------------


def _control_plane_view(strat, seed, batching, invoker="inline",
                        map_split=3, plan=None, quota=None):
    fd, dd, ref = synth_query_tables(2048, 256, seed=seed)
    wf = build_query_workflow(QueryStrategy(strat))
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, invoker=invoker, batching=batching)
    if quota is not None:
        rt.store.set_quota("query", quota)
    if plan is not None:
        FaultInjector(plan).install(rt)
    got, _ = execute_query_runtime(fd, dd, QueryStrategy(strat), runtime=rt,
                                   workflow=wf, map_split=map_split)
    np.testing.assert_allclose(got, ref, atol=1e-2)
    assert sum(gc.used.values()) == 0
    decisions = [(name, s.node.history[-1][1].func,
                  s.node.history[-1][1].scale)
                 for name, s in wf.stages.items() if s.node.history]
    by_stage = rt.metrics.by_stage("query")
    rows = {name: (m.invocations, m.ok) for name, m in by_stage.items()}
    lineage = {(ev.lost_stage, ev.recovered) for ev in rt.recoveries}
    bytes_out = {name: m.bytes_out for name, m in by_stage.items()}
    return decisions, rows, lineage, bytes_out


@pytest.mark.parametrize("strat", STRATEGIES)
def test_batching_invisible_to_control_plane(strat):
    a = _control_plane_view(strat, seed=21, batching=True)
    b = _control_plane_view(strat, seed=21, batching=False)
    assert a == b


def test_batching_invisible_under_fault_recovery():
    """Same seeded loss plan: identical decision sequences, record counts
    and lineage recovery sets with batching on and off."""
    def plan():
        return FaultPlan(
            crashes=[CrashFault("scan_fact", index=1, when="before")],
            losses=[StageLossFault("joined", partitions=(0,), on_read=1)])

    views = [_control_plane_view("static_merge", seed=33, batching=on,
                                 plan=plan(), quota=1 << 30)
             for on in (True, False)]
    dec_a, rows_a, lin_a, _ = views[0]
    dec_b, rows_b, lin_b, _ = views[1]
    assert dec_a == dec_b
    assert lin_a == lin_b and lin_a        # the loss really recovered
    # the crash adds exactly one extra (crashed) record in both modes
    assert rows_a.keys() == rows_b.keys()
    assert {k: v[1] for k, v in rows_a.items()} == \
        {k: v[1] for k, v in rows_b.items()}      # identical ok counts


if HAVE_HYPOTHESIS:

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2 ** 10),
           strat=st.sampled_from(STRATEGIES),
           split=st.sampled_from([1, 2, 5]))
    def test_batching_invisibility_property(seed, strat, split):
        """Random plans/seeds/splits: decision sequences, per-stage metric
        row counts and byte totals are identical with batching on vs off."""
        a = _control_plane_view(strat, seed=seed, batching=True,
                                map_split=split)
        b = _control_plane_view(strat, seed=seed, batching=False,
                                map_split=split)
        assert a == b


def test_batching_coalesces_claims_threads(tables):
    """Batching on: strictly fewer slot commits than invocations (map
    stages coalesce); batching off: one commit per attempt."""
    fd, dd, ref = tables
    commits = []
    counts = {}
    for on in (True, False):
        gc = GlobalController({n: 8 for n in range(4)})
        gc.subscribe(lambda ev, claim: commits.append(ev)
                     if ev == "commit" else None)
        before = len([c for c in commits if c == "commit"])
        rt = Runtime(gc, invoker="threads", batching=on)
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                       runtime=rt, map_split=4)
        np.testing.assert_allclose(got, ref, atol=1e-2)
        n_records = len(rt.metrics.for_app("query"))
        counts[on] = (len([c for c in commits if c == "commit"]) - before,
                      n_records)
    assert counts[True][1] == counts[False][1]     # same per-member records
    assert counts[True][0] < counts[False][0]      # fewer claims when batched


# -- batch crash demotes members to individual retries ----------------------------


def _map_stage(app, n, node=0):
    return RuntimeStage(app, [
        Invocation(f"{app}/{i}", "q", app, i, "scan_filter", node,
                   params={"src": "in", "dst": "out", "partition": i},
                   batchable=True)
        for i in range(n)])


def test_nonbatchable_interleave_closes_open_groups():
    """A non-batchable invocation is a sequencing point: it closes every
    open batch group, so a later same-key batchable invocation can never
    coalesce backwards across it — pinning execution order to submission
    order at each interleave."""
    order = []

    def rec(ctx):
        order.append(ctx.params["tag"])

    gc = GlobalController({0: 8})
    ivk = InlineInvoker(gc, ShuffleStore(), MetricsSink(), batching=True)
    ivk.registry = {"rec": rec}

    def mk(i, batchable):
        return Invocation(f"q/s/{i}", "q", "s", i, "rec", 0,
                          params={"tag": i}, batchable=batchable)

    invs = [mk(0, True), mk(1, True), mk(2, False), mk(3, True), mk(4, True)]
    groups = ivk._groups(invs)
    # the pre-interleave group stays coalesced; 3 and 4 form a NEW group
    # after the sequencing point instead of rejoining [0, 1]
    assert [[i.index for i in g] for g in groups] == [[0, 1], [2], [3, 4]]
    ivk.run_stage(invs)
    assert order == [0, 1, 2, 3, 4]
    assert sum(gc.used.values()) == 0


def test_batch_crash_retries_members_individually():
    gc = GlobalController({0: 4})
    store, metrics = ShuffleStore(), MetricsSink()
    t = synth_table("t", 64, 128, seed=0)
    for i in range(4):
        store.put("q", "in", i, t, node=0, writer="seed")
    plan = FaultPlan(crashes=[CrashFault("batchy", index=2, when="before")])
    rt = Runtime(gc, invoker=InlineInvoker(gc, store, metrics),
                 store=store, metrics=metrics)
    FaultInjector(plan).install(rt)
    rt.execute([_map_stage("batchy", 4)])

    recs = {}
    for r in metrics.records:
        recs.setdefault(r.name, []).append((r.status, r.attempt))
    # crashed member: crashed at the batch attempt, ok on its own attempt 1
    assert recs["batchy/2"] == [("crashed", 0), ("ok", 1)]
    # members before the crash committed inside the batch
    assert recs["batchy/0"] == [("ok", 0)] and recs["batchy/1"] == [("ok", 0)]
    # the member after the crash re-ran individually at attempt 0
    assert recs["batchy/3"] == [("ok", 0)]
    assert sum(gc.used.values()) == 0
    assert store.data_dist("q", "out").rows == 4 * 64


def test_batch_crash_exhaustion_matches_unbatched_budget():
    """An invocation that crashes on every attempt exhausts the same
    ``max_attempts`` budget whether its first attempt ran inside a batch
    or not (demotion must not grant a fresh budget)."""
    from repro.runtime import InvocationError

    for batching in (True, False):
        gc = GlobalController({0: 4})
        store, metrics = ShuffleStore(), MetricsSink()
        t = synth_table("t", 64, 128, seed=0)
        for i in range(4):
            store.put("q", "in", i, t, node=0, writer="seed")
        plan = FaultPlan(crashes=[CrashFault("batchy", index=1, when="before",
                                             attempt=a, times=1)
                                  for a in range(8)])
        rt = Runtime(gc, invoker=InlineInvoker(gc, store, metrics,
                                               batching=batching),
                     store=store, metrics=metrics)
        FaultInjector(plan).install(rt)
        with pytest.raises(InvocationError, match="crashed"):
            rt.execute([_map_stage("batchy", 4)])
        crashed = [r for r in metrics.records
                   if r.name == "batchy/1" and r.status == "crashed"]
        assert [r.attempt for r in crashed] == list(range(5))   # max_attempts
        assert sum(gc.used.values()) == 0


def test_batch_loss_mid_batch_propagates_typed_error():
    """A StageLostError inside a batch member releases the slot, keeps the
    completed members' records and propagates for executor recovery."""
    from repro.runtime import StageLostError

    gc = GlobalController({0: 4})
    store, metrics = ShuffleStore(), MetricsSink()
    t = synth_table("t", 64, 128, seed=0)
    for i in range(4):
        store.put("q", "in", i, t, node=0, writer="seed")
    inv = InlineInvoker(gc, store, metrics)
    store.lose_stage("q", "in", partitions=[2])
    with pytest.raises(StageLostError):
        inv.run_stage(_map_stage("batchy", 4).invocations)
    statuses = [(r.name, r.status) for r in metrics.records]
    assert ("batchy/0", "ok") in statuses and ("batchy/2", "error") in statuses
    assert sum(gc.used.values()) == 0             # no slot leak


# -- compute vs store-transfer timing split ---------------------------------------


def test_store_seconds_split_in_records_and_profile(tables):
    fd, dd, ref = tables
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, net_bw=200e6, disaggregated=True)
    got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                   runtime=rt)
    np.testing.assert_allclose(got, ref, atol=1e-2)
    oks = [r for r in rt.metrics.records if r.status == "ok"]
    assert oks
    for r in oks:
        assert 0.0 <= r.store_seconds <= r.seconds + 1e-6
        assert r.compute_seconds == pytest.approx(
            max(0.0, r.seconds - r.store_seconds))
    # the disaggregated store makes transfer time visible on the scans
    scan = rt.metrics.by_stage("query")["scan_fact"]
    assert scan.store_seconds > 0
    assert scan.seconds == pytest.approx(
        scan.store_seconds + scan.compute_seconds, rel=1e-6)
    profile = rt.metrics.profile_feedback("query")
    assert profile["scan_fact.store_seconds"] > 0
    assert "scan_fact.compute_seconds" in profile


def test_threads_batched_query_matches_oracle_with_split(tables):
    fd, dd, ref = tables
    gc = GlobalController({n: 8 for n in range(4)})
    store, metrics = ShuffleStore(), MetricsSink()
    rt = Runtime(gc, invoker=ThreadPoolInvoker(gc, store, metrics,
                                               max_workers=8),
                 store=store, metrics=metrics)
    got, _ = execute_query_runtime(fd, dd, QueryStrategy("dynamic"),
                                   runtime=rt, map_split=6)
    np.testing.assert_allclose(got, ref, atol=1e-2)
    assert sum(gc.used.values()) == 0
